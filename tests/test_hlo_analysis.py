"""The trip-count-aware HLO cost model — validated against programs whose
true cost is known analytically (this underpins every §Roofline number)."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_analysis import HloCostModel, analyze


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestHloCostModel:
    def test_single_matmul_flops(self):
        x = jnp.zeros((64, 128), jnp.float32)
        w = jnp.zeros((128, 32), jnp.float32)
        res = analyze(_hlo(lambda a, b: a @ b, x, w))
        expect = 2 * 64 * 128 * 32
        assert abs(res["flops"] - expect) / expect < 0.05

    def test_scan_multiplies_by_trip_count(self):
        L = 10
        x = jnp.zeros((64, 64), jnp.float32)

        def f(x):
            return lax.scan(lambda c, _: (jnp.tanh(c @ c), None), x, None,
                            length=L)[0]
        res = analyze(_hlo(f, x))
        one = 2 * 64 ** 3
        assert res["flops"] > L * one * 0.9
        assert res["flops"] < L * one * 1.6   # + elementwise floor

    def test_nested_scan(self):
        x = jnp.zeros((32, 32), jnp.float32)

        def inner(c):
            return lax.scan(lambda c, _: (c @ c, None), c, None,
                            length=3)[0]

        def f(x):
            return lax.scan(lambda c, _: (inner(c), None), x, None,
                            length=4)[0]
        res = analyze(_hlo(f, x))
        expect = 12 * 2 * 32 ** 3
        assert res["flops"] > expect * 0.9

    def test_memory_bytes_scale(self):
        x = jnp.zeros((1024, 1024), jnp.float32)
        res = analyze(_hlo(lambda a: a + 1.0, x))
        # read + write ≈ 8MB
        assert 4e6 < res["hbm_bytes"] < 4e7

    def test_entry_found(self):
        txt = _hlo(lambda a: a * 2, jnp.zeros((8,)))
        cm = HloCostModel(txt)
        assert cm.entry is not None
        assert len(cm.computations) >= 1
