"""Algorithm 3 (SolveBakF) — feature selection + stepwise baseline."""
import jax.numpy as jnp
import numpy as np

from repro.core import solvebakf, stepwise_regression_baseline


def planted_problem(rng, obs=400, nvars=60, k=6, noise=0.01):
    x = rng.normal(size=(obs, nvars)).astype(np.float32)
    idx = rng.choice(nvars, size=k, replace=False)
    coef = np.zeros(nvars, np.float32)
    coef[idx] = rng.normal(size=k).astype(np.float32) * 4 + np.sign(
        rng.normal(size=k)).astype(np.float32)
    y = x @ coef + noise * rng.normal(size=obs).astype(np.float32)
    return x, y, set(idx.tolist())


class TestSolveBakF:
    def test_recovers_planted_features(self, rng):
        x, y, idx = planted_problem(rng)
        res = solvebakf(jnp.array(x), jnp.array(y), max_feat=len(idx))
        assert set(np.array(res.selected).tolist()) == idx

    def test_sse_path_decreasing(self, rng):
        x, y, _ = planted_problem(rng, k=8)
        res = solvebakf(jnp.array(x), jnp.array(y), max_feat=8)
        path = np.array(res.sse_path)
        assert np.all(np.diff(path) <= 1e-3 * path[:-1] + 1e-5)

    def test_no_duplicate_selection(self, rng):
        x, y, _ = planted_problem(rng, k=4)
        res = solvebakf(jnp.array(x), jnp.array(y), max_feat=10)
        sel = np.array(res.selected).tolist()
        assert len(set(sel)) == len(sel)

    def test_matches_stepwise_on_easy_problem(self, rng):
        """Fig 2 framing: same features as stepwise regression, much less
        work (stepwise cost is O(vars) solves per step)."""
        x, y, idx = planted_problem(rng, nvars=30, k=4)
        fast = solvebakf(jnp.array(x), jnp.array(y), max_feat=4)
        slow = stepwise_regression_baseline(jnp.array(x), jnp.array(y),
                                            max_feat=4)
        assert set(np.array(fast.selected).tolist()) == \
            set(np.array(slow.selected).tolist()) == idx
