"""Fused whole-solve megakernel: interpret-mode parity + dispatch + fallback.

Parity targets (ISSUE 5 acceptance): <= 1e-5 vs the XLA ``solvebak`` /
``solvebakp`` solvers across single/multi-RHS x warm-start x early-exit, and
``n_sweeps`` equality vs the unfused per-sweep kernel launch loop (the fused
kernel reproduces its SSE reduction bit-for-bit in interpret mode, so the
on-chip stopping decisions match the host-side ones sweep-for-sweep).

The VMEM-budget tests monkeypatch ``repro.kernels.cd_sweep.
VMEM_BUDGET_BYTES`` (the shared budget ``fused_fits`` reads at call time):
the raw kernel must refuse with the VMEM error message, while every dispatch
route (method registry, ``PreparedDesign.solve``, the serving engine) must
fall back to the XLA path and still serve the request.
"""
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverSpec, prepare, solve, solvebak, solvebakp
from repro.core.spec import solver_method
from repro.kernels import (fused_fits, fused_solve, fused_vmem_bytes,
                           solvebakp_kernel, solvebakp_persweep_kernel)

# The package attribute ``cd_sweep`` is the *function*; the module (owner of
# VMEM_BUDGET_BYTES) is reached through sys.modules (see test_kernels.py).
_CD = sys.modules["repro.kernels.cd_sweep"]


def _system(rng, obs=512, nvars=64, k=None, consistent=True):
    x = rng.normal(size=(obs, nvars)).astype(np.float32)
    shape = (nvars,) if k is None else (nvars, k)
    a = rng.normal(size=shape).astype(np.float32)
    y = (x @ a).astype(np.float32)
    if not consistent:
        y = y + 0.1 * rng.normal(size=y.shape).astype(np.float32)
    return x, a, y


class TestFusedParity:
    @pytest.mark.parametrize("k", [None, 4])
    @pytest.mark.parametrize("warm", [False, True])
    def test_bakp_vs_xla(self, rng, k, warm):
        x, a, y = _system(rng, k=k)
        a0 = None
        if warm:
            a0 = (0.8 * a).astype(np.float32)
        rf = fused_solve(jnp.asarray(x.T), jnp.asarray(y),
                         a0=None if a0 is None else jnp.asarray(a0),
                         block=16, max_iter=40)
        rx = solvebakp(jnp.asarray(x), jnp.asarray(y), thr=16, max_iter=40,
                       a0=None if a0 is None else jnp.asarray(a0))
        np.testing.assert_allclose(np.asarray(rf.coef), np.asarray(rx.coef),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rf.residual),
                                   np.asarray(rx.residual),
                                   rtol=1e-5, atol=1e-5)
        assert int(rf.n_sweeps) == int(rx.n_sweeps) == 40

    @pytest.mark.parametrize("k", [None, 3])
    def test_bak_vs_solvebak(self, rng, k):
        x, _, y = _system(rng, obs=256, nvars=32, k=k)
        rf = fused_solve(jnp.asarray(x.T), jnp.asarray(y), variant="bak",
                         block=8, max_iter=12)
        rx = solvebak(jnp.asarray(x), jnp.asarray(y), max_iter=12)
        np.testing.assert_allclose(np.asarray(rf.coef), np.asarray(rx.coef),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rf.sse), np.asarray(rx.sse),
                                   rtol=1e-4, atol=1e-6)

    @pytest.mark.parametrize("k", [None, 4])
    @pytest.mark.parametrize("warm", [False, True])
    @pytest.mark.parametrize("variant", ["bak", "bakp"])
    def test_early_exit_n_sweeps_matches_unfused(self, rng, k, warm,
                                                 variant):
        """atol early exit: fused must stop at the same sweep as the
        per-sweep launch loop, well before max_iter, on cold AND warm
        starts, single- AND multi-RHS."""
        x, a, y = _system(rng, k=k)
        a0 = jnp.asarray(0.5 * a) if warm else None
        kw = dict(block=16, max_iter=100, atol=1e-3, variant=variant)
        rf = fused_solve(jnp.asarray(x.T), jnp.asarray(y), a0=a0, **kw)
        ru = solvebakp_persweep_kernel(jnp.asarray(x.T), jnp.asarray(y),
                                       a0=a0, **kw)
        assert int(rf.n_sweeps) == int(ru.n_sweeps) < 100
        assert bool(rf.converged) and bool(ru.converged)
        np.testing.assert_allclose(np.asarray(rf.coef), np.asarray(ru.coef),
                                   rtol=1e-5, atol=1e-5)

    def test_rtol_stall_matches_unfused(self, rng):
        """rtol stall: the fused kernel's on-chip SSE reduction reproduces
        the host jnp.vdot bit-for-bit, so even the razor-edge rtol stopping
        sweep matches the unfused launch loop, and the histories are
        identical."""
        x, _, y = _system(rng, obs=1024, nvars=128)
        kw = dict(block=32, max_iter=80, rtol=1e-9)
        rf = fused_solve(jnp.asarray(x.T), jnp.asarray(y), **kw)
        ru = solvebakp_persweep_kernel(jnp.asarray(x.T), jnp.asarray(y),
                                       **kw)
        assert int(rf.n_sweeps) == int(ru.n_sweeps) < 80
        np.testing.assert_array_equal(np.asarray(rf.history),
                                      np.asarray(ru.history))

    def test_precomputed_norms_match_recomputed(self, rng):
        from repro.core.types import column_norms_sq, safe_inv

        x, _, y = _system(rng)
        cn = column_norms_sq(jnp.asarray(x))
        base = fused_solve(jnp.asarray(x.T), jnp.asarray(y), block=16,
                           max_iter=10)
        via_cn = fused_solve(jnp.asarray(x.T), jnp.asarray(y), cn=cn,
                             block=16, max_iter=10)
        via_inv = fused_solve(jnp.asarray(x.T), jnp.asarray(y),
                              inv_cn=safe_inv(cn), block=16, max_iter=10)
        np.testing.assert_array_equal(np.asarray(base.coef),
                                      np.asarray(via_cn.coef))
        np.testing.assert_array_equal(np.asarray(base.coef),
                                      np.asarray(via_inv.coef))

    def test_solvebakp_kernel_shim_dispatches_fused(self, rng):
        """The public kernel entry runs fused for VMEM-fitting designs and
        matches the per-sweep path it replaced."""
        x, _, y = _system(rng)
        assert fused_fits(64, 512, 1, 4, max_iter=40)
        ks = solvebakp_kernel(jnp.asarray(x.T), jnp.asarray(y), block=16,
                              max_iter=40)
        ps = solvebakp_persweep_kernel(jnp.asarray(x.T), jnp.asarray(y),
                                       block=16, max_iter=40)
        np.testing.assert_allclose(np.asarray(ks.coef), np.asarray(ps.coef),
                                   rtol=1e-5, atol=1e-5)
        assert int(ks.n_sweeps) == int(ps.n_sweeps)


class TestVmemBudget:
    def test_fused_solve_raises_over_budget(self, rng, monkeypatch):
        x, _, y = _system(rng, obs=128, nvars=16)
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES", 1024)
        with pytest.raises(ValueError, match="VMEM"):
            fused_solve(jnp.asarray(x.T), jnp.asarray(y), block=8)

    def test_budget_accounting(self):
        b = fused_vmem_bytes(128, 1024, 2, 4, max_iter=50)
        assert b == (128 * 1024 * 4 + 2 * 2 * 1024 * 4 + 2 * 128 * 2 * 4
                     + 128 * 4 + 50 * 4)
        assert fused_fits(128, 1024, 2, 4, max_iter=50)

    def test_kernel_shim_falls_back_to_persweep(self, rng, monkeypatch):
        """Over budget, solvebakp_kernel silently uses the per-sweep loop
        (whose own smaller working set still fits) instead of raising."""
        x, _, y = _system(rng, obs=128, nvars=16)
        # fused needs the whole x resident; the per-sweep loop only one
        # (block, obs) tile + the residual.
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES", 6 * 1024)
        r = solvebakp_kernel(jnp.asarray(x.T), jnp.asarray(y), block=8,
                             max_iter=30)
        ref = solvebakp(jnp.asarray(x), jnp.asarray(y), thr=8, max_iter=30)
        np.testing.assert_allclose(np.asarray(r.coef), np.asarray(ref.coef),
                                   rtol=1e-5, atol=1e-5)

    def test_method_falls_back_to_xla(self, rng, monkeypatch):
        """The registry method never raises on oversized designs — it runs
        the XLA path of the same algorithm."""
        x, a, y = _system(rng, obs=128, nvars=16)
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES", 128)
        r = solve(x, y, method="bakp_fused", thr=8, max_iter=60, rtol=1e-9)
        ref = solvebakp(jnp.asarray(x), jnp.asarray(y), thr=8, max_iter=60,
                        rtol=1e-9)
        np.testing.assert_allclose(np.asarray(r.coef), np.asarray(ref.coef),
                                   rtol=1e-6, atol=1e-6)

    def test_engine_falls_back_instead_of_raising(self, rng, monkeypatch):
        """A bakp_fused request on an over-budget bucket is served (XLA
        fallback), not failed."""
        from repro.serve import SolveRequest, SolverServeEngine

        x, a, y = _system(rng, obs=128, nvars=16)
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES", 128)
        engine = SolverServeEngine()
        spec = SolverSpec(method="bakp_fused", thr=8, max_iter=60,
                          rtol=1e-9)
        [served] = engine.serve([SolveRequest(x=x, y=y, spec=spec)])
        assert served.error is None
        np.testing.assert_allclose(served.coef, a, rtol=1e-3, atol=1e-3)


class TestMethodDispatch:
    @pytest.mark.parametrize("method,variant", [("bakp_fused", "bakp"),
                                                ("bak_fused", "bak")])
    def test_registry_entry(self, method, variant):
        e = solver_method(method)
        assert e.iterative and e.multi_rhs and e.blocked
        assert not e.shardable and not e.batchable
        assert e.prepare is not None

    def test_solve_shim(self, rng):
        x, a, y = _system(rng)
        r = solve(x, y, method="bakp_fused", thr=16, max_iter=60, rtol=1e-9)
        np.testing.assert_allclose(np.asarray(r.coef), a, rtol=1e-3,
                                   atol=1e-3)

    def test_prepared_design_handle(self, rng):
        """prepare() warms the transposed design + inv_cn caches; repeated
        handle solves reuse them and match the XLA bakp path."""
        x, _, y = _system(rng, k=4)
        spec = SolverSpec(method="bakp_fused", thr=16, max_iter=40)
        design = prepare(x, spec)
        assert 16 in design._x_t and 16 in design._inv_cn  # prepare hook ran
        r = design.solve(y)
        ref = solvebakp(jnp.asarray(x), jnp.asarray(y), thr=16, max_iter=40)
        np.testing.assert_allclose(np.asarray(r.coef), np.asarray(ref.coef),
                                   rtol=1e-5, atol=1e-5)
        # x_t cache: padded to a thr multiple (64 -> 72), transposed layout
        x_t = design.x_t_for(24)
        assert x_t.shape == (72, x.shape[0])
        np.testing.assert_array_equal(np.asarray(x_t[:64]), x.T)
        assert float(jnp.abs(x_t[64:]).max()) == 0.0

    def test_engine_coalesces_fused_requests(self, rng):
        from repro.serve import SolveRequest, SolverServeEngine

        x, _, _ = _system(rng, obs=256, nvars=32)
        coefs = rng.normal(size=(32, 3)).astype(np.float32)
        spec = SolverSpec(method="bakp_fused", thr=16, max_iter=60,
                          rtol=1e-9)
        engine = SolverServeEngine()
        served = engine.serve([
            SolveRequest(x=x, y=(x @ coefs[:, i]).astype(np.float32),
                         spec=spec, design_key="d0")
            for i in range(3)])
        assert all(s.batch_kind == "multi_rhs" for s in served)
        assert all(s.error is None for s in served)
        for i, s in enumerate(served):
            np.testing.assert_allclose(s.coef, coefs[:, i], rtol=1e-3,
                                       atol=1e-3)

    def test_engine_prefer_fused_upgrade(self, rng):
        """prefer_fused upgrades eligible 'bakp' requests to the megakernel
        and serves identical results."""
        from repro.serve import (ServeConfig, SolveRequest,
                                 SolverServeEngine)

        x, a, y = _system(rng, obs=256, nvars=32)
        req = SolveRequest(x=x, y=y, spec=SolverSpec(
            method="bakp", thr=16, max_iter=60, rtol=1e-9))
        engine = SolverServeEngine(ServeConfig(prefer_fused=True))
        assert engine.spec_for(req).method == "bakp_fused"
        plain = SolverServeEngine()
        assert plain.spec_for(req).method == "bakp"
        [served] = engine.serve([req])
        assert served.error is None
        np.testing.assert_allclose(served.coef, a, rtol=1e-3, atol=1e-3)


class TestValidationAndDonation:
    def test_rejects_bad_shapes(self, rng):
        x, _, y = _system(rng, obs=64, nvars=16)
        with pytest.raises(ValueError, match="multiple of block"):
            fused_solve(jnp.asarray(x.T), jnp.asarray(y), block=10)
        with pytest.raises(ValueError, match="a0"):
            fused_solve(jnp.asarray(x.T), jnp.asarray(y), block=8,
                        a0=jnp.zeros((7,)))
        with pytest.raises(ValueError, match="variant"):
            fused_solve(jnp.asarray(x.T), jnp.asarray(y), block=8,
                        variant="nope")
        with pytest.raises(ValueError, match="max_iter"):
            fused_solve(jnp.asarray(x.T), jnp.asarray(y), block=8,
                        max_iter=0)

    def test_donate_flag_accepted(self, rng):
        """donate is a no-op on CPU but must be accepted on every solver
        entry, and an explicit donate=False must never invalidate inputs."""
        x, _, y = _system(rng, obs=64, nvars=16)
        xd, yd = jnp.asarray(x), jnp.asarray(y)
        r1 = fused_solve(jnp.asarray(x.T), yd, block=8, max_iter=5,
                         donate=False)
        r2 = solvebak(xd, yd, max_iter=5, donate=False)
        r3 = solvebakp(xd, yd, thr=8, max_iter=5, donate=False)
        r4 = solvebakp_kernel(jnp.asarray(x.T), yd, block=8, max_iter=5,
                              donate=False)
        assert float(yd[0]) == y[0]  # y still alive after all four solves
        assert r2.coef.shape == (16,)  # solvebak ran (Algorithm 1)
        np.testing.assert_allclose(np.asarray(r1.residual),
                                   np.asarray(r3.residual), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(r1.coef),
                                      np.asarray(r4.coef))
