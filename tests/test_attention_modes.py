"""Flash-attention schedule equivalence: masked vs triangular causal modes
must produce identical outputs (the §Perf lever changes compute order only),
and both must match a dense reference softmax(QK^T)V."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention


def dense_ref(q, k, v, causal=True, window=0, softcap=0.0):
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    q5 = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k.astype(jnp.float32)) * d ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos, kpos = jnp.arange(sq), jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -2e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d)


@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("seq", [128, 96])
def test_masked_equals_triangular_equals_dense(window, seq):
    rng = np.random.default_rng(0)
    b, h, hkv, d = 2, 4, 2, 16
    q = jnp.array(rng.normal(size=(b, seq, h, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, seq, hkv, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, seq, hkv, d)), jnp.float32)
    kw = dict(causal=True, window=window, q_chunk=32, k_chunk=32)
    o_masked = flash_attention(q, k, v, causal_mode="masked", **kw)
    o_tri = flash_attention(q, k, v, causal_mode="triangular", **kw)
    o_ref = dense_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.array(o_masked), np.array(o_tri),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.array(o_tri), np.array(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_softcap_modes_agree():
    rng = np.random.default_rng(1)
    b, seq, h, hkv, d = 1, 64, 4, 4, 8
    q = jnp.array(rng.normal(size=(b, seq, h, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, seq, hkv, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, seq, hkv, d)), jnp.float32)
    kw = dict(causal=True, softcap=30.0, q_chunk=16, k_chunk=16)
    o1 = flash_attention(q, k, v, causal_mode="masked", **kw)
    o2 = flash_attention(q, k, v, causal_mode="triangular", **kw)
    o3 = dense_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.array(o1), np.array(o2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.array(o2), np.array(o3), rtol=1e-4,
                               atol=1e-4)
