"""int8 KV-cache quantization (serving lever, §Perf-5): quantized decode
must track the fp cache decode closely and halve+ the cache bytes."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models.kvcache import cache_bytes, init_cache
from repro.models.model import (forward_decode, forward_prefill, init_model,
                                make_smoke_batch)


def _run(cfg, params, batch, steps=4):
    cache = init_cache(cfg, 2, cfg.max_cache_len)
    logits, cache = forward_prefill(cfg, params, batch, cache)
    outs = [logits]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(steps):
        logits, cache = forward_decode(cfg, params, tok, cache)
        outs.append(logits)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return outs


def test_int8_kv_matches_fp_cache():
    base = ARCHS["qwen3-8b"].smoke()
    quant = dataclasses.replace(base, kv_quant="int8")
    key = jax.random.PRNGKey(0)
    params = init_model(base, key)
    batch = make_smoke_batch(base, key, batch=2, seq=32)
    batch.pop("labels", None)
    fp = _run(base, params, batch)
    q8 = _run(quant, params, batch)
    for a, b in zip(fp, q8):
        # same greedy tokens + close logits
        assert jnp.array_equal(jnp.argmax(a, -1), jnp.argmax(b, -1))
        sa = jax.nn.log_softmax(a)
        sb = jax.nn.log_softmax(b)
        assert float(jnp.abs(sa - sb).max()) < 0.15


def test_int8_kv_cache_bytes_halved():
    base = ARCHS["qwen3-8b"]
    quant = dataclasses.replace(base, kv_quant="int8")
    assert cache_bytes(quant, 128, 32768) < 0.6 * cache_bytes(base, 128, 32768)


def test_int8_kv_with_swa_ring():
    base = ARCHS["h2o-danube-1.8b"].smoke()
    quant = dataclasses.replace(base, kv_quant="int8")
    key = jax.random.PRNGKey(1)
    params = init_model(base, key)
    batch = make_smoke_batch(base, key, batch=2, seq=48)  # > ring window 32
    batch.pop("labels", None)
    fp = _run(base, params, batch)
    q8 = _run(quant, params, batch)
    for a, b in zip(fp, q8):
        assert jnp.array_equal(jnp.argmax(a, -1), jnp.argmax(b, -1))
