"""Execution lanes: routing, executor semantics, engine/dispatcher parity.

Covers the lane layer end to end:

  * pure routing units — ``Placement.lane_key`` / ``lane_for`` /
    ``MethodEntry.lane`` registry capability, no threads or devices;
  * ``LaneExecutor``/``LanePool`` concurrency semantics — most-urgent-first
    ordering, error propagation, drain vs abandon shutdown;
  * engine parity — a mixed xla + fused workload through the lane engine is
    bitwise-identical to ``lane_execution=False`` (the serial baseline),
    including a 1-device in-process mesh so the ``mesh:obs_sharded`` lane
    runs without virtual-device forcing;
  * the dispatcher hammer — concurrent submitters racing mixed placements
    through ``AsyncDispatcher``, per-lane stats, clean ``stop(drain=...)``
    with no orphaned tickets;
  * the ``prefer_fused``-on-mesh fallback metric and the dispatch thread's
    deadline/idle firing without a poll interval.
"""
import threading
import time

import numpy as np
import pytest

from conftest import make_system
from repro import obs
from repro.core.spec import solver_method
from repro.serve import (AsyncDispatcher, DispatchConfig, DispatcherStopped,
                         LaneKey, LanePool, LaneShutdown, LaneWork, Placement,
                         PlacementPolicy, ServeConfig, SolveRequest,
                         SolverServeEngine, build_serve_mesh, current_lane,
                         lane_for)
from repro.serve.lanes import SERIAL_LANE


def _req(x, y, **kw):
    kw.setdefault("max_iter", 40)
    kw.setdefault("rtol", 1e-12)
    return SolveRequest(x=x, y=y, **kw)


# ------------------------------------------------------------ routing (pure)
class TestLaneRouting:
    def test_registry_lane_capability(self):
        assert solver_method("bakp").lane == "xla"
        assert solver_method("bakp_gram").lane == "xla"
        assert solver_method("bakp_fused").lane == "fused"
        assert solver_method("bak_fused").lane == "fused"

    def test_placement_lane_key(self):
        assert Placement().lane_key("bakp_gram") == "single:xla"
        assert Placement().lane_key("bakp_fused") == "single:fused"
        assert Placement().lane_key("not_registered") == "single:xla"
        assert (Placement("obs_sharded").lane_key("bakp")
                == "mesh:obs_sharded")
        assert (Placement("rhs_sharded").lane_key("bakp_gram")
                == "mesh:rhs_sharded")

    def test_lane_for_labels_and_devices(self):
        xla = lane_for("bakp_gram")
        fused = lane_for("bakp_fused")
        assert xla.label == "single:xla" and fused.label == "single:fused"
        assert xla != fused
        assert len(xla.devices) == 1  # the default device
        # same method + placement -> the same (hashable) key
        assert lane_for("bakp_gram") == xla

    def test_serial_pool_collapses_everything(self):
        pool = LanePool(serial=True)
        assert pool.lane_for("bakp_gram") == SERIAL_LANE
        assert pool.lane_for("bakp_fused",
                             Placement("obs_sharded")) == SERIAL_LANE


# ----------------------------------------------------- executor (no devices)
class TestLaneExecutor:
    def test_urgency_orders_queue(self):
        pool = LanePool(registry=obs.MetricsRegistry())
        key = LaneKey("single:test")
        order = []
        gate = threading.Event()
        first = pool.submit(key, LaneWork(gate.wait, size=0))
        # Queue three more while the lane is blocked; they must drain
        # most-urgent-first regardless of submission order.
        works = [pool.submit(key, LaneWork(lambda u=u: order.append(u),
                                           urgency=u))
                 for u in (30.0, 10.0, 20.0)]
        gate.set()
        for w in works:
            assert w.wait(10.0)
        assert order == [10.0, 20.0, 30.0]
        assert first.done() and first.error is None
        stats = pool.stats()["single:test"]
        assert stats["batches"] == 4
        assert stats["max_queue_depth"] >= 3
        pool.shutdown()

    def test_error_lands_on_work_not_thread(self):
        pool = LanePool(registry=obs.MetricsRegistry())
        key = LaneKey("single:test")

        def boom():
            raise ValueError("boom")

        bad = pool.submit(key, LaneWork(boom))
        good = pool.submit(key, LaneWork(lambda: None))
        assert bad.wait(10.0) and good.wait(10.0)
        assert isinstance(bad.error, ValueError)
        assert good.error is None
        assert pool.stats()["single:test"]["failures"] == 1
        pool.shutdown()

    def test_current_lane_marks_executor_thread(self):
        pool = LanePool(registry=obs.MetricsRegistry())
        key = LaneKey("single:test")
        seen = []
        w = pool.submit(key, LaneWork(lambda: seen.append(current_lane())))
        assert w.wait(10.0)
        assert seen == [key]
        assert current_lane() is None  # not on a lane thread here
        pool.shutdown()

    def test_shutdown_abandons_queued_work(self):
        pool = LanePool(registry=obs.MetricsRegistry())
        key = LaneKey("single:test")
        gate = threading.Event()
        running = pool.submit(key, LaneWork(gate.wait, size=0))
        queued = [pool.submit(key, LaneWork(lambda: None)) for _ in range(3)]
        gate.set()
        pool.shutdown(drain=False)
        assert running.wait(10.0)
        for w in queued:
            assert w.wait(10.0)  # events fire even though abandoned
            assert (w.error is None  # may have started before the stop
                    or isinstance(w.error, LaneShutdown))
        # The pool stays usable: a fresh executor spins up for the key.
        again = pool.submit(key, LaneWork(lambda: None))
        assert again.wait(10.0) and again.error is None
        pool.shutdown()


# ------------------------------------------------------- engine parity (jax)
class TestEngineLaneParity:
    def _workload(self, rng, n=6):
        reqs = []
        for i in range(n):
            x, y, _ = make_system(rng, 96, 12)
            method = "bakp_fused" if i % 3 == 0 else "bakp_gram"
            reqs.append(_req(x, y, method=method, thr=8,
                             design_key=f"lane-{i}", request_id=f"r-{i}"))
        return reqs

    def test_mixed_lanes_bitwise_match_serial(self, rng):
        lane_eng = SolverServeEngine(ServeConfig())
        serial_eng = SolverServeEngine(ServeConfig(lane_execution=False))
        r_lane = lane_eng.serve(self._workload(np.random.default_rng(3)))
        r_serial = serial_eng.serve(self._workload(np.random.default_rng(3)))
        assert not [r.error for r in r_lane + r_serial if r.error]
        for a, b in zip(r_lane, r_serial):
            assert np.array_equal(a.coef, b.coef), a.request_id
        labels = set(lane_eng.lanes.stats())
        assert labels == {"single:xla", "single:fused"}
        assert set(serial_eng.lanes.stats()) == {"serial"}
        # telemetry + per-lane gauges carry the lane identity
        lanes_seen = {r.telemetry.lane for r in r_lane
                      if r.telemetry is not None}
        assert lanes_seen == {"single:xla", "single:fused"}
        lat = lane_eng.registry.get("serve_solve_latency_seconds")
        assert lat.count(lane="single:fused") >= 1
        assert lat.count(lane="single:xla") >= 1
        g = lane_eng.registry.get("serve_lane_inflight")
        assert g.value(lane="single:xla") == 0  # drained
        lane_eng.shutdown()
        serial_eng.shutdown()

    def test_one_device_mesh_lane(self, rng):
        """A 1-device in-process mesh exercises the mesh lane (and its
        resident PreparedDesign copies) without virtual-device forcing."""
        policy = PlacementPolicy(obs_shard_min_cells=128 * 16)
        mesh_eng = SolverServeEngine(
            ServeConfig(placement_policy=policy),
            mesh=build_serve_mesh("1"))
        serial_eng = SolverServeEngine(ServeConfig())

        def work(seed):
            r = np.random.default_rng(seed)
            reqs = []
            for i in range(2):  # big bucket -> obs_sharded on the mesh
                x, y, _ = make_system(r, 200, 16)
                reqs.append(_req(x, y, method="bakp_gram", thr=16,
                                 design_key=f"big-{i}",
                                 request_id=f"big-{i}"))
            for i in range(2):  # small bucket -> single lane
                x, y, _ = make_system(r, 40, 8)
                reqs.append(_req(x, y, method="bakp_gram", thr=8,
                                 design_key=f"small-{i}",
                                 request_id=f"small-{i}"))
            return reqs

        r_mesh = mesh_eng.serve(work(11))
        r_single = serial_eng.serve(work(11))
        assert not [r.error for r in r_mesh + r_single if r.error]
        assert {r.placement for r in r_mesh} == {"obs_sharded", "single"}
        for m, s in zip(r_mesh, r_single):
            denom = np.maximum(np.abs(s.coef), 1e-12)
            assert float(np.mean(np.abs(m.coef - s.coef) / denom)) <= 1e-5
        assert "mesh:obs_sharded" in mesh_eng.lanes.stats()
        # the design entries remember their home + resident lanes
        entry = mesh_eng.cache.get("big-0", record_stats=False)
        assert entry.home == "obs_sharded"
        assert "obs_sharded" in entry.resident_lanes()
        mesh_eng.shutdown()
        serial_eng.shutdown()


# -------------------------------------------------------- dispatcher hammer
class TestDispatcherLanes:
    @pytest.mark.slow
    def test_concurrent_submitters_mixed_lanes(self, rng):
        """Racing submitters over single:xla, single:fused and vmap traffic:
        every ticket lands, per-lane stats populate, answers stay correct."""
        eng = SolverServeEngine(ServeConfig())
        cfg = DispatchConfig(max_batch=8, idle_timeout_s=0.005,
                             prewarm_cache=True)
        n_sub, per = 4, 12
        systems = {}
        r = np.random.default_rng(21)
        for s in range(n_sub):
            for i in range(per):
                method = "bakp_fused" if (s + i) % 3 == 0 else "bakp_gram"
                x = r.normal(size=(80, 10)).astype(np.float32)
                a = r.normal(size=(10,)).astype(np.float32)
                systems[(s, i)] = (x, x @ a, a, method)
        tickets = {}
        tlock = threading.Lock()
        errs = []

        def submitter(s, disp):
            try:
                for i in range(per):
                    x, y, _, method = systems[(s, i)]
                    t = disp.submit(_req(
                        x, y, method=method, thr=8,
                        design_key=f"d-{s}-{i}", request_id=f"q-{s}-{i}"))
                    with tlock:
                        tickets[(s, i)] = t
            except Exception as exc:  # pragma: no cover - surfaced below
                errs.append(exc)

        with AsyncDispatcher(eng, cfg) as disp:
            threads = [threading.Thread(target=submitter, args=(s, disp))
                       for s in range(n_sub)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errs
            results = {k: t.result(timeout=120.0)
                       for k, t in tickets.items()}
        assert len(results) == n_sub * per
        for (s, i), res in results.items():
            _, _, a, _ = systems[(s, i)]
            denom = np.maximum(np.abs(a), 1e-12)
            assert float(np.mean(np.abs(res.coef - a) / denom)) <= 1e-4
        assert disp.inflight == 0
        # both single-device lanes fired, and the dispatcher + engine agree
        assert {"single:xla", "single:fused"} <= set(disp.stats.lane_batches)
        lanes = eng.lanes.stats()
        assert {"single:xla", "single:fused"} <= set(lanes)
        assert (sum(ls["requests"] for ls in lanes.values())
                >= n_sub * per)
        eng.shutdown()

    def test_stop_no_drain_orphans_nothing(self, rng):
        eng = SolverServeEngine(ServeConfig())
        # Huge idle timeout: batches only fire on the drain/stop path, so
        # tickets are still pending when stop(drain=False) lands.
        cfg = DispatchConfig(idle_timeout_s=1e9, max_batch=1000,
                             prewarm_cache=False)
        disp = AsyncDispatcher(eng, cfg).start()
        x, y, _ = make_system(rng, 40, 8)
        tickets = [disp.submit(_req(x, y, thr=8, design_key="d",
                                    request_id=f"s-{i}"))
                   for i in range(8)]
        disp.stop(drain=False)
        for t in tickets:
            assert t.done(), "orphaned ticket after stop(drain=False)"
            with pytest.raises(DispatcherStopped):
                t.result(timeout=0)
        assert disp.inflight == 0
        eng.shutdown()

    def test_stop_drain_serves_everything(self, rng):
        eng = SolverServeEngine(ServeConfig())
        cfg = DispatchConfig(idle_timeout_s=1e9, max_batch=1000,
                             prewarm_cache=False)
        disp = AsyncDispatcher(eng, cfg).start()
        x, y, a = make_system(rng, 40, 8)
        tickets = [disp.submit(_req(x, y, thr=8, design_key="d",
                                    request_id=f"t-{i}"))
                   for i in range(4)]
        disp.stop(drain=True)
        for t in tickets:
            assert t.done()
            t.result(timeout=0)  # served, not failed
        eng.shutdown()

    def test_fires_without_polling(self, rng):
        """Idle and deadline firing rely on the computed CV wakeup now:
        with the deprecated poll interval set absurdly high, batches must
        still fire on time."""
        eng = SolverServeEngine(ServeConfig())
        x, y, _ = make_system(rng, 40, 8)
        eng.serve([_req(x, y, thr=8, design_key="w")])  # precompile
        cfg = DispatchConfig(idle_timeout_s=0.01, max_batch=1000,
                             poll_interval_s=1e6, prewarm_cache=False)
        with AsyncDispatcher(eng, cfg) as disp:
            t0 = time.perf_counter()
            t = disp.submit(_req(x, y, thr=8, design_key="w"))
            t.result(timeout=30.0)
            assert time.perf_counter() - t0 < 5.0
        cfg = DispatchConfig(idle_timeout_s=1e9, max_batch=1000,
                             deadline_margin_s=0.25,
                             poll_interval_s=1e6, prewarm_cache=False)
        with AsyncDispatcher(eng, cfg) as disp:
            t0 = time.perf_counter()
            t = disp.submit(_req(x, y, thr=8, design_key="w"),
                            deadline_s=0.3)
            t.result(timeout=30.0)
            assert time.perf_counter() - t0 < 5.0
        eng.shutdown()

    def test_per_lane_backpressure_rejects(self, rng):
        eng = SolverServeEngine(ServeConfig())
        cfg = DispatchConfig(idle_timeout_s=1e9, max_batch=1000,
                             max_lane_inflight=2, backpressure="reject",
                             prewarm_cache=False)
        from repro.serve import QueueFullError
        disp = AsyncDispatcher(eng, cfg).start()
        x, y, _ = make_system(rng, 40, 8)
        for i in range(2):
            disp.submit(_req(x, y, thr=8, design_key="bp",
                             request_id=f"bp-{i}"))
        with pytest.raises(QueueFullError, match="lane single:xla"):
            disp.submit(_req(x, y, thr=8, design_key="bp",
                             request_id="bp-over"))
        disp.stop(drain=True)
        # completions released the lane budget
        t = disp = None
        eng.shutdown()


# ----------------------------------------------- prefer_fused mesh fallback
class TestUnshardableFusedFallback:
    def test_mesh_engine_counts_and_logs_once(self, rng, caplog):
        eng = SolverServeEngine(ServeConfig(prefer_fused=True),
                                mesh=build_serve_mesh("1"),
                                registry=obs.MetricsRegistry())
        x, y, _ = make_system(rng, 40, 8)
        req = _req(x, y, method="bakp", thr=8, max_iter=4)
        with caplog.at_level("WARNING", logger="repro.serve.engine"):
            s1 = eng.spec_for(req, record=True)
            s2 = eng.spec_for(req, record=True)
        assert s1.method == "bakp" and s2.method == "bakp"  # no upgrade
        ctr = eng.registry.get("solver_fallback_total")
        assert ctr.value(reason="unshardable_fused") == 2
        warnings = [r for r in caplog.records
                    if "prefer_fused" in r.getMessage()]
        assert len(warnings) == 1  # one-time log
        eng.shutdown()

    def test_single_engine_still_upgrades(self, rng):
        eng = SolverServeEngine(ServeConfig(prefer_fused=True),
                                registry=obs.MetricsRegistry())
        x, y, _ = make_system(rng, 40, 8)
        spec = eng.spec_for(_req(x, y, method="bakp", thr=8, max_iter=4),
                            record=True)
        assert spec.method == "bakp_fused"
        assert eng.registry.get(
            "solver_fallback_total").value(reason="unshardable_fused") == 0
        eng.shutdown()
