"""repro.obs: metrics registry, exporters, tracing, telemetry wiring.

Covers the metric primitives (counter/gauge/histogram with log-spaced
buckets and label-subset merges), the Prometheus text-format grammar, the
span tracer + JSONL sink, the kill switch, the kernel-path relay, the
``SolveTelemetry`` record attached by the engine/dispatcher, the scrape
endpoint, and a concurrency hammer over the async dispatcher (registry
counts must agree with delivered results, and ``snapshot()`` must never
throw mid-update).
"""
import json
import math
import re
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from conftest import make_system
from repro import obs
from repro.obs.metrics import _env_disabled
from repro.serve import (AsyncDispatcher, DispatchConfig, ServeConfig,
                         SolveRequest, SolverServeEngine)


@pytest.fixture(autouse=True)
def _obs_enabled():
    """Every test starts (and leaves) with obs on, whatever it flips."""
    prev = obs.set_enabled(True)
    yield
    obs.set_enabled(prev)


def _req(x, y, **kw):
    kw.setdefault("method", "bakp")
    kw.setdefault("max_iter", 15)
    return SolveRequest(x=x, y=y, **kw)


# ----------------------------------------------------------------- buckets
class TestBuckets:
    def test_log_buckets_span_and_spacing(self):
        b = obs.log_buckets(1e-3, 1.0, per_decade=4)
        assert b[0] == pytest.approx(1e-3)
        assert b[-1] == pytest.approx(1.0)
        assert len(b) == 13  # 3 decades * 4 + endpoint
        ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
        assert all(r == pytest.approx(10 ** 0.25) for r in ratios)

    def test_log_buckets_validation(self):
        with pytest.raises(ValueError):
            obs.log_buckets(0.0, 1.0)
        with pytest.raises(ValueError):
            obs.log_buckets(2.0, 1.0)

    def test_default_buckets_cover_serving_range(self):
        assert obs.LATENCY_BUCKETS[0] <= 1e-4
        assert obs.LATENCY_BUCKETS[-1] >= 100.0
        assert obs.COUNT_BUCKETS[0] <= 1.0
        assert obs.COUNT_BUCKETS[-1] >= 1024.0


# ----------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_labels_and_subset_sum(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("reqs_total", "help text")
        c.inc(2, kind="a", path="x")
        c.inc(3, kind="b", path="x")
        c.inc(1, kind="a", path="y")
        assert c.value() == 6
        assert c.value(kind="a") == 3
        assert c.value(path="x") == 5
        assert c.value(kind="b", path="y") == 0

    def test_counter_rejects_decrease(self):
        c = obs.MetricsRegistry().counter("c")
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_inc_dec(self):
        g = obs.MetricsRegistry().gauge("depth")
        g.set(5)
        g.inc(2)
        g.dec()
        assert g.value() == 6.0
        g.set(1, queue="q")
        assert g.value(queue="q") == 1.0

    def test_histogram_percentile_and_merge(self):
        h = obs.MetricsRegistry().histogram(
            "lat", buckets=obs.log_buckets(1e-3, 10.0, per_decade=8))
        rng = np.random.default_rng(7)
        vals = np.exp(rng.normal(-2.0, 0.5, size=4000))
        for i, v in enumerate(vals):
            h.observe(float(v), path="a" if i % 2 else "b")
        assert h.count() == 4000
        assert h.count(path="a") == 2000
        assert h.sum() == pytest.approx(float(vals.sum()), rel=1e-6)
        # Bucket-interpolated percentiles within one bucket width (~33%).
        for q in (50, 95):
            est, true = h.percentile(q), float(np.percentile(vals, q))
            assert abs(est - true) / true < 0.35, (q, est, true)
        assert math.isnan(h.percentile(50, path="missing"))

    def test_histogram_overflow_bucket(self):
        h = obs.MetricsRegistry().histogram("o", buckets=(1.0, 10.0))
        h.observe(0.5)
        h.observe(1e9)
        assert h.count() == 2
        assert h.percentile(99) == 10.0  # rank lands in +Inf -> top bound

    def test_bound_children_share_series(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("c")
        h = reg.histogram("h", buckets=(1.0, 2.0))
        g = reg.gauge("g")
        c.labels(kind="a").inc(2)
        c.inc(1, kind="a")
        assert c.value(kind="a") == 3
        h.labels(kind="a").observe(1.5)
        assert h.count(kind="a") == 1
        g.labels(kind="a").set(4)
        assert g.value(kind="a") == 4.0

    def test_registry_get_or_create_and_kind_clash(self):
        reg = obs.MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")
        assert "x" in reg.names()
        assert reg.get("nope") is None

    def test_reset_keeps_held_references_live(self):
        # Components hold family references; reset must zero, not detach.
        reg = obs.MetricsRegistry()
        c = reg.counter("kept")
        c.inc(5)
        reg.reset()
        assert c.value() == 0
        c.inc(2)
        assert reg.get("kept").value() == 2

    def test_snapshot_shape(self):
        reg = obs.MetricsRegistry()
        reg.counter("c", "ch").inc(2, kind="a")
        reg.gauge("g").set(1.5)
        h = reg.histogram("h", buckets=(1.0, 2.0))
        h.observe(1.5)
        h.observe(99.0)
        snap = reg.snapshot()
        assert snap["c"] == {"type": "counter", "help": "ch",
                             "values": {"kind=a": 2.0}}
        assert snap["g"]["values"][""] == 1.5
        hv = snap["h"]["values"][""]
        assert hv["counts"] == [0, 1, 1]  # le=1, le=2, +Inf overflow
        assert hv["count"] == 2
        assert hv["sum"] == pytest.approx(100.5)
        json.dumps(snap)  # JSON-serialisable end to end


# -------------------------------------------------------------- prometheus
# Text exposition format 0.0.4: comment lines, then one sample per line.
_SAMPLE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\.)*"'
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\.)*")*\})?'
    r' (?:[-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?|\+Inf|-Inf|NaN)$')


class TestPrometheus:
    def _render(self):
        reg = obs.MetricsRegistry()
        reg.counter("solve_total", "solves by kind").inc(3, kind="multi_rhs")
        reg.counter("solve_total").inc(1, kind='we"ird\\label')
        reg.gauge("inflight").set(2)
        h = reg.histogram("lat_seconds", "latency",
                          buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.05, 5.0):
            h.observe(v, path="xla")
        return reg, reg.render_prometheus()

    def test_every_line_parses(self):
        _, text = self._render()
        assert text.endswith("\n")
        for line in text.strip().split("\n"):
            if line.startswith("# HELP ") or line.startswith("# TYPE "):
                continue
            assert _SAMPLE.match(line), f"bad exposition line: {line!r}"

    def test_histogram_cumulative_and_consistent(self):
        _, text = self._render()
        buckets = re.findall(r'lat_seconds_bucket\{path="xla",le="([^"]+)"\} '
                             r'(\d+)', text)
        assert [b[0] for b in buckets] == ["0.01", "0.1", "1", "+Inf"]
        counts = [int(b[1]) for b in buckets]
        assert counts == sorted(counts)  # cumulative
        assert counts == [1, 3, 3, 4]
        assert 'lat_seconds_count{path="xla"} 4' in text
        assert "# TYPE lat_seconds histogram" in text

    def test_type_lines_and_escaping(self):
        _, text = self._render()
        assert "# TYPE solve_total counter" in text
        assert "# TYPE inflight gauge" in text
        assert r'kind="we\"ird\\label"' in text


# ----------------------------------------------------------------- tracing
class TestTracing:
    def test_span_nesting_and_tags(self):
        tr = obs.Tracer(capacity=16)
        with tr.span("outer", bucket="64x8"):
            with tr.span("inner", step=1):
                pass
        spans = {s.name: s for s in tr.spans()}
        assert spans["inner"].parent == "outer"
        assert spans["inner"].depth == 1
        assert spans["outer"].parent is None
        assert spans["outer"].tags == {"bucket": "64x8"}
        assert spans["outer"].duration_s >= spans["inner"].duration_s >= 0

    def test_ring_buffer_bounded(self):
        tr = obs.Tracer(capacity=4)
        for i in range(10):
            with tr.span(f"s{i}"):
                pass
        assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]

    def test_jsonl_sink(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        tr = obs.Tracer(capacity=8, jsonl_path=str(path))
        with tr.span("solve", bucket=(64, 8)):
            pass
        tr.close()
        rows = [json.loads(line) for line in path.read_text().splitlines()]
        assert rows and rows[0]["name"] == "solve"
        assert rows[0]["tags"]["bucket"] == [64, 8]

    def test_dispatch_relay(self):
        obs.consume_dispatch()  # clear any leftover
        obs.record_dispatch("fused", method="bakp")
        assert obs.consume_dispatch("xla") == "fused"
        assert obs.consume_dispatch("xla") == "xla"  # one-shot

    def test_now_is_perf_counter_family(self):
        a = obs.now()
        b = obs.now()
        assert b >= a


# ------------------------------------------------------------- kill switch
class TestKillSwitch:
    def test_env_parsing(self):
        assert _env_disabled({"REPRO_OBS_DISABLED": "1"})
        assert _env_disabled({"REPRO_OBS_DISABLED": "True"})
        assert not _env_disabled({"REPRO_OBS_DISABLED": "0"})
        assert not _env_disabled({})

    def test_disabled_mutators_are_noops(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("c")
        h = reg.histogram("h")
        c.inc(5)
        obs.set_enabled(False)
        c.inc(100)
        c.labels().inc(100)
        h.observe(1.0)
        with obs.span("dead") as s:
            assert s is None
        obs.set_enabled(True)
        assert c.value() == 5
        assert h.count() == 0

    def test_disabled_engine_serves_without_telemetry(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        obs.set_enabled(False)
        out = eng.serve([_req(x, y)])
        assert out[0].ok
        assert out[0].telemetry is None
        assert reg.get("serve_requests_served_total").value() == 0


# ----------------------------------------------------- engine telemetry
class TestEngineTelemetry:
    def test_solve_telemetry_attached_and_kernel_path(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        out = eng.serve([_req(x, y, tenant_id="t0", request_id="r0")])
        tel = out[0].telemetry
        assert tel is not None
        assert tel.request_id == "r0" and tel.tenant_id == "t0"
        assert tel.method == "bakp" and tel.kernel_path == "xla"
        assert tel.batch_kind == out[0].batch_kind
        assert tel.bucket == out[0].bucket
        assert tel.n_sweeps == out[0].n_sweeps
        assert tel.solve_s == pytest.approx(out[0].latency_s)
        assert not tel.warm_start and tel.error_type is None
        d = tel.as_dict()
        assert d["kernel_path"] == "xla" and json.dumps(d)

    def test_fused_method_reports_fused_path(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        eng = SolverServeEngine(ServeConfig(), registry=obs.MetricsRegistry())
        out = eng.serve([_req(x, y, method="bakp_fused", thr=8)])
        assert out[0].ok
        assert out[0].telemetry.kernel_path == "fused"

    def test_registry_families_after_serve(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        x2, y2, _ = make_system(rng, 40, 8)
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        served = eng.serve([_req(x, y, design_key="d1"),
                            _req(x2, y2, design_key="d2")])
        assert all(s.ok for s in served)
        assert reg.get("serve_requests_total").value() == 2
        assert reg.get("serve_requests_served_total").value() == 2
        assert reg.get("serve_solve_latency_seconds").count() >= 1
        assert reg.get("serve_sweeps").count() == 2
        assert reg.get("serve_cache_misses_total").value() == 2
        assert reg.get("serve_cache_entries").value() == 2
        # Warm pass: same designs now hit.
        eng.serve([_req(x, y, design_key="d1")])
        assert reg.get("serve_cache_hits_total").value() == 1

    def test_warm_start_label(self, rng):
        x, y, _ = make_system(rng, 60, 8)
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        eng.serve([_req(x, y, design_key="d", tenant_id="t")])
        out = eng.serve([_req(x, y, design_key="d", tenant_id="t")])
        assert out[0].warm_start and out[0].telemetry.warm_start
        assert reg.get("serve_requests_served_total").value(warm="1") == 1
        assert reg.get("serve_sweeps").count(warm="1") == 1

    def test_error_telemetry_and_counter(self, rng):
        x, y, _ = make_system(rng, 40, 4)
        reg = obs.MetricsRegistry()
        # retry_ladder=False pins the error-path telemetry; with the
        # ladder on this request is recovered (test_resilience.py).
        eng = SolverServeEngine(ServeConfig(retry_ladder=False),
                                registry=reg)
        # thr=0 explodes inside solvebakp at trace time — the "poisoned
        # request" class that submit-time validation cannot catch.
        out = eng.serve([_req(x, y, thr=0, max_iter=5)])
        assert not out[0].ok
        tel = out[0].telemetry
        assert tel is not None
        assert tel.error_type and tel.kernel_path == "none"
        assert tel.batch_kind == "error"
        errs = reg.get("serve_errors_total")
        assert errs.value() == 1
        assert errs.value(exception_type=tel.error_type) == 1
        assert errs.value(method="bakp") == 1


# -------------------------------------------------- dispatcher telemetry
class TestDispatcherTelemetry:
    def test_queue_wait_and_deadline_margin_backfilled(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        with AsyncDispatcher(eng, DispatchConfig(idle_timeout_s=0.005)) as d:
            t = d.submit(_req(x, y), deadline_s=30.0)
            res = t.result(timeout=30.0)
        assert res.ok
        tel = res.telemetry
        assert tel is t.telemetry
        assert tel.queue_wait_s is not None and tel.queue_wait_s >= 0
        assert tel.queue_wait_s == pytest.approx(t.queue_wait_s)
        assert tel.deadline_margin_s is not None
        assert tel.deadline_margin_s == pytest.approx(
            t.deadline - t.completed_at)
        assert tel.deadline_margin_s > 0  # 30s deadline was met
        assert reg.get("serve_dispatch_submitted_total").value() == 1
        assert reg.get("serve_dispatch_completed_total").value() == 1
        assert reg.get("serve_queue_wait_seconds").count() == 1
        assert reg.get("serve_request_latency_seconds").count() == 1
        assert reg.get("serve_dispatch_fired_total").value() == 1
        assert reg.get("serve_dispatch_inflight").value() == 0

    def test_ticket_clock_is_obs_now(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        before = obs.now()
        eng = SolverServeEngine(ServeConfig(),
                                registry=obs.MetricsRegistry())
        with AsyncDispatcher(eng, DispatchConfig()) as d:
            t = d.submit(_req(x, y))
            t.result(timeout=30.0)
        after = obs.now()
        # Same epoch as obs.now(): composes with engine/queue timings.
        assert before <= t.submitted_at <= t.fired_at <= t.completed_at
        assert t.completed_at <= after


# ------------------------------------------------------------ concurrency
class TestHammer:
    def test_hammer_counts_consistent_and_snapshot_safe(self, rng):
        x, y, _ = make_system(rng, 40, 8)
        x2, y2, _ = make_system(rng, 40, 8)
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        n_threads, per_thread = 6, 12
        results = [[] for _ in range(n_threads)]
        errors = []
        stop = threading.Event()

        def snapshotter():
            # snapshot()/render_prometheus() must never throw mid-update.
            while not stop.is_set():
                try:
                    json.dumps(reg.snapshot())
                    reg.render_prometheus()
                except Exception as exc:  # pragma: no cover
                    errors.append(exc)

        cfg = DispatchConfig(max_queue=512, idle_timeout_s=0.005,
                             max_batch=8)
        with AsyncDispatcher(eng, cfg) as disp:
            def worker(slot):
                try:
                    tickets = [
                        disp.submit(_req(
                            x if i % 2 else x2, y if i % 2 else y2,
                            design_key="da" if i % 2 else "db",
                            tenant_id=f"w{slot}"))
                        for i in range(per_thread)]
                    results[slot] = [t.result(timeout=60.0) for t in tickets]
                except Exception as exc:  # pragma: no cover
                    errors.append(exc)

            snap_t = threading.Thread(target=snapshotter, daemon=True)
            snap_t.start()
            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=120.0)
            stop.set()
            snap_t.join(timeout=10.0)

        assert not errors, errors
        delivered = [r for slot in results for r in slot]
        total = n_threads * per_thread
        assert len(delivered) == total
        assert all(r.ok for r in delivered)
        assert all(r.telemetry is not None for r in delivered)
        # Registry totals agree with what callers actually received.
        assert reg.get("serve_dispatch_submitted_total").value() == total
        assert reg.get("serve_dispatch_completed_total").value() == total
        assert reg.get("serve_requests_served_total").value() == total
        assert reg.get("serve_request_latency_seconds").count() == total
        assert reg.get("serve_queue_wait_seconds").count() == total
        assert reg.get("serve_sweeps").count() == total
        fired = reg.get("serve_dispatch_fired_total").value()
        assert 1 <= fired <= total
        assert reg.get("serve_dispatch_inflight").value() == 0


# ------------------------------------------------------------- exporters
class TestExporters:
    def test_write_metrics_json(self, tmp_path):
        reg = obs.MetricsRegistry()
        reg.counter("c").inc(3)
        path = tmp_path / "m.json"
        doc = obs.write_metrics_json(str(path), registry=reg,
                                     extra={"run": "test"})
        on_disk = json.loads(path.read_text())
        assert on_disk == doc
        assert on_disk["metrics"]["c"]["values"][""] == 3.0
        assert on_disk["meta"]["run"] == "test"

    def test_http_endpoint(self):
        reg = obs.MetricsRegistry()
        reg.counter("hits_total", "hits").inc(7, route="a")
        with obs.start_metrics_server(0, registry=reg,
                                      host="127.0.0.1") as srv:
            base = f"http://127.0.0.1:{srv.port}"
            text = urllib.request.urlopen(f"{base}/metrics").read().decode()
            assert 'hits_total{route="a"} 7' in text
            snap = json.loads(
                urllib.request.urlopen(f"{base}/metrics.json").read())
            assert snap["hits_total"]["values"]["route=a"] == 7.0
            assert urllib.request.urlopen(
                f"{base}/healthz").read() == b"ok\n"
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(f"{base}/nope")
