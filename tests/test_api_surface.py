"""Public-API snapshot: guards accidental surface breakage.

These are exact-equality assertions on the exported names, the
``SolverSpec`` field set (name, order-independent) and the method
registry's capability flags.  Changing the public API is fine — but it must
be a *decision*: update the snapshot here together with the README
migration table, never as a side effect of a refactor.
"""
import dataclasses

import repro.core as core
import repro.serve as serve
from repro.core import SolverSpec, method_names, solver_method

CORE_EXPORTS = {
    "MethodEntry",
    "PRECISIONS",
    "PreparedDesign",
    "SelectResult",
    "SolveResult",
    "SolverSpec",
    "UnsupportedSpecError",
    "block_gram_cholesky",
    "design_fingerprint",
    "fit_linear_probe",
    "method_names",
    "methods_for_precision",
    "normalize_columns",
    "prepare",
    "register_method",
    "solve",
    "solvebak",
    "solvebak_onesweep",
    "solvebakf",
    "solvebakp",
    "solvebakp_2d",
    "solvebakp_obs_sharded",
    "solvebakp_rhs_sharded",
    "solvebakp_vars_sharded",
    "solver_method",
    "stepwise_regression_baseline",
    "unscale_coef",
}

SERVE_EXPORTS = {
    "AsyncDispatcher",
    "CacheStats",
    "DesignCache",
    "DesignEntry",
    "DesignStore",
    "DispatchConfig",
    "DispatchStats",
    "DispatcherStopped",
    "LaneExecutor",
    "LaneKey",
    "LanePool",
    "LaneShutdown",
    "LaneStats",
    "LaneWork",
    "LaneWorkerDeath",
    "Placement",
    "PlacementPolicy",
    "PreparedDesign",
    "QueueFullError",
    "ServeConfig",
    "ServeMesh",
    "ServeStats",
    "ServedSolve",
    "SolveRequest",
    "SolveTelemetry",
    "SolveTicket",
    "TicketCancelled",
    "SolverServeEngine",
    "SolverSpec",
    "StoreStats",
    "UnsupportedSpecError",
    "build_serve_mesh",
    "mesh_device_count",
    "placement_for_bucket",
    "placement_for_group",
    "bucket_shape",
    "current_lane",
    "design_fingerprint",
    "group_requests",
    "lane_for",
    "next_pow2",
    "pad_x",
    "pad_y",
    "prepare_request",
}

SOLVER_SPEC_FIELDS = {
    "method": "bakp_gram",
    "max_iter": 50,
    "atol": 0.0,
    "rtol": 0.0,
    "thr": 128,
    "omega": 1.0,
    "order": "cyclic",
    "ridge": 1e-6,
    "precision": "fp32",
    "refine_sweeps": 4,
}

_ALL_PRECISIONS = ("fp32", "bf16", "bf16_fp32acc")

# method -> (iterative, multi_rhs, batchable, shardable, precisions)
METHOD_CAPABILITIES = {
    "bak": (True, True, True, False, ("fp32",)),
    "bakp": (True, True, True, True, ("fp32",)),
    "bakp_gram": (True, True, True, True, ("fp32",)),
    # The fused megakernel methods are single-device whole-solve launches:
    # neither vmap-batchable (a batched pallas whole-solve would multiply
    # the VMEM residency) nor mesh-shardable (route big buckets to "bakp").
    # They are the only methods streaming the bf16 X cache tier (fp32
    # accumulators; "bf16_fp32acc" adds the fp32 polish sweeps).
    "bakp_fused": (True, True, False, False, _ALL_PRECISIONS),
    "bak_fused": (True, True, False, False, _ALL_PRECISIONS),
    # Out-of-core streaming solve: single-device by design (the point is
    # the design does NOT fit on one device), x tiles double-buffered
    # from HBM or fetched through the store's host/disk tiers.
    "bakp_stream": (True, True, False, False, ("fp32", "bf16")),
    "lstsq": (False, True, False, False, ("fp32",)),
    "normal": (False, True, False, False, ("fp32",)),
    "bakf": (False, False, False, False, ("fp32",)),
}


def test_core_exports():
    assert set(core.__all__) == CORE_EXPORTS
    for name in CORE_EXPORTS:
        assert hasattr(core, name), f"repro.core.{name} missing"


def test_serve_exports():
    assert set(serve.__all__) == SERVE_EXPORTS
    for name in SERVE_EXPORTS:
        assert hasattr(serve, name), f"repro.serve.{name} missing"


def test_solver_spec_fields():
    fields = {f.name: f.default for f in dataclasses.fields(SolverSpec)}
    assert fields == SOLVER_SPEC_FIELDS
    # Frozen + hashable: specs key program caches and serving groups.
    spec = SolverSpec()
    assert hash(spec) == hash(SolverSpec())
    try:
        spec.method = "bak"
        raise AssertionError("SolverSpec must be frozen")
    except dataclasses.FrozenInstanceError:
        pass


def test_method_registry_snapshot():
    assert set(method_names()) == set(METHOD_CAPABILITIES)
    for name, (it, mrhs, batch, shard, precs) in METHOD_CAPABILITIES.items():
        e = solver_method(name)
        assert (e.iterative, e.multi_rhs, e.batchable, e.shardable,
                e.precisions) == (it, mrhs, batch, shard, precs), name
        # Every method consumes a subset of real SolverSpec fields.
        field_names = {f.name for f in dataclasses.fields(SolverSpec)}
        assert set(e.consumes) <= field_names, name


def test_canonical_precision_key_compat():
    """precision="fp32" specs hash/compare identically to pre-precision
    specs, so serving config_keys, warm-coef LRU keys and compiled-program
    caches never cold-start on upgrade."""
    legacy_like = SolverSpec(method="bakp", max_iter=30, rtol=1e-8)
    explicit = SolverSpec(method="bakp", max_iter=30, rtol=1e-8,
                          precision="fp32", refine_sweeps=9)
    assert legacy_like.canonical() == explicit.canonical()
    assert hash(legacy_like.canonical()) == hash(explicit.canonical())
    # refine_sweeps only differentiates under bf16_fp32acc.
    a = SolverSpec(method="bakp_fused", precision="bf16_fp32acc",
                   refine_sweeps=2)
    b = SolverSpec(method="bakp_fused", precision="bf16_fp32acc",
                   refine_sweeps=8)
    assert a.canonical() != b.canonical()
    c = SolverSpec(method="bakp_fused", precision="bf16", refine_sweeps=2)
    d = SolverSpec(method="bakp_fused", precision="bf16", refine_sweeps=8)
    assert c.canonical() == d.canonical()


def test_design_entry_is_prepared_design():
    """The serving cache's per-design state IS the public handle."""
    assert serve.DesignEntry is core.PreparedDesign


def test_solve_request_spec_roundtrip():
    """Legacy-kwargs requests and spec requests express the same config."""
    req = serve.SolveRequest(x=None, y=None, method="bakp", max_iter=7,
                             atol=0.5, rtol=1e-3, thr=4)
    spec = req.solver_spec()
    assert spec == SolverSpec(method="bakp", max_iter=7, atol=0.5,
                              rtol=1e-3, thr=4)
    explicit = serve.SolveRequest(x=None, y=None, spec=spec)
    assert explicit.solver_spec() is spec
