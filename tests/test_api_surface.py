"""Public-API snapshot: guards accidental surface breakage.

These are exact-equality assertions on the exported names, the
``SolverSpec`` field set (name, order-independent) and the method
registry's capability flags.  Changing the public API is fine — but it must
be a *decision*: update the snapshot here together with the README
migration table, never as a side effect of a refactor.
"""
import dataclasses

import repro.core as core
import repro.serve as serve
from repro.core import SolverSpec, method_names, solver_method

CORE_EXPORTS = {
    "MethodEntry",
    "PreparedDesign",
    "SelectResult",
    "SolveResult",
    "SolverSpec",
    "block_gram_cholesky",
    "design_fingerprint",
    "fit_linear_probe",
    "method_names",
    "normalize_columns",
    "prepare",
    "register_method",
    "solve",
    "solvebak",
    "solvebak_onesweep",
    "solvebakf",
    "solvebakp",
    "solvebakp_2d",
    "solvebakp_obs_sharded",
    "solvebakp_rhs_sharded",
    "solvebakp_vars_sharded",
    "solver_method",
    "stepwise_regression_baseline",
    "unscale_coef",
}

SERVE_EXPORTS = {
    "AsyncDispatcher",
    "CacheStats",
    "DesignCache",
    "DesignEntry",
    "DispatchConfig",
    "DispatchStats",
    "DispatcherStopped",
    "Placement",
    "PlacementPolicy",
    "PreparedDesign",
    "QueueFullError",
    "ServeConfig",
    "ServeMesh",
    "ServeStats",
    "ServedSolve",
    "SolveRequest",
    "SolveTelemetry",
    "SolveTicket",
    "SolverServeEngine",
    "SolverSpec",
    "build_serve_mesh",
    "mesh_device_count",
    "placement_for_bucket",
    "placement_for_group",
    "bucket_shape",
    "design_fingerprint",
    "group_requests",
    "next_pow2",
    "pad_x",
    "pad_y",
    "prepare_request",
}

SOLVER_SPEC_FIELDS = {
    "method": "bakp_gram",
    "max_iter": 50,
    "atol": 0.0,
    "rtol": 0.0,
    "thr": 128,
    "omega": 1.0,
    "order": "cyclic",
    "ridge": 1e-6,
}

# method -> (iterative, multi_rhs, batchable, shardable)
METHOD_CAPABILITIES = {
    "bak": (True, True, True, False),
    "bakp": (True, True, True, True),
    "bakp_gram": (True, True, True, True),
    # The fused megakernel methods are single-device whole-solve launches:
    # neither vmap-batchable (a batched pallas whole-solve would multiply
    # the VMEM residency) nor mesh-shardable (route big buckets to "bakp").
    "bakp_fused": (True, True, False, False),
    "bak_fused": (True, True, False, False),
    "lstsq": (False, True, False, False),
    "normal": (False, True, False, False),
    "bakf": (False, False, False, False),
}


def test_core_exports():
    assert set(core.__all__) == CORE_EXPORTS
    for name in CORE_EXPORTS:
        assert hasattr(core, name), f"repro.core.{name} missing"


def test_serve_exports():
    assert set(serve.__all__) == SERVE_EXPORTS
    for name in SERVE_EXPORTS:
        assert hasattr(serve, name), f"repro.serve.{name} missing"


def test_solver_spec_fields():
    fields = {f.name: f.default for f in dataclasses.fields(SolverSpec)}
    assert fields == SOLVER_SPEC_FIELDS
    # Frozen + hashable: specs key program caches and serving groups.
    spec = SolverSpec()
    assert hash(spec) == hash(SolverSpec())
    try:
        spec.method = "bak"
        raise AssertionError("SolverSpec must be frozen")
    except dataclasses.FrozenInstanceError:
        pass


def test_method_registry_snapshot():
    assert set(method_names()) == set(METHOD_CAPABILITIES)
    for name, (it, mrhs, batch, shard) in METHOD_CAPABILITIES.items():
        e = solver_method(name)
        assert (e.iterative, e.multi_rhs, e.batchable, e.shardable) == \
            (it, mrhs, batch, shard), name
        # Every method consumes a subset of real SolverSpec fields.
        field_names = {f.name for f in dataclasses.fields(SolverSpec)}
        assert set(e.consumes) <= field_names, name


def test_design_entry_is_prepared_design():
    """The serving cache's per-design state IS the public handle."""
    assert serve.DesignEntry is core.PreparedDesign


def test_solve_request_spec_roundtrip():
    """Legacy-kwargs requests and spec requests express the same config."""
    req = serve.SolveRequest(x=None, y=None, method="bakp", max_iter=7,
                             atol=0.5, rtol=1e-3, thr=4)
    spec = req.solver_spec()
    assert spec == SolverSpec(method="bakp", max_iter=7, atol=0.5,
                              rtol=1e-3, thr=4)
    explicit = serve.SolveRequest(x=None, y=None, spec=spec)
    assert explicit.solver_spec() is spec
