"""repro.serve: bucketing, padding, caching, coalescing + multi-RHS solves."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_system
from repro.core import solve, solvebak, solvebakp
from repro.serve import (ServeConfig, ServedSolve, SolveRequest,
                         SolverServeEngine, bucket_shape, design_fingerprint,
                         group_requests, next_pow2)


def _lstsq(x, y):
    return np.linalg.lstsq(np.asarray(x, np.float64),
                           np.asarray(y, np.float64), rcond=None)[0]


# --------------------------------------------------------------- multi-RHS
class TestMultiRhsSolvers:
    """Multi-RHS core solves vs a column-by-column fp32 oracle."""

    @pytest.mark.parametrize("solver_kw", [
        dict(fn="bak"),
        dict(fn="bakp", mode="jacobi", thr=16),
        dict(fn="bakp", mode="gram", thr=16),
    ])
    def test_matches_column_by_column(self, rng, solver_kw):
        obs, nvars, k = 400, 32, 6
        x = rng.normal(size=(obs, nvars)).astype(np.float32)
        a_true = rng.normal(size=(nvars, k)).astype(np.float32)
        ys = x @ a_true
        if solver_kw["fn"] == "bak":
            multi = solvebak(jnp.array(x), jnp.array(ys), max_iter=60)
            cols = [solvebak(jnp.array(x), jnp.array(ys[:, i]), max_iter=60)
                    for i in range(k)]
        else:
            kw = dict(thr=solver_kw["thr"], mode=solver_kw["mode"],
                      max_iter=60)
            multi = solvebakp(jnp.array(x), jnp.array(ys), **kw)
            cols = [solvebakp(jnp.array(x), jnp.array(ys[:, i]), **kw)
                    for i in range(k)]
        assert multi.coef.shape == (nvars, k)
        assert multi.residual.shape == (obs, k)
        for i, c in enumerate(cols):
            # Multi-RHS sweeps are the single-RHS sweeps run side by side;
            # only the (shared) stopping decision may differ.  With a fixed
            # sweep budget the iterates are identical.
            np.testing.assert_allclose(np.array(multi.coef[:, i]),
                                       np.array(c.coef), rtol=1e-5,
                                       atol=1e-5)
        np.testing.assert_allclose(np.array(multi.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    def test_multi_rhs_via_solve_api(self, rng):
        x, _, _ = make_system(rng, 300, 20)
        a_true = rng.normal(size=(20, 3)).astype(np.float32)
        ys = x @ a_true
        for method in ("bak", "bakp", "bakp_gram", "lstsq", "normal"):
            res = solve(jnp.array(x), jnp.array(ys), method=method,
                        max_iter=60, thr=8)
            assert res.coef.shape == (20, 3), method
            np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                       atol=1e-3, err_msg=method)

    def test_multi_rhs_kernels_vs_ref(self, rng):
        from repro.core.types import column_norms_sq, safe_inv
        from repro.kernels import bakp_sweep, block_update, cd_sweep
        from repro.kernels.ref import (ref_bakp_sweep, ref_block_update,
                                       ref_cd_sweep)
        obs, nvars, k, blk = 128, 16, 4, 8
        x = rng.normal(size=(obs, nvars)).astype(np.float32)
        e = rng.normal(size=(k, obs)).astype(np.float32)
        x_t = jnp.array(x.T)
        inv_cn = safe_inv(column_norms_sq(jnp.array(x)))
        for kern, ref, kw in ((cd_sweep, ref_cd_sweep, {}),
                              (bakp_sweep, ref_bakp_sweep,
                               dict(block=blk))):
            da_k, e_k = kern(x_t, jnp.array(e), inv_cn, block=blk)
            da_r, e_r = ref(x_t, jnp.array(e), inv_cn, **kw)
            np.testing.assert_allclose(np.array(da_k), np.array(da_r),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.array(e_k), np.array(e_r),
                                       rtol=1e-5, atol=1e-5)
        da = rng.normal(size=(blk, k)).astype(np.float32)
        out = block_update(x_t[:blk], jnp.array(e), jnp.array(da), obs_tile=64)
        np.testing.assert_allclose(
            np.array(out),
            np.array(ref_block_update(x_t[:blk], jnp.array(e), jnp.array(da))),
            rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- dispatch errors
class TestDispatchErrors:
    def test_unknown_method_raises(self, rng):
        x, y, _ = make_system(rng, 50, 4)
        with pytest.raises(ValueError, match="method must be one of"):
            solve(jnp.array(x), jnp.array(y), method="cholesky_qr")

    def test_random_order_requires_key(self, rng):
        x, y, _ = make_system(rng, 50, 4)
        with pytest.raises(ValueError, match="requires a PRNG key"):
            solvebak(jnp.array(x), jnp.array(y), order="random")

    def test_engine_rejects_unknown_method(self, rng):
        x, y, _ = make_system(rng, 50, 4)
        with pytest.raises(ValueError, match="method must be one of"):
            SolverServeEngine().submit(SolveRequest(x=x, y=y, method="qr"))

    def test_engine_rejects_bad_shapes(self, rng):
        x, y, _ = make_system(rng, 50, 4)
        eng = SolverServeEngine()
        with pytest.raises(ValueError, match="x must be 2D"):
            eng.submit(SolveRequest(x=y, y=y))
        with pytest.raises(ValueError, match="y must be"):
            eng.submit(SolveRequest(x=x, y=y[:-1]))


# ----------------------------------------------------------------- batching
class TestBucketing:
    def test_pow2(self):
        assert next_pow2(1) == 1
        assert next_pow2(5) == 8
        assert next_pow2(8) == 8
        assert next_pow2(9) == 16
        assert next_pow2(3, floor=8) == 8
        assert bucket_shape(300, 24) == (512, 32)
        assert bucket_shape(4, 4) == (8, 8)

    def test_fingerprint_content_keyed(self, rng):
        x = rng.normal(size=(20, 4)).astype(np.float32)
        assert design_fingerprint(x) == design_fingerprint(x.copy())
        x2 = x.copy()
        x2[3, 2] += 1.0
        assert design_fingerprint(x) != design_fingerprint(x2)
        # same bytes, different shape must differ
        assert design_fingerprint(x) != design_fingerprint(x.reshape(4, 20))

    def test_grouping_deterministic(self, rng):
        xs = [rng.normal(size=(30, 6)).astype(np.float32) for _ in range(3)]
        reqs = [SolveRequest(x=xs[i % 3], y=xs[i % 3][:, 0])
                for i in range(9)]
        g1 = group_requests(reqs)
        g2 = group_requests(reqs)
        assert list(g1) == list(g2)
        (outer, designs), = g1.items()
        assert outer[0] == (32, 8)
        assert [idx for lst in designs.values() for idx in lst] == \
            [0, 3, 6, 1, 4, 7, 2, 5, 8]


# ------------------------------------------------------------------- engine
class TestEngine:
    def test_padding_strip_correctness(self, rng):
        """Non-pow2 shapes through every batch path match unpadded lstsq."""
        eng = SolverServeEngine()
        reqs = []
        x_shared = rng.normal(size=(300, 24)).astype(np.float32)
        for i in range(3):  # same-design -> multi_rhs
            a = rng.normal(size=(24,)).astype(np.float32)
            reqs.append(SolveRequest(x=x_shared, y=x_shared @ a, thr=16,
                                     max_iter=60, rtol=1e-12))
        for i in range(2):  # unique designs, same bucket -> vmap
            x = rng.normal(size=(290, 20)).astype(np.float32)
            a = rng.normal(size=(20,)).astype(np.float32)
            reqs.append(SolveRequest(x=x, y=x @ a, thr=16, max_iter=60,
                                     rtol=1e-12))
        x = rng.normal(size=(100, 5)).astype(np.float32)  # own bucket
        reqs.append(SolveRequest(x=x, y=x @ np.ones(5, np.float32), thr=16,
                                 max_iter=60, rtol=1e-12))
        results = eng.serve(reqs)
        assert [r.batch_kind for r in results] == \
            ["multi_rhs"] * 3 + ["vmap"] * 2 + ["single"]
        for req, res in zip(reqs, results):
            assert isinstance(res, ServedSolve)
            assert res.coef.shape == (req.x.shape[1],)
            assert res.residual.shape == (req.x.shape[0],)
            np.testing.assert_allclose(res.coef, _lstsq(req.x, req.y),
                                       rtol=1e-3, atol=1e-3)
            assert res.sse == pytest.approx(
                float(np.sum(res.residual ** 2)), rel=1e-5, abs=1e-8)

    def test_results_in_submission_order(self, rng):
        eng = SolverServeEngine()
        reqs = []
        for i in range(6):
            x = rng.normal(size=(40 + i, 4)).astype(np.float32)
            reqs.append(SolveRequest(x=x, y=x @ np.ones(4, np.float32),
                                     request_id=f"tag-{i}", thr=4,
                                     max_iter=40, rtol=1e-12))
        out = eng.serve(reqs)
        assert [r.request_id for r in out] == [f"tag-{i}" for i in range(6)]

    def test_cache_hits_for_repeated_design(self, rng):
        eng = SolverServeEngine()
        x = rng.normal(size=(200, 16)).astype(np.float32)

        def mk():
            a = rng.normal(size=(16,)).astype(np.float32)
            return SolveRequest(x=x, y=x @ a, thr=8, max_iter=40, rtol=1e-12)

        first = eng.serve([mk()])
        assert not first[0].cache_hit
        assert eng.cache.stats.hits == 0
        second = eng.serve([mk(), mk()])
        assert all(r.cache_hit for r in second)
        assert eng.cache.stats.hits == 1  # one lookup per design group
        assert len(eng.cache) == 1

    def test_cache_lru_eviction(self, rng):
        from repro.serve import ServeConfig
        eng = SolverServeEngine(ServeConfig(cache_entries=2))
        for i in range(4):
            x = rng.normal(size=(50, 4)).astype(np.float32)
            eng.serve([SolveRequest(x=x, y=x[:, 0], thr=4, max_iter=20)])
        assert len(eng.cache) == 2
        assert eng.cache.stats.evictions == 2

    def test_coalescing_off_falls_back(self, rng):
        eng = SolverServeEngine(ServeConfig(coalesce=False, vmap_batch=False))
        x = rng.normal(size=(64, 8)).astype(np.float32)
        out = eng.serve([SolveRequest(x=x, y=x[:, 0], thr=8, max_iter=30,
                                      rtol=1e-12) for _ in range(3)])
        assert all(r.batch_kind == "single" for r in out)
        np.testing.assert_allclose(out[0].coef, _lstsq(x, x[:, 0]),
                                   rtol=1e-3, atol=1e-3)

    def test_atol_corrected_for_padding(self, rng):
        """atol through the engine must match the unpadded criterion.

        obs=300 pads to 512; an uncorrected atol would inflate the SSE
        threshold by 512/300 and stop early.  The engine's solve must take
        exactly as many sweeps as the direct unpadded solve.
        """
        x, y, _ = make_system(rng, 300, 24, noise=0.3)
        atol = 0.35
        direct = solvebak(jnp.array(x), jnp.array(y), max_iter=50, atol=atol)
        eng = SolverServeEngine()
        served, = eng.serve([SolveRequest(x=x, y=y, method="bak",
                                          max_iter=50, atol=atol)])
        assert served.n_sweeps == int(direct.n_sweeps)
        assert served.converged == bool(direct.converged)
        # sanity: the tolerance actually fires mid-run, so the test bites
        assert 1 <= int(direct.n_sweeps) < 50

    def test_direct_methods_served_singly(self, rng):
        eng = SolverServeEngine()
        x = rng.normal(size=(60, 6)).astype(np.float32)
        a = rng.normal(size=(6,)).astype(np.float32)
        out = eng.serve([SolveRequest(x=x, y=x @ a, method="lstsq")
                         for _ in range(2)])
        # lstsq isn't batchable -> per-request solves, still cache-backed.
        assert all(r.batch_kind in ("single", "multi_rhs") for r in out)
        for r in out:
            np.testing.assert_allclose(r.coef, a, rtol=1e-3, atol=1e-3)
