"""Data pipeline, optimizers, checkpointing, compression, FT monitors."""
import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.distributed.compression import (compress, compressed_tree,
                                           decompress, decompressed_tree,
                                           init_error_tree)
from repro.distributed.fault_tolerance import (CheckpointManager,
                                               StragglerMonitor)
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update)
from repro.optim.schedule import clip_by_global_norm, cosine_schedule


class TestData:
    def test_deterministic_and_resumable(self):
        d1 = SyntheticLM(128, 16, 4)
        batches1 = [d1.next_batch() for _ in range(5)]
        d2 = SyntheticLM(128, 16, 4)
        d2.skip_to(3)
        b = d2.next_batch()
        np.testing.assert_array_equal(b["tokens"], batches1[3]["tokens"])

    def test_host_sharding_disjoint(self):
        SyntheticLM(128, 16, 8, host_count=1, host_id=0)
        h0 = SyntheticLM(128, 16, 8, host_count=2, host_id=0)
        h1 = SyntheticLM(128, 16, 8, host_count=2, host_id=1)
        assert h0.next_batch()["tokens"].shape == (4, 16)
        assert h1.next_batch()["tokens"].shape == (4, 16)

    def test_learnable_structure(self):
        b = SyntheticLM(128, 32, 4, noise=0.0).next_batch()
        # next token = current + 1 mod base
        t, l = b["tokens"], b["labels"]
        assert np.mean((t + 1) % 97 == l) > 0.95


class TestOptim:
    def _quadratic(self, opt_init, opt_update):
        params = {"w": jnp.array([5.0, -3.0], jnp.float32)}
        state = opt_init(params)
        for i in range(300):
            grads = {"w": 2 * params["w"]}
            params, state = opt_update(grads, state, params, lr=0.05,
                                       weight_decay=0.0)
        return float(jnp.abs(params["w"]).max())

    def test_adamw_converges(self):
        assert self._quadratic(adamw_init, adamw_update) < 1e-2

    def test_adafactor_converges(self):
        assert self._quadratic(adafactor_init, adafactor_update) < 1e-1

    def test_adafactor_memory_factored(self):
        p = {"w": jnp.zeros((64, 32))}
        st = adafactor_init(p)
        assert st["stats"]["w"]["vr"].shape == (64,)
        assert st["stats"]["w"]["vc"].shape == (32,)

    def test_bf16_master_roundtrip(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        st = adamw_init(params)
        g = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
        params2, st2 = adamw_update(g, st, params, lr=1e-2)
        assert params2["w"].dtype == jnp.bfloat16
        assert st2["master"]["w"].dtype == jnp.float32

    def test_schedule_and_clip(self):
        lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0,
                                     warmup_steps=10, total_steps=100))
               for s in [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
        assert lrs[3] < lrs[2] and lrs[4] >= 0.1 - 1e-6
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


class TestCheckpoint:
    def test_roundtrip_and_keep_k(self, tmp_path):
        tree = {"layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                          "b": jnp.ones((3,), jnp.bfloat16)},
                "count": jnp.int32(7)}
        d = str(tmp_path)
        for step in (10, 20, 30, 40):
            save_checkpoint(d, step, tree, extras={"data_step": step},
                            keep=2)
        names = sorted(os.listdir(d))
        assert names == ["step_00000030", "step_00000040"]
        restored, extras, step = restore_checkpoint(d, tree)
        assert step == 40 and extras["data_step"] == 40
        np.testing.assert_array_equal(np.array(restored["layer"]["w"]),
                                      np.array(tree["layer"]["w"]))
        assert restored["layer"]["b"].dtype == jnp.bfloat16

    def test_manager_preemption_flag(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval_steps=100)
        assert not mgr.should_save(5)
        mgr._preempted = True
        assert mgr.should_save(5)


class TestCompression:
    def test_int8_error_feedback_unbiased(self):
        rng = np.random.default_rng(0)
        g = jnp.array(rng.normal(size=(256,)).astype(np.float32))
        err = jnp.zeros_like(g)
        # accumulate over steps: with error feedback the cumulative
        # dequantised sum tracks the cumulative true sum
        total_true = jnp.zeros_like(g)
        total_deq = jnp.zeros_like(g)
        for i in range(50):
            gi = g * (1 + 0.1 * i)
            q, scale, err = compress(gi, err)
            total_true += gi
            total_deq += decompress(q, scale)
        rel = float(jnp.linalg.norm(total_true - total_deq) /
                    jnp.linalg.norm(total_true))
        assert rel < 0.01

    def test_tree_roundtrip(self):
        g = {"a": jnp.ones((8,)), "b": {"c": jnp.full((4,), -2.0)}}
        err = init_error_tree(g)
        q, s, err2 = compressed_tree(g, err)
        deq = decompressed_tree(q, s)
        np.testing.assert_allclose(np.array(deq["b"]["c"]),
                                   np.array(g["b"]["c"]), rtol=0.02)


class TestStraggler:
    def test_flags_outlier(self):
        import time
        mon = StragglerMonitor(window=32, k=3.0)
        for i in range(12):
            mon.step_start()
            time.sleep(0.002)
            mon.step_end()
        mon.step_start()
        time.sleep(0.1)
        assert mon.step_end() is True
        assert mon.flagged >= 1
