"""Algorithm 2 (SolveBakP) — block CD, gram mode, property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional (requirements-dev.txt): only the property test
# skips without it; the deterministic solver tests always run.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from conftest import make_system
from repro.core import solvebakp
from repro.core.solvebakp import block_gram_cholesky


class TestSolveBakP:
    @pytest.mark.parametrize("thr", [1, 4, 16, 64])
    def test_thr_sweep(self, rng, thr):
        x, y, a_true = make_system(rng, 600, 48)
        res = solvebakp(jnp.array(x), jnp.array(y), thr=thr, max_iter=80,
                        mode="jacobi")
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    @pytest.mark.parametrize("thr", [4, 16, 48])
    def test_gram_mode(self, rng, thr):
        x, y, a_true = make_system(rng, 600, 48)
        res = solvebakp(jnp.array(x), jnp.array(y), thr=thr, max_iter=40,
                        mode="gram")
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    def test_gram_beats_jacobi_on_correlated(self, rng):
        """Beyond-paper claim: exact block CD converges faster on systems
        with correlated columns inside a block."""
        base = rng.normal(size=(500, 8)).astype(np.float32)
        # 32 columns, groups of 4 strongly correlated
        x = np.concatenate(
            [base[:, i // 4: i // 4 + 1] + 0.1 * rng.normal(
                size=(500, 1)).astype(np.float32) for i in range(32)], axis=1)
        a = rng.normal(size=(32,)).astype(np.float32)
        y = x @ a
        rj = solvebakp(jnp.array(x), jnp.array(y), thr=8, max_iter=20,
                       mode="jacobi", omega=0.5)
        rg = solvebakp(jnp.array(x), jnp.array(y), thr=8, max_iter=20,
                       mode="gram")
        assert float(rg.sse) < float(rj.sse)

    def test_non_divisible_vars_padding(self, rng):
        x, y, a_true = make_system(rng, 300, 37)  # 37 % 16 != 0
        res = solvebakp(jnp.array(x), jnp.array(y), thr=16, max_iter=60,
                        mode="gram")
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    def test_block_gram_cholesky_shapes(self, rng):
        x = rng.normal(size=(100, 32)).astype(np.float32)
        xb = jnp.array(x).reshape(100, 4, 8)
        chol = block_gram_cholesky(xb, ridge=1e-6)
        assert chol.shape == (4, 8, 8)
        g = np.einsum("obt,obs->bts", x.reshape(100, 4, 8),
                      x.reshape(100, 4, 8)) + 1e-6 * np.eye(8)
        np.testing.assert_allclose(np.array(chol @ chol.transpose(0, 2, 1)),
                                   g, rtol=1e-3, atol=1e-3)

    if HAS_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(obs=st.integers(24, 200), nvars=st.integers(2, 40),
               thr=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**30))
        def test_property_monotone_and_bounded(self, obs, nvars, thr, seed):
            """Property (Theorem 1): for any random system, SSE after any
            number of gram-mode sweeps is non-increasing and ≤ ||y||²."""
            r = np.random.default_rng(seed)
            x = r.normal(size=(obs, nvars)).astype(np.float32)
            y = r.normal(size=(obs,)).astype(np.float32)
            res = solvebakp(jnp.array(x), jnp.array(y), thr=thr, max_iter=10,
                            mode="gram")
            h = np.array(res.history)
            h = h[~np.isnan(h)]
            y2 = float(np.sum(y * y))
            assert h[0] <= y2 * (1 + 1e-4) + 1e-4
            assert np.all(np.diff(h) <= 1e-3 * h[:-1] + 1e-5)
    else:
        @pytest.mark.skip(reason="hypothesis not installed")
        def test_property_monotone_and_bounded(self):
            pass
