"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode on CPU (same kernel body Python-executed);
BlockSpecs/grid layouts are identical to the TPU path.
"""
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional (requirements-dev.txt): only the property tests
# skip without it; the deterministic oracle sweeps always run.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.types import column_norms_sq, safe_inv
from repro.kernels import (bakp_sweep, block_update, cd_sweep,
                           score_features, solvebakp_kernel)
from repro.kernels.ref import (ref_bakp_sweep, ref_block_update,
                               ref_cd_sweep, ref_score_features)


def _mk(rng, obs, nvars, dtype):
    x = rng.normal(size=(obs, nvars)).astype(np.float32)
    e = rng.normal(size=(obs,)).astype(np.float32)
    x_t = jnp.array(x.T, dtype=dtype)
    inv_cn = safe_inv(column_norms_sq(jnp.array(x_t.T, jnp.float32)))
    return x_t, jnp.array(e), inv_cn


SHAPES = [(64, 8, 8), (256, 32, 16), (512, 64, 32), (128, 16, 4)]
DTYPES = [jnp.float32, jnp.bfloat16]


class TestCdSweep:
    @pytest.mark.parametrize("obs,nvars,blk", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_oracle(self, rng, obs, nvars, blk, dtype):
        x_t, e, inv_cn = _mk(rng, obs, nvars, dtype)
        da_k, e_k = cd_sweep(x_t, e, inv_cn, block=blk)
        da_r, e_r = ref_cd_sweep(x_t, e, inv_cn)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.array(da_k), np.array(da_r),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.array(e_k), np.array(e_r),
                                   rtol=tol, atol=tol)

    def test_vmem_guard(self, rng):
        x_t, e, inv_cn = _mk(rng, 64, 8, jnp.float32)
        import sys
        m = sys.modules["repro.kernels.cd_sweep"]  # pkg attr shadows module
        old = m.VMEM_BUDGET_BYTES
        try:
            m.VMEM_BUDGET_BYTES = 128
            with pytest.raises(ValueError, match="VMEM"):
                cd_sweep(x_t, e, inv_cn, block=8)
        finally:
            m.VMEM_BUDGET_BYTES = old


class TestBakpSweep:
    @pytest.mark.parametrize("obs,nvars,blk", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_oracle(self, rng, obs, nvars, blk, dtype):
        x_t, e, inv_cn = _mk(rng, obs, nvars, dtype)
        da_k, e_k = bakp_sweep(x_t, e, inv_cn, block=blk)
        da_r, e_r = ref_bakp_sweep(x_t, e, inv_cn, block=blk)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.array(da_k), np.array(da_r),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.array(e_k), np.array(e_r),
                                   rtol=tol, atol=tol)

    def test_omega(self, rng):
        x_t, e, inv_cn = _mk(rng, 128, 16, jnp.float32)
        da_k, _ = bakp_sweep(x_t, e, inv_cn, block=8, omega=0.5)
        da_r, _ = ref_bakp_sweep(x_t, e, inv_cn, block=8, omega=0.5)
        np.testing.assert_allclose(np.array(da_k), np.array(da_r),
                                   rtol=1e-4, atol=1e-5)


class TestBlockUpdate:
    @pytest.mark.parametrize("obs,cb,tile", [(256, 8, 64), (512, 16, 128),
                                             (1024, 32, 256)])
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_oracle(self, rng, obs, cb, tile, dtype):
        x_t = jnp.array(rng.normal(size=(cb, obs)), dtype=dtype)
        e = jnp.array(rng.normal(size=(obs,)).astype(np.float32))
        da = jnp.array(rng.normal(size=(cb,)).astype(np.float32))
        out_k = block_update(x_t, e, da, obs_tile=tile)
        out_r = ref_block_update(x_t, e, da)
        tol = 1e-4 if dtype == jnp.float32 else 1e-1
        np.testing.assert_allclose(np.array(out_k), np.array(out_r),
                                   rtol=tol, atol=tol)


class TestScoreFeatures:
    @pytest.mark.parametrize("obs,nvars,cb,ot", [(256, 16, 8, 64),
                                                 (512, 64, 32, 128)])
    def test_matches_oracle(self, rng, obs, nvars, cb, ot):
        x = rng.normal(size=(obs, nvars)).astype(np.float32)
        e = rng.normal(size=(obs,)).astype(np.float32)
        x_t = jnp.array(x.T)
        inv_cn = safe_inv(column_norms_sq(jnp.array(x)))
        s_k = score_features(x_t, jnp.array(e), inv_cn, col_block=cb,
                             obs_tile=ot)
        s_r = ref_score_features(x_t, jnp.array(e), inv_cn)
        np.testing.assert_allclose(np.array(s_k), np.array(s_r),
                                   rtol=1e-4, atol=1e-3)

    if HAS_HYPOTHESIS:
        @settings(max_examples=15, deadline=None)
        @given(obs_t=st.sampled_from([32, 64]), nob=st.integers(1, 4),
               nvars_b=st.sampled_from([4, 8]), nb=st.integers(1, 4),
               seed=st.integers(0, 2**30))
        def test_property_grid_invariance(self, obs_t, nob, nvars_b, nb,
                                          seed):
            """Scores are invariant to the (col_block, obs_tile) grid."""
            r = np.random.default_rng(seed)
            obs, nvars = obs_t * nob, nvars_b * nb
            x = r.normal(size=(obs, nvars)).astype(np.float32)
            e = r.normal(size=(obs,)).astype(np.float32)
            x_t = jnp.array(x.T)
            inv_cn = safe_inv(column_norms_sq(jnp.array(x)))
            s1 = score_features(x_t, jnp.array(e), inv_cn, col_block=nvars_b,
                                obs_tile=obs_t)
            s2 = ref_score_features(x_t, jnp.array(e), inv_cn)
            np.testing.assert_allclose(np.array(s1), np.array(s2), rtol=1e-4,
                                       atol=1e-3)
    else:
        @pytest.mark.skip(reason="hypothesis not installed")
        def test_property_grid_invariance(self):
            pass


class TestKernelSolver:
    def test_full_solve_bakp(self, rng):
        x = rng.normal(size=(512, 64)).astype(np.float32)
        a = rng.normal(size=(64,)).astype(np.float32)
        y = x @ a
        res = solvebakp_kernel(jnp.array(x.T), jnp.array(y), block=16,
                               max_iter=60)
        np.testing.assert_allclose(np.array(res.coef), a, rtol=1e-3,
                                   atol=1e-3)

    def test_full_solve_bak_variant(self, rng):
        x = rng.normal(size=(256, 32)).astype(np.float32)
        a = rng.normal(size=(32,)).astype(np.float32)
        y = x @ a
        res = solvebakp_kernel(jnp.array(x.T), jnp.array(y), block=16,
                               max_iter=15, variant="bak")
        np.testing.assert_allclose(np.array(res.coef), a, rtol=1e-3,
                                   atol=1e-3)

    def test_atol_stops_early(self, rng):
        x = rng.normal(size=(256, 32)).astype(np.float32)
        y = (x @ rng.normal(size=(32,)).astype(np.float32))
        res = solvebakp_kernel(jnp.array(x.T), jnp.array(y), block=16,
                               max_iter=100, atol=1e-3)
        assert bool(res.converged) and int(res.n_sweeps) < 100
