"""Mixed-precision X streaming (SolverSpec.precision) — ISSUE 7.

Parity gates: ``precision="bf16"`` (bf16 X storage, fp32 accumulators, no
polish) must land within 1e-2 of the fp32 solve; ``"bf16_fp32acc"`` (plus
the fp32 iterative-refinement polish) within 1e-5 — across single/multi-RHS
x warm/cold starts x every kernel path (fused, per-sweep bf16 stream, and
the engine's downgrade-to-fp32 route).

The VMEM-budget tests monkeypatch ``repro.kernels.cd_sweep.
VMEM_BUDGET_BYTES`` (reached via importlib — the package re-exports a
*function* named ``cd_sweep``) and pick a budget strictly between the bf16
and fp32 fused working sets: the acceptance criterion is that such a design
dispatches FUSED at bf16 (no XLA fallback) while the fp32 spec falls back.
"""
import importlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (PRECISIONS, SolverSpec, UnsupportedSpecError,
                        methods_for_precision, prepare)
from repro.core.types import column_norms_sq, column_norms_sq_t
from repro.kernels.fused_solve import fused_vmem_bytes

_CD = importlib.import_module("repro.kernels.cd_sweep")


def _well_conditioned(rng, obs=512, nvars=64, k=None):
    """Design with singular values in [1, 2] (CD converges fast and the
    fp32/bf16 gap is representation error, not conditioning amplification),
    plus consistent right-hand side(s) and the true coefficients."""
    q1 = np.linalg.qr(rng.normal(size=(obs, nvars)))[0]
    q2 = np.linalg.qr(rng.normal(size=(nvars, nvars)))[0]
    x = (q1 * np.linspace(1.0, 2.0, nvars)) @ q2
    x = x.astype(np.float32)
    shape = (nvars,) if k is None else (nvars, k)
    a = rng.normal(size=shape).astype(np.float32)
    y = (x @ a).astype(np.float32)
    return x, a, y


def _max_err(got, want):
    return float(np.max(np.abs(np.asarray(got) - np.asarray(want))))


class TestSpecSurface:
    def test_precisions_tuple(self):
        assert PRECISIONS == ("fp32", "bf16", "bf16_fp32acc")
        assert set(methods_for_precision("bf16")) == {"bakp_fused",
                                                      "bak_fused",
                                                      "bakp_stream"}
        assert "bakp" in methods_for_precision("fp32")

    def test_malformed_precision_is_value_error(self):
        with pytest.raises(ValueError, match="precision"):
            SolverSpec(method="bakp_fused", precision="fp16")

    def test_unsupported_method_precision_raises_typed(self, rng):
        x, _, y = _well_conditioned(rng, obs=64, nvars=16)
        bad = SolverSpec(method="bakp", precision="bf16", thr=8)
        with pytest.raises(UnsupportedSpecError):
            prepare(x, bad)
        design = prepare(x, SolverSpec(method="bakp", thr=8))
        with pytest.raises(UnsupportedSpecError):
            design.solve(y, spec=bad)
        # The typed error is still a ValueError (pre-existing handlers).
        assert issubclass(UnsupportedSpecError, ValueError)


class TestParityFused:
    @pytest.mark.parametrize("variant", ["bakp_fused", "bak_fused"])
    @pytest.mark.parametrize("k", [None, 4])
    @pytest.mark.parametrize("warm", [False, True])
    def test_bf16_and_refined_vs_fp32(self, rng, variant, k, warm):
        x, a, y = _well_conditioned(rng, k=k)
        base = SolverSpec(method=variant, thr=16, max_iter=200, rtol=1e-12)
        design = prepare(x, base)
        a0 = None if not warm else (0.8 * a).astype(np.float32)
        r32 = design.solve(y, a0=a0)
        rbf = design.solve(y, a0=a0, spec=base.replace(precision="bf16"))
        racc = design.solve(y, a0=a0,
                            spec=base.replace(precision="bf16_fp32acc",
                                              refine_sweeps=8))
        assert _max_err(rbf.coef, r32.coef) <= 1e-2
        assert _max_err(racc.coef, r32.coef) <= 1e-5
        # The polish accounts for its sweeps and extends the history.
        assert racc.history.shape[0] == base.max_iter + 8

    def test_warm_cold_equivalence_of_quantized_tier(self, rng):
        """The bf16 tier is cast once and cached; warm (repeat) solves see
        the identical resident copy, so results are bit-stable."""
        x, _, y = _well_conditioned(rng, obs=256, nvars=32)
        spec = SolverSpec(method="bakp_fused", thr=16, max_iter=50,
                          precision="bf16")
        design = prepare(x, spec)
        cold = design.solve(y)
        warm = design.solve(y)
        np.testing.assert_array_equal(np.asarray(cold.coef),
                                      np.asarray(warm.coef))


class TestDispatchPaths:
    def test_bf16_only_fits_fused_dispatches_fused(self, rng, monkeypatch):
        """Acceptance: a design over the fp32 fused budget but inside it at
        bf16 runs FUSED under a bf16 precision (no XLA fallback), while the
        fp32 spec falls back."""
        x, a, y = _well_conditioned(rng, obs=512, nvars=64)
        spec32 = SolverSpec(method="bakp_fused", thr=16, max_iter=40,
                            rtol=1e-10)
        need32 = fused_vmem_bytes(64, 512, 1, 4, max_iter=40)
        need16 = fused_vmem_bytes(64, 512, 1, 2, max_iter=40)
        budget = (need32 + need16) // 2
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES", budget)
        design = prepare(x, spec32)
        obs.consume_dispatch()
        r32 = design.solve(y)
        assert obs.consume_dispatch() == "xla"
        racc = design.solve(y, spec=spec32.replace(precision="bf16_fp32acc",
                                                   refine_sweeps=8))
        assert obs.consume_dispatch() == "fused"
        assert _max_err(racc.coef, r32.coef) <= 1e-5
        np.testing.assert_allclose(np.asarray(racc.coef), a, rtol=1e-3,
                                   atol=1e-3)

    def test_bf16_over_budget_streams_persweep(self, rng, monkeypatch):
        """A bf16 solve too large even for the halved fused footprint keeps
        the bf16 per-sweep stream (halved HBM traffic), not the fp32 XLA
        path, and refinement still recovers fp32 accuracy."""
        x, _, y = _well_conditioned(rng, obs=512, nvars=64)
        spec = SolverSpec(method="bakp_fused", thr=16, max_iter=120,
                          rtol=1e-12)
        design = prepare(x, spec)
        r32 = design.solve(y)
        need16 = fused_vmem_bytes(64, 512, 1, 2, max_iter=120)
        sweep16 = 512 * 4 + 16 * 512 * 2  # persweep tile working set
        assert sweep16 < need16
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES",
                            (sweep16 + need16) // 2)
        obs.consume_dispatch()
        rbf = design.solve(y, spec=spec.replace(precision="bf16"))
        assert obs.consume_dispatch() == "persweep"
        assert _max_err(rbf.coef, r32.coef) <= 1e-2
        racc = design.solve(y, spec=spec.replace(precision="bf16_fp32acc",
                                                 refine_sweeps=8))
        assert _max_err(racc.coef, r32.coef) <= 1e-5


class TestQuantizedCacheTier:
    def test_x_bf16_for_cached_and_layouted(self, rng):
        x, _, _ = _well_conditioned(rng, obs=128, nvars=24)
        design = prepare(x)
        xb = design.x_bf16_for(16)
        assert xb.dtype == jnp.bfloat16
        assert xb.shape == (32, 128)  # thr-padded transposed layout
        assert design.x_bf16_for(16) is xb  # memoised
        np.testing.assert_array_equal(
            np.asarray(xb, np.float32),
            np.asarray(design.x_t_for(16).astype(jnp.bfloat16), np.float32))

    def test_prepare_hook_warms_quantized_tier(self, rng):
        x, _, _ = _well_conditioned(rng, obs=128, nvars=24)
        d32 = prepare(x, SolverSpec(method="bakp_fused", thr=8))
        assert 8 in d32._x_t and 8 not in d32._x_bf16
        dbf = prepare(x, SolverSpec(method="bakp_fused", thr=8,
                                    precision="bf16"))
        assert 8 in dbf._x_bf16  # dispatcher pre-warm path hits this hook

    def test_norms_accumulate_fp32_on_bf16_input(self, rng):
        """Satellite bugfix: column_norms_sq(_t) must produce fp32 sums of
        the bf16 values — an in-dtype (bf16) accumulation loses ~2 decimal
        digits that inv_cn then amplifies in every sweep."""
        x = rng.normal(size=(2048, 8)).astype(np.float32)
        xb = jnp.asarray(x).astype(jnp.bfloat16)
        got_t = column_norms_sq_t(jnp.swapaxes(xb, 0, 1))
        got = column_norms_sq(xb)
        assert got.dtype == jnp.float32 and got_t.dtype == jnp.float32
        ref = np.sum(np.asarray(xb, np.float64) ** 2, axis=0)
        np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(got_t), ref, rtol=1e-3)
        # fp32 inputs keep their exact pre-PR behaviour.
        np.testing.assert_allclose(
            np.asarray(column_norms_sq(jnp.asarray(x))),
            np.sum(x.astype(np.float64) ** 2, axis=0), rtol=1e-5)


class TestServingPrecision:
    def _engine(self, **cfg):
        from repro.serve import ServeConfig, SolverServeEngine

        return SolverServeEngine(ServeConfig(**cfg),
                                 registry=obs.MetricsRegistry())

    def test_engine_serves_bf16_and_labels_latency(self, rng):
        from repro.serve import SolveRequest

        x, _, _ = _well_conditioned(rng, obs=256, nvars=32)
        coefs = rng.normal(size=(32, 3)).astype(np.float32)
        spec = SolverSpec(method="bakp_fused", thr=16, max_iter=200,
                          rtol=1e-12, precision="bf16_fp32acc")
        eng = self._engine()
        served = eng.serve([
            SolveRequest(x=x, y=(x @ coefs[:, i]).astype(np.float32),
                         spec=spec, design_key="d0")
            for i in range(3)])
        assert all(s.ok for s in served)
        assert all(s.batch_kind == "multi_rhs" for s in served)
        for i, s in enumerate(served):
            np.testing.assert_allclose(s.coef, coefs[:, i], rtol=1e-4,
                                       atol=1e-4)
        lat = eng.registry.get("serve_solve_latency_seconds")
        assert lat.count(precision="bf16_fp32acc") == 1
        assert lat.count(precision="fp32") == 0

    def test_engine_downgrades_unsupported_precision(self, rng):
        """A precision its method can't run is served at fp32 (identical
        results to an fp32 request), never an error, and counts one
        solver_fallback_total{reason="precision"} per request."""
        from repro.serve import SolveRequest

        x, a, y = _well_conditioned(rng, obs=256, nvars=32)
        eng = self._engine()
        bad = SolverSpec(method="bakp_gram", thr=16, max_iter=60,
                         rtol=1e-10, precision="bf16")
        [served] = eng.serve([SolveRequest(x=x, y=y, spec=bad)])
        assert served.ok
        good = SolverSpec(method="bakp_gram", thr=16, max_iter=60,
                          rtol=1e-10)
        [ref] = self._engine().serve([SolveRequest(x=x, y=y, spec=good)])
        np.testing.assert_array_equal(served.coef, ref.coef)
        fb = eng.registry.get("solver_fallback_total")
        assert fb.value(method="bakp_gram", reason="precision") == 1.0
        # Counted once per request, not once per spec_for call.
        eng.serve([SolveRequest(x=x, y=y, spec=bad),
                   SolveRequest(x=x, y=y, spec=bad)])
        assert fb.value(method="bakp_gram", reason="precision") == 3.0

    def test_engine_precision_policy_on_legacy_requests(self, rng):
        """ServeConfig.precision applies to legacy per-field requests like
        omega/ridge; with prefer_fused the upgraded method carries it."""
        from repro.serve import SolveRequest

        x, a, y = _well_conditioned(rng, obs=256, nvars=32)
        eng = self._engine(precision="bf16_fp32acc", prefer_fused=True)
        req = SolveRequest(x=x, y=y, method="bakp", thr=16, max_iter=200,
                           rtol=1e-12)
        eff = eng.spec_for(req)
        assert eff.method == "bakp_fused"
        assert eff.precision == "bf16_fp32acc"
        [served] = eng.serve([req])
        assert served.ok and served.telemetry.kernel_path == "fused"
        np.testing.assert_allclose(served.coef, a, rtol=1e-4, atol=1e-4)
        # An explicit spec stays authoritative over the engine policy.
        explicit = SolveRequest(x=x, y=y, spec=SolverSpec(
            method="bakp_fused", thr=16, max_iter=50))
        assert eng.spec_for(explicit).precision == "fp32"

    def test_prefer_fused_upgrade_uses_bf16_headroom(self, rng,
                                                     monkeypatch):
        """A bucket over the fp32 fused budget still upgrades bakp ->
        bakp_fused when the bf16 footprint fits."""
        from repro.serve import SolveRequest

        x, _, y = _well_conditioned(rng, obs=512, nvars=64)
        # Bucket pads to (512, 64); thr=16 keeps vars_pb=64.
        need32 = fused_vmem_bytes(64, 512, 1, 4, max_iter=40)
        need16 = fused_vmem_bytes(64, 512, 1, 2, max_iter=40)
        monkeypatch.setattr(_CD, "VMEM_BUDGET_BYTES",
                            (need32 + need16) // 2)
        req = SolveRequest(x=x, y=y, method="bakp", thr=16, max_iter=40)
        assert self._engine(prefer_fused=True).spec_for(req).method == "bakp"
        eng = self._engine(prefer_fused=True, precision="bf16_fp32acc")
        assert eng.spec_for(req).method == "bakp_fused"
