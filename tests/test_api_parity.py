"""Golden parity suite: legacy ``solve(x, y, method=..., **kw)`` call
patterns vs the spec/prepare handle API, plus the PR-4 satellite fixes
(bakf registration, multi-output ``fit_linear_probe``, the ``normal``
ridge spec field) and serve-engine end-to-end parity with the core API.

The contract: every legacy pattern and its ``prepare(x, spec).solve(y)``
equivalent agree to <= 1e-6, and both agree with the raw underlying kernels
(``solvebak``/``solvebakp`` called directly — the pre-refactor ground
truth) to the same tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_system
from repro.core import (SolverSpec, fit_linear_probe, prepare, solve,
                        solvebak, solvebakp, solver_method)

TOL = dict(rtol=1e-6, atol=1e-6)

# One spec per method, exercising that method's own knobs.
SPECS = {
    "bak": SolverSpec(method="bak", max_iter=60, rtol=1e-12),
    "bakp": SolverSpec(method="bakp", max_iter=60, rtol=1e-12, thr=8),
    "bakp_gram": SolverSpec(method="bakp_gram", max_iter=60, rtol=1e-12,
                            thr=8),
    "bakp_fused": SolverSpec(method="bakp_fused", max_iter=60, rtol=1e-12,
                             thr=8),
    "bak_fused": SolverSpec(method="bak_fused", max_iter=60, rtol=1e-12,
                            thr=8),
    "bakf": SolverSpec(method="bakf", max_iter=40, thr=8),
    "lstsq": SolverSpec(method="lstsq"),
    "normal": SolverSpec(method="normal"),
}


def _legacy_kwargs(spec: SolverSpec) -> dict:
    return dict(method=spec.method, max_iter=spec.max_iter, atol=spec.atol,
                rtol=spec.rtol, thr=spec.thr)


class TestGoldenParity:
    """legacy solve(**kw) == prepare(x, spec).solve(y), all methods."""

    @pytest.mark.parametrize("method", sorted(SPECS))
    def test_single_rhs(self, rng, method):
        x, y, _ = make_system(rng, 300, 24)
        spec = SPECS[method]
        legacy = solve(jnp.array(x), jnp.array(y), **_legacy_kwargs(spec))
        handle = prepare(x, spec).solve(y)
        np.testing.assert_allclose(np.array(legacy.coef),
                                   np.array(handle.coef), **TOL)
        np.testing.assert_allclose(np.array(legacy.residual),
                                   np.array(handle.residual), **TOL)
        assert int(legacy.n_sweeps) == int(handle.n_sweeps)
        assert bool(legacy.converged) == bool(handle.converged)

    @pytest.mark.parametrize(
        "method", sorted(m for m in SPECS if solver_method(m).multi_rhs))
    def test_multi_rhs(self, rng, method):
        x, _, _ = make_system(rng, 300, 24)
        a_true = rng.normal(size=(24, 5)).astype(np.float32)
        ys = x @ a_true
        spec = SPECS[method]
        legacy = solve(jnp.array(x), jnp.array(ys), **_legacy_kwargs(spec))
        handle = prepare(x, spec).solve(ys)
        assert legacy.coef.shape == handle.coef.shape == (24, 5)
        np.testing.assert_allclose(np.array(legacy.coef),
                                   np.array(handle.coef), **TOL)

    @pytest.mark.parametrize(
        "method", sorted(m for m in SPECS if solver_method(m).iterative))
    def test_warm_start(self, rng, method):
        x, y, a_true = make_system(rng, 300, 24)
        a0 = (a_true + 0.1 * rng.normal(size=24).astype(np.float32))
        spec = SPECS[method]
        legacy = solve(jnp.array(x), jnp.array(y), a0=jnp.array(a0),
                       **_legacy_kwargs(spec))
        handle = prepare(x, spec).solve(y, a0=a0)
        np.testing.assert_allclose(np.array(legacy.coef),
                                   np.array(handle.coef), **TOL)
        assert int(legacy.n_sweeps) == int(handle.n_sweeps)

    def test_matches_raw_kernels(self, rng):
        """Both API layers agree with the raw pre-refactor kernels."""
        x, y, _ = make_system(rng, 300, 24)
        raw = solvebak(jnp.array(x), jnp.array(y), max_iter=60, rtol=1e-12)
        via_api = solve(jnp.array(x), jnp.array(y), method="bak",
                        max_iter=60, rtol=1e-12)
        np.testing.assert_allclose(np.array(raw.coef), np.array(via_api.coef),
                                   **TOL)
        rawp = solvebakp(jnp.array(x), jnp.array(y), thr=8, max_iter=60,
                         rtol=1e-12, mode="gram")
        via_apip = solve(jnp.array(x), jnp.array(y), method="bakp_gram",
                         thr=8, max_iter=60, rtol=1e-12)
        np.testing.assert_allclose(np.array(rawp.coef),
                                   np.array(via_apip.coef), **TOL)

    def test_tenant_rhs_count_change_falls_back_cold(self, rng):
        """Regression: a tenant's stored (vars, k) multi-RHS coefficients
        must not crash (or mis-shape) their next solve with a different
        RHS count — incompatible warm state means a cold start."""
        x, y, _ = make_system(rng, 200, 16)
        handle = prepare(x, SPECS["bakp_gram"])
        ys = x @ rng.normal(size=(16, 4)).astype(np.float32)
        handle.solve(ys, tenant_id="t1")          # stores (16, 4)
        single = handle.solve(y, tenant_id="t1")  # cold fallback, no crash
        cold = handle.solve(y)
        np.testing.assert_array_equal(np.array(single.coef),
                                      np.array(cold.coef))
        # Same-k re-solve accepts the stored (16, 4) warm state and lands
        # on the same fixed point (sweep counts at the accuracy floor are
        # jittery, so parity of the solution is the stable assertion).
        warm = handle.solve(ys, tenant_id="t1")
        np.testing.assert_allclose(np.array(warm.coef),
                                   np.array(handle.solve(ys).coef),
                                   rtol=1e-5, atol=1e-5)

    def test_direct_methods_skip_column_norms(self, rng):
        """Regression: prepare() must not pay the O(obs·vars) column-norm
        pass for methods that never read it."""
        x, y, _ = make_system(rng, 200, 16)
        handle = prepare(x, SPECS["lstsq"])
        handle.solve(y)
        assert handle._cn is None
        _ = handle.cn                      # iterative path materialises it
        assert handle._cn is not None

    def test_bak_random_order_errors_in_vmap_batch(self, rng):
        """Regression: order="random" (no key in serving) must error in a
        vmap batch exactly like it does solo — never silently solve with
        cyclic order.  retry_ladder=False pins the raw validation parity;
        with the ladder on, the engine instead degrades the request down
        the method chain (test_resilience.py)."""
        from repro.serve import ServeConfig, SolveRequest, SolverServeEngine

        spec = SolverSpec(method="bak", max_iter=20, order="random")
        reqs = []
        for i in range(2):  # distinct designs, same bucket -> vmap path
            x = rng.normal(size=(100, 8)).astype(np.float32)
            reqs.append(SolveRequest(x=x, y=x[:, 0], spec=spec,
                                     design_key=f"rd-{i}"))
        out = SolverServeEngine(ServeConfig(retry_ladder=False)).serve(reqs)
        assert all(not r.ok for r in out)
        assert all("PRNG key" in r.error for r in out)

    def test_prepared_reuse_is_stable(self, rng):
        """Repeated solves off one handle return identical results (cached
        cn/chol state must not drift)."""
        x, y, _ = make_system(rng, 200, 16)
        handle = prepare(x, SPECS["bakp_gram"])
        r1 = handle.solve(y)
        r2 = handle.solve(y)
        np.testing.assert_array_equal(np.array(r1.coef), np.array(r2.coef))


class TestBakfMethod:
    """Satellite: solvebakf registered as method "bakf"."""

    def test_parity_vs_solvebak(self, rng):
        x, y, a_true = make_system(rng, 400, 16)
        bakf = solve(jnp.array(x), jnp.array(y), method="bakf", max_iter=40,
                     thr=8)
        bak = solvebak(jnp.array(x), jnp.array(y), max_iter=200, rtol=1e-14)
        # Both converge to the least-squares solution of a consistent
        # system; greedy selection order must not change the fixed point.
        np.testing.assert_allclose(np.array(bakf.coef), np.array(bak.coef),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.array(bakf.coef), a_true, rtol=1e-3,
                                   atol=1e-3)
        assert float(bakf.sse) <= 1e-4

    def test_rejects_multi_rhs(self, rng):
        x, _, _ = make_system(rng, 100, 8)
        ys = rng.normal(size=(100, 3)).astype(np.float32)
        with pytest.raises(ValueError, match="multi-RHS"):
            solve(jnp.array(x), jnp.array(ys), method="bakf")

    def test_registry_flags(self):
        entry = solver_method("bakf")
        assert not entry.multi_rhs
        assert not entry.batchable
        assert not entry.shardable


class TestFitLinearProbe:
    """Satellite: (tokens, k) targets ride the multi-RHS path."""

    def test_multi_output_targets(self, rng):
        feats = rng.normal(size=(300, 16)).astype(np.float32)
        a_true = rng.normal(size=(16, 4)).astype(np.float32)
        targets = feats @ a_true
        res = fit_linear_probe(jnp.array(feats), jnp.array(targets),
                               max_iter=100, rtol=1e-10, thr=8)
        assert res.coef.shape == (16, 4)
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)
        # Column-by-column parity with single-output fits: the multi-output
        # fit is the k single fits run side by side.
        for j in range(4):
            single = fit_linear_probe(jnp.array(feats),
                                      jnp.array(targets[:, j]),
                                      max_iter=100, rtol=1e-10, thr=8)
            np.testing.assert_allclose(np.array(res.coef[:, j]),
                                       np.array(single.coef), rtol=1e-5,
                                       atol=1e-5)

    def test_leading_axes_flattened(self, rng):
        feats = rng.normal(size=(4, 50, 8)).astype(np.float32)
        a_true = rng.normal(size=(8, 3)).astype(np.float32)
        targets = feats @ a_true                      # (4, 50, 3)
        res = fit_linear_probe(jnp.array(feats), jnp.array(targets),
                               max_iter=100, rtol=1e-10, thr=8)
        assert res.coef.shape == (8, 3)
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    def test_scalar_targets_unchanged(self, rng):
        feats = rng.normal(size=(4, 50, 8)).astype(np.float32)
        a_true = rng.normal(size=(8,)).astype(np.float32)
        res = fit_linear_probe(jnp.array(feats), jnp.array(feats @ a_true),
                               max_iter=100, rtol=1e-10, thr=8)
        assert res.coef.shape == (8,)
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    def test_shape_mismatch_raises(self, rng):
        feats = rng.normal(size=(50, 8)).astype(np.float32)
        with pytest.raises(ValueError, match="do not match"):
            fit_linear_probe(jnp.array(feats),
                             jnp.array(np.zeros((49,), np.float32)))


class TestNormalRidge:
    """Satellite: the "normal" baseline's ridge is a SolverSpec field."""

    def test_default_matches_legacy_hardcode(self, rng):
        x, y, _ = make_system(rng, 200, 16)
        res = solve(jnp.array(x), jnp.array(y), method="normal")
        spec_res = solve(jnp.array(x), jnp.array(y),
                         spec=SolverSpec(method="normal", ridge=1e-6))
        np.testing.assert_allclose(np.array(res.coef),
                                   np.array(spec_res.coef), **TOL)

    def test_ridge_changes_solution(self, rng):
        x, y, _ = make_system(rng, 200, 16)
        # Gram diagonal is ~obs here, so the ridge must dwarf it to bite.
        soft = solve(jnp.array(x), jnp.array(y), method="normal", ridge=1e4)
        hard = solve(jnp.array(x), jnp.array(y), method="normal", ridge=1e-6)
        # A strong ridge shrinks the coefficients toward zero.
        assert (float(jnp.sum(soft.coef ** 2))
                < 0.9 * float(jnp.sum(hard.coef ** 2)))

    def test_direct_methods_ignore_a0(self, rng):
        """The SolverSpec contract: a0 is ignored by direct methods —
        passing garbage must not change the answer."""
        x, y, _ = make_system(rng, 200, 16)
        for method in ("lstsq", "normal"):
            cold = solve(jnp.array(x), jnp.array(y), method=method)
            warm = prepare(x, SolverSpec(method=method)).solve(
                y, a0=np.full((16,), 1e6, np.float32))
            np.testing.assert_array_equal(np.array(cold.coef),
                                          np.array(warm.coef))


class TestServeEngineParity:
    """Serve engine end-to-end results match direct handle solves, for both
    legacy-kwargs and spec-carrying requests (the PR-3 behaviour contract:
    the engine is now a consumer of the same public API)."""

    def test_engine_matches_handle(self, rng):
        from repro.serve import SolveRequest, SolverServeEngine

        eng = SolverServeEngine()
        x = rng.normal(size=(300, 24)).astype(np.float32)
        spec = SolverSpec(method="bakp_gram", thr=16, max_iter=60,
                          rtol=1e-12)
        ys = [x @ rng.normal(size=(24,)).astype(np.float32)
              for _ in range(3)]
        legacy_reqs = [SolveRequest(x=x, y=y, method="bakp_gram", thr=16,
                                    max_iter=60, rtol=1e-12) for y in ys]
        spec_reqs = [SolveRequest(x=x, y=y, spec=spec) for y in ys]
        served_legacy = eng.serve(legacy_reqs)
        served_spec = eng.serve(spec_reqs)

        # The equivalent direct core-API call: one prepared design, one
        # coalesced multi-RHS solve on the bucket-padded system.
        from repro.serve import pad_x, pad_y
        bucket = (512, 32)
        handle = prepare(pad_x(x, bucket), spec)
        ys_pad = pad_y(np.stack(ys, axis=1), bucket[0])
        ys_pad = np.concatenate(
            [ys_pad, np.zeros((bucket[0], 1), np.float32)], axis=1)  # k_pad=4
        direct = handle.solve(ys_pad)
        for c, (sl, ss) in enumerate(zip(served_legacy, served_spec)):
            assert sl.batch_kind == ss.batch_kind == "multi_rhs"
            np.testing.assert_allclose(sl.coef, ss.coef, **TOL)
            np.testing.assert_allclose(
                sl.coef, np.array(direct.coef)[:24, c], **TOL)

    def test_spec_and_legacy_requests_group_together(self, rng):
        """A spec-carrying request and an equivalent legacy one coalesce
        into the same multi-RHS group."""
        from repro.serve import SolveRequest, SolverServeEngine

        eng = SolverServeEngine()
        x = rng.normal(size=(100, 8)).astype(np.float32)
        spec = SolverSpec(method="bakp_gram", thr=8, max_iter=40,
                          rtol=1e-12)
        out = eng.serve([
            SolveRequest(x=x, y=x[:, 0], spec=spec, design_key="d"),
            SolveRequest(x=x, y=x[:, 1], method="bakp_gram", thr=8,
                         max_iter=40, rtol=1e-12, design_key="d"),
        ])
        assert [r.batch_kind for r in out] == ["multi_rhs", "multi_rhs"]
        assert eng.stats.multi_rhs_groups == 1

    def test_bakf_served_singly(self, rng):
        """A non-multi-RHS method is servable: same-design requests fall
        back to per-request solves instead of coalescing."""
        from repro.serve import SolveRequest, SolverServeEngine

        eng = SolverServeEngine()
        x = rng.normal(size=(100, 8)).astype(np.float32)
        a = rng.normal(size=(8,)).astype(np.float32)
        out = eng.serve([
            SolveRequest(x=x, y=x @ a, spec=SolverSpec(method="bakf", thr=8),
                         design_key="d")
            for _ in range(2)
        ])
        assert [r.batch_kind for r in out] == ["single", "single"]
        for r in out:
            assert r.ok
            np.testing.assert_allclose(r.coef, a, rtol=1e-3, atol=1e-3)
