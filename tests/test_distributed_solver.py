"""Distributed (shard_map) solvers.

The 8-device checks run in a subprocess with forced virtual host devices so
the main test process keeps the single-device view; the regression tests
for the convergence-flag and history bugs run in-process on a trivial
(1, 1) mesh — the sharding machinery is identical, only the axis sizes
differ, so they exercise the exact while_loop state layout that was broken.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_system
from repro.core import (solvebakp, solvebakp_rhs_sharded,
                        solvebakp_vars_sharded)
from repro.launch.mesh import make_debug_mesh

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import (solvebakp_obs_sharded, solvebakp_vars_sharded,
                            solvebakp_2d, solvebakp_rhs_sharded, solvebakp)
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(1)
    x = rng.normal(size=(512, 64)).astype(np.float32)
    a_true = rng.normal(size=(64,)).astype(np.float32)
    y = x @ a_true

    r = solvebakp_obs_sharded(jnp.array(x), jnp.array(y), mesh, thr=16,
                              max_iter=50, mode="gram")
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"obs-sharded err {err}"

    # must agree with the single-device gram solver sweep-for-sweep
    r1 = solvebakp(jnp.array(x), jnp.array(y), thr=16, max_iter=5,
                   mode="gram")
    r2 = solvebakp_obs_sharded(jnp.array(x), jnp.array(y), mesh, thr=16,
                               max_iter=5, mode="gram")
    h1, h2 = np.array(r1.history)[:5], np.array(r2.history)[:5]
    np.testing.assert_allclose(h1, h2, rtol=1e-3)

    r = solvebakp_vars_sharded(jnp.array(x), jnp.array(y), mesh, thr=16,
                               max_iter=100, mode="gram", omega=0.5)
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"vars-sharded err {err}"

    r = solvebakp_2d(jnp.array(x), jnp.array(y), mesh, thr=16,
                     max_iter=100, mode="gram", omega=0.5)
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"2d err {err}"

    # jacobi mode distributed
    r = solvebakp_obs_sharded(jnp.array(x), jnp.array(y), mesh, thr=8,
                              max_iter=80, mode="jacobi")
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"obs-sharded jacobi err {err}"

    # ---- multi-RHS + warm starts through every sharded variant ----
    k = 32
    A = rng.normal(size=(64, k)).astype(np.float32)
    Y = x @ A
    ref = solvebakp(jnp.array(x), jnp.array(Y), thr=16, max_iter=20,
                    mode="gram")
    robs = solvebakp_obs_sharded(jnp.array(x), jnp.array(Y), mesh, thr=16,
                                 max_iter=20, mode="gram")
    np.testing.assert_allclose(np.array(robs.coef), np.array(ref.coef),
                               rtol=1e-4, atol=1e-5)
    # rhs-sharded: identical iterates AND identical (global-SSE) history —
    # per-RHS coordinate updates never interact across the k shards.
    rrhs = solvebakp_rhs_sharded(jnp.array(x), jnp.array(Y), mesh, thr=16,
                                 max_iter=20, mode="gram")
    np.testing.assert_allclose(np.array(rrhs.coef), np.array(ref.coef),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.array(rrhs.history)[:20],
                               np.array(ref.history)[:20], rtol=1e-4)

    # warm start from the exact solution: first-sweep residual ~ 0
    for fn, kw in ((solvebakp_obs_sharded, {}),
                   (solvebakp_rhs_sharded, {}),
                   (solvebakp_vars_sharded, dict(omega=0.5)),
                   (solvebakp_2d, dict(omega=0.5))):
        rw = fn(jnp.array(x), jnp.array(Y), mesh, thr=16, max_iter=3,
                mode="gram", a0=jnp.array(A), **kw)
        assert float(rw.sse) < 1e-4, f"{fn.__name__} warm sse {float(rw.sse)}"
    # (vars,) a0 broadcasts across all k right-hand sides
    a1 = rng.normal(size=(64,)).astype(np.float32)
    rb = solvebakp_rhs_sharded(jnp.array(x), jnp.array(Y), mesh, thr=16,
                               max_iter=20, mode="gram", a0=jnp.array(a1))
    rs = solvebakp(jnp.array(x), jnp.array(Y), thr=16, max_iter=20,
                   mode="gram", a0=jnp.array(a1))
    np.testing.assert_allclose(np.array(rb.coef), np.array(rs.coef),
                               rtol=1e-5, atol=1e-6)
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_solvers_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    assert "DISTRIBUTED_OK" in p.stdout


# --------------------------------------------------- in-process regressions
@pytest.fixture(scope="module")
def mesh1():
    """Trivial (1, 1) mesh on the test process's single CPU device."""
    return make_debug_mesh((1, 1), ("data", "model"))


class TestVarsShardedHistory:
    def test_history_holds_sse_trace(self, rng, mesh1):
        """Regression: the while_loop state was unpacked as
        ``... converged_h, converged`` and the *converged flag* landed in
        ``SolveResult.history`` (correct only by positional coincidence).
        The history slot must hold the per-sweep SSE trace."""
        x, y, _ = make_system(rng, 128, 32)
        n = 6
        r = solvebakp_vars_sharded(jnp.array(x), jnp.array(y), mesh1, thr=8,
                                   max_iter=n, mode="gram", omega=0.5)
        h = np.array(r.history)
        assert h.shape == (n,)
        assert np.all(np.isfinite(h[:n]))
        # a real SSE trace: positive, non-increasing, starting below ||y||²
        assert h[0] <= float(np.dot(y, y)) + 1e-3
        assert np.all(np.diff(h) <= 1e-5 * np.maximum(h[:-1], 1.0))
        # on a 1-device mesh vars-sharding is the single-device solver
        ref = solvebakp(jnp.array(x), jnp.array(y), thr=8, max_iter=n,
                        mode="gram", omega=0.5)
        np.testing.assert_allclose(h, np.array(ref.history), rtol=1e-5)


def _diverging_system(rng, obs=256, nvars=32):
    """Strongly correlated columns: Jacobi-within-block with thr=nvars
    diverges at ω=1 (the paper's remedy is small thr; we *want* the blowup
    here)."""
    base = rng.normal(size=(obs, 1)).astype(np.float32)
    x = base + 0.01 * rng.normal(size=(obs, nvars)).astype(np.float32)
    return x, (x @ np.ones(nvars, np.float32))


class TestDivergenceFlag:
    """Regression: ``(sse_prev - sse) <= rtol * sse_prev`` is trivially true
    when SSE *increases*, so a diverging solve used to stop after one sweep
    with ``converged=True``.  It must still stop early, but say False."""

    def test_single_device(self, rng):
        x, y = _diverging_system(rng)
        r = solvebakp(jnp.array(x), jnp.array(y), thr=32, max_iter=50,
                      mode="jacobi", rtol=1e-8)
        h = np.array(r.history)
        assert h[0] > float(np.dot(y, y))       # genuinely diverging
        assert not bool(r.converged)
        assert int(r.n_sweeps) < 50             # early exit retained

    def test_sharded(self, rng, mesh1):
        x, y = _diverging_system(rng)
        r = solvebakp_vars_sharded(jnp.array(x), jnp.array(y), mesh1,
                                   thr=32, max_iter=50, mode="jacobi",
                                   omega=1.0, rtol=1e-8)
        assert not bool(r.converged)
        assert int(r.n_sweeps) < 50

    def test_converging_still_reports_true(self, rng):
        x, y, _ = make_system(rng, 200, 16)
        r = solvebakp(jnp.array(x), jnp.array(y), thr=8, max_iter=100,
                      mode="gram", rtol=1e-10)
        assert bool(r.converged)
        assert int(r.n_sweeps) < 100

    def test_warm_start_at_optimum_is_converged(self):
        """A warm start already at the fixed point sits AT the accuracy
        floor, so the first sweep's float-noise SSE wobble may land a hair
        above sse0 — that is a stall (converged=True), not divergence.
        Several seeds: the wobble's sign is seed-dependent."""
        for seed in range(8):
            r = np.random.default_rng(seed)
            x = r.normal(size=(256, 32)).astype(np.float32)
            y = (x @ r.normal(size=(32,)).astype(np.float32)
                 + 0.1 * r.normal(size=(256,)).astype(np.float32))
            a_opt = np.linalg.lstsq(x.astype(np.float64),
                                    y.astype(np.float64), rcond=None)[0]
            res = solvebakp(jnp.array(x), jnp.array(y), thr=8, max_iter=50,
                            mode="gram", rtol=1e-6,
                            a0=jnp.array(a_opt.astype(np.float32)))
            assert bool(res.converged), f"seed {seed}"
            assert int(res.n_sweeps) <= 2, f"seed {seed}"


class TestRhsShardedApi:
    def test_requires_multi_rhs(self, rng, mesh1):
        x, y, _ = make_system(rng, 64, 8)
        with pytest.raises(ValueError, match="multi-RHS"):
            solvebakp_rhs_sharded(jnp.array(x), jnp.array(y), mesh1, thr=8)

    def test_one_device_matches_single(self, rng, mesh1):
        x, _, _ = make_system(rng, 96, 12)
        A = rng.normal(size=(12, 4)).astype(np.float32)
        Y = jnp.array(x @ A)
        r1 = solvebakp_rhs_sharded(jnp.array(x), Y, mesh1, thr=8,
                                   max_iter=15, mode="gram")
        r2 = solvebakp(jnp.array(x), Y, thr=8, max_iter=15, mode="gram")
        np.testing.assert_allclose(np.array(r1.coef), np.array(r2.coef),
                                   rtol=1e-5, atol=1e-6)

    def test_bad_a0_shape_raises(self, rng, mesh1):
        x, _, _ = make_system(rng, 64, 8)
        Y = jnp.array(rng.normal(size=(64, 2)).astype(np.float32))
        with pytest.raises(ValueError, match="a0 must be"):
            solvebakp_rhs_sharded(jnp.array(x), Y, mesh1, thr=8,
                                  a0=jnp.zeros((5,)))

    def test_tolerances_do_not_retrace(self, rng, mesh1):
        """atol/rtol are traced operands of the sharded programs: the
        serving engine's padding-corrected atol varies with real group
        size, and must never force a shard_map recompile."""
        from repro.core.distributed import _sharded_program
        x, _, _ = make_system(rng, 96, 12)
        Y = jnp.array(rng.normal(size=(96, 4)).astype(np.float32))
        before = _sharded_program.cache_info().currsize
        for atol, rtol in ((0.0, 0.0), (0.013, 1e-7), (0.250, 1e-9)):
            solvebakp_rhs_sharded(jnp.array(x), Y, mesh1, thr=8,
                                  max_iter=5, mode="gram", atol=atol,
                                  rtol=rtol)
        after = _sharded_program.cache_info().currsize
        assert after - before <= 1  # one program serves every tolerance


def test_mesh_builder_no_axistype_needed():
    """make_debug_mesh must work on jax versions without sharding.AxisType
    (the root cause of the seed's distributed-test failure)."""
    m = make_debug_mesh((1,), ("data",))
    assert m.shape["data"] == 1
    assert jax.devices()[0] in list(m.devices.flat)
