"""Distributed (shard_map) solvers — run in a subprocess with 8 host devices
so the main test process keeps the single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.core import (solvebakp_obs_sharded, solvebakp_vars_sharded,
                            solvebakp_2d, solvebakp)

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(512, 64)).astype(np.float32)
    a_true = rng.normal(size=(64,)).astype(np.float32)
    y = x @ a_true

    r = solvebakp_obs_sharded(jnp.array(x), jnp.array(y), mesh, thr=16,
                              max_iter=50, mode="gram")
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"obs-sharded err {err}"

    # must agree with the single-device gram solver sweep-for-sweep
    r1 = solvebakp(jnp.array(x), jnp.array(y), thr=16, max_iter=5,
                   mode="gram")
    r2 = solvebakp_obs_sharded(jnp.array(x), jnp.array(y), mesh, thr=16,
                               max_iter=5, mode="gram")
    h1, h2 = np.array(r1.history)[:5], np.array(r2.history)[:5]
    np.testing.assert_allclose(h1, h2, rtol=1e-3)

    r = solvebakp_vars_sharded(jnp.array(x), jnp.array(y), mesh, thr=16,
                               max_iter=100, mode="gram", omega=0.5)
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"vars-sharded err {err}"

    r = solvebakp_2d(jnp.array(x), jnp.array(y), mesh, thr=16,
                     max_iter=100, mode="gram", omega=0.5)
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"2d err {err}"

    # jacobi mode distributed
    r = solvebakp_obs_sharded(jnp.array(x), jnp.array(y), mesh, thr=8,
                              max_iter=80, mode="jacobi")
    err = float(np.abs(np.array(r.coef) - a_true).max())
    assert err < 1e-3, f"obs-sharded jacobi err {err}"
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_solvers_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    assert "DISTRIBUTED_OK" in p.stdout
