"""Per-architecture smoke tests (reduced configs, CPU): one train step +
prefill/decode, asserting shapes and finiteness — the per-arch deliverable.

Also: prefill+decode consistency vs a pure forward pass (cache correctness).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.launch.steps import make_train_step
from repro.models.kvcache import cache_bytes, init_cache
from repro.models.model import (forward_decode, forward_prefill, init_model,
                                make_smoke_batch)
from repro.optim import make_optimizer

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name, key):
    cfg = ARCHS[name].smoke()
    cfg = dataclasses.replace(cfg, microbatch=1)
    params = init_model(cfg, key)
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    batch = make_smoke_batch(cfg, key, batch=2, seq=32)
    step = make_train_step(cfg)
    params, opt_state, metrics = jax.jit(step)(params, opt_state, batch,
                                               jnp.int32(0))
    loss = float(metrics["ce_loss"])
    assert np.isfinite(loss), f"{name}: loss={loss}"
    assert loss > 0
    for leaf in jax.tree_util.tree_leaves(params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_smoke(name, key):
    cfg = ARCHS[name].smoke()
    params = init_model(cfg, key)
    batch = make_smoke_batch(cfg, key, batch=2, seq=32)
    batch.pop("labels", None)
    cache = init_cache(cfg, 2, cfg.max_cache_len)
    logits, cache = forward_prefill(cfg, params, batch, cache)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.all(np.isfinite(np.array(logits)))
    assert int(cache["lengths"][0]) == 32
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = forward_decode(cfg, params, tok, cache)
        assert np.all(np.isfinite(np.array(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(cache["lengths"][0]) == 35


# MoE archs are excluded: capacity dropping makes a 1-token decode route
# differently than the same token inside a 33-token teacher-forced batch —
# logits legitimately differ (their smoke/decode coverage lives in
# test_prefill_decode_smoke + test_kv_quant).
@pytest.mark.parametrize("name", ["qwen3-8b", "h2o-danube-1.8b",
                                  "minicpm3-4b", "mamba2-370m",
                                  "gemma2-9b", "zamba2-7b", "qwen2-vl-2b"])
def test_decode_matches_forward(name, key):
    """Prefill S tokens then decode token S must equal the full forward of
    S+1 tokens at position S (cache correctness, incl. ring/MLA/SSM)."""
    cfg = ARCHS[name].smoke()
    params = init_model(cfg, key)
    full = make_smoke_batch(cfg, key, batch=2, seq=33)
    prompt = {k: (v[:, :32] if k != "positions" else v[..., :32])
              for k, v in full.items() if k != "labels"}
    if "frames" in full:
        prompt["frames"] = full["frames"]

    # path A: prefill 32 + decode the 33rd token's logits
    cache = init_cache(cfg, 2, cfg.max_cache_len)
    _, cache = forward_prefill(cfg, params, prompt, cache)
    tok33 = full["tokens"][:, 32:33]
    logits_a, _ = forward_decode(cfg, params, tok33, cache)

    # path B: forward over all 33, take logits at the last position
    batch33 = dict(full)
    batch33["labels"] = full["tokens"]  # dummy
    from repro.models.model import _dtype, _positions
    from repro.models.common import embed_tokens, rmsnorm, unembed
    from repro.models.transformer import run_backbone
    x = embed_tokens(params["embed"], full["tokens"], _dtype(cfg))
    pos = full.get("positions")
    if pos is None:
        pos = _positions(cfg, 2, jnp.zeros((2,), jnp.int32), 33)
    h, _, _ = run_backbone(cfg, params["backbone"], x, mode="train",
                           positions=pos)
    h = rmsnorm(h, params["final_ln"])
    logits_b = unembed(params["embed"], h, tie=cfg.tie_embeddings,
                       final_softcap=cfg.final_softcap)[:, -1]

    np.testing.assert_allclose(np.array(logits_a), np.array(logits_b),
                               rtol=2e-2, atol=2e-2)


def test_cache_bytes_mla_compression():
    """MLA latent cache must be much smaller than an equivalent GQA cache."""
    cfg = ARCHS["minicpm3-4b"]
    mla_bytes = cache_bytes(cfg, 1, 32768)
    # hypothetical per-head cache: L * S * H * (nope+rope+v) * 2B
    full = cfg.n_layers * 32768 * cfg.n_heads * \
        (cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim) * 2 * 2
    assert mla_bytes < full / 10


def test_swa_ring_cache_constant_memory():
    cfg = ARCHS["h2o-danube-1.8b"]
    assert cache_bytes(cfg, 1, 524288) == cache_bytes(cfg, 1, 1 << 22)


def test_swa_ring_wraparound_decode(key):
    """Prefill LONGER than the SWA window: the ring cache must hold the last
    `window` tokens at slots t % window, and decode must match the full
    forward with windowed masking (exercises the prefill roll + ring write).
    """
    import dataclasses
    from repro.models.model import _dtype, _positions
    from repro.models.common import embed_tokens, rmsnorm, unembed
    from repro.models.transformer import run_backbone

    cfg = ARCHS["h2o-danube-1.8b"].smoke()          # sliding_window=32
    cfg = dataclasses.replace(cfg, max_cache_len=64)
    params = init_model(cfg, key)
    seq = 49                                        # > window, not multiple
    full = make_smoke_batch(cfg, key, batch=2, seq=seq + 1)

    prompt = {"tokens": full["tokens"][:, :seq]}
    cache = init_cache(cfg, 2, cfg.max_cache_len)
    _, cache = forward_prefill(cfg, params, prompt, cache)
    logits_a, _ = forward_decode(cfg, params, full["tokens"][:, seq:seq + 1],
                                 cache)

    x = embed_tokens(params["embed"], full["tokens"], _dtype(cfg))
    pos = _positions(cfg, 2, jnp.zeros((2,), jnp.int32), seq + 1)
    h, _, _ = run_backbone(cfg, params["backbone"], x, mode="train",
                           positions=pos)
    h = rmsnorm(h, params["final_ln"])
    logits_b = unembed(params["embed"], h, tie=cfg.tie_embeddings,
                       final_softcap=cfg.final_softcap)[:, -1]
    np.testing.assert_allclose(np.array(logits_a), np.array(logits_b),
                               rtol=2e-2, atol=2e-2)
