"""repro.serve.dispatch: flush policy, backpressure, deadlines, warm starts."""
import time

import numpy as np
import pytest

from conftest import make_system
from repro import obs
from repro.serve import (AsyncDispatcher, DispatchConfig, QueueFullError,
                         ServeConfig, SolveRequest, SolverServeEngine)


def _lstsq(x, y):
    return np.linalg.lstsq(np.asarray(x, np.float64),
                           np.asarray(y, np.float64), rcond=None)[0]


def _req(x, y, **kw):
    kw.setdefault("method", "bakp_gram")
    kw.setdefault("thr", 8)
    kw.setdefault("max_iter", 60)
    kw.setdefault("rtol", 1e-12)
    return SolveRequest(x=x, y=y, **kw)


# ------------------------------------------------------- flush policy (unit)
class TestFlushPolicy:
    """Drive _admit/_fire_ready directly — no threads, no timing races."""

    def _dispatcher(self, **kw):
        return AsyncDispatcher(SolverServeEngine(),
                               DispatchConfig(prewarm_cache=False, **kw))

    def _ticket(self, disp, req, deadline_s=None):
        from repro.serve.dispatch import SolveTicket
        t = SolveTicket(req, None if deadline_s is None
                        else obs.now() + deadline_s)
        disp._admit(t)
        return t

    def test_fires_when_full(self, rng):
        disp = self._dispatcher(max_batch=3, idle_timeout_s=1e9)
        x, y, _ = make_system(rng, 40, 4)
        for _ in range(2):
            self._ticket(disp, _req(x, y, design_key="d"))
        assert disp._fire_ready(obs.now()) == []
        self._ticket(disp, _req(x, y, design_key="d"))
        fired = disp._fire_ready(obs.now())
        assert len(fired) == 1 and len(fired[0][2]) == 3
        assert fired[0][0].label == "single:xla"
        assert disp.stats.fired_full == 1
        assert not disp._pending

    def test_deadline_ordered_flushing(self, rng):
        """The batch holding the most urgent deadline fires first, even when
        a looser-deadline batch was admitted earlier."""
        disp = self._dispatcher(max_batch=100, idle_timeout_s=1e9,
                                deadline_margin_s=0.5)
        x1, y1, _ = make_system(rng, 40, 4)
        x2, y2, _ = make_system(rng, 400, 40)  # different bucket
        loose = self._ticket(disp, _req(x1, y1, design_key="a"),
                             deadline_s=0.2)
        tight = self._ticket(disp, _req(x2, y2, design_key="b"),
                             deadline_s=0.1)
        fired = disp._fire_ready(obs.now())
        assert [b[2][0] for b in fired] == [tight, loose]
        assert [b[1] for b in fired] == sorted(b[1] for b in fired)
        assert disp.stats.fired_deadline == 2

    def test_burst_fires_in_max_batch_chunks(self, rng):
        """max_batch bounds each fired solve even when a burst lands in
        one dispatch iteration."""
        disp = self._dispatcher(max_batch=4, idle_timeout_s=1e9)
        x, y, _ = make_system(rng, 40, 4)
        for _ in range(10):
            self._ticket(disp, _req(x, y, design_key="d"))
        fired = disp._fire_ready(obs.now())
        assert [len(c) for _, _, c in fired] == [4, 4, 2]
        assert disp.stats.fired_full == 3

    def test_deadline_not_fired_outside_margin(self, rng):
        disp = self._dispatcher(max_batch=100, idle_timeout_s=1e9,
                                deadline_margin_s=0.01)
        x, y, _ = make_system(rng, 40, 4)
        self._ticket(disp, _req(x, y, design_key="d"), deadline_s=60.0)
        assert disp._fire_ready(obs.now()) == []

    def test_idle_timeout_fires(self, rng):
        disp = self._dispatcher(max_batch=100, idle_timeout_s=0.01)
        x, y, _ = make_system(rng, 40, 4)
        self._ticket(disp, _req(x, y, design_key="d"))
        assert disp._fire_ready(obs.now()) == []
        time.sleep(0.02)
        fired = disp._fire_ready(obs.now())
        assert len(fired) == 1
        assert disp.stats.fired_idle == 1

    def test_invalid_request_fails_ticket_at_admit(self, rng):
        disp = self._dispatcher()
        x, y, _ = make_system(rng, 40, 4)
        t = self._ticket(disp, SolveRequest(x=x, y=y[:-1]))
        assert t.done()
        with pytest.raises(ValueError, match="y must be"):
            t.result(timeout=0)


# ----------------------------------------------------------- backpressure
class TestBackpressure:
    def test_reject_policy_raises(self, rng):
        """With nothing firing, the (max_queue+1)-th submit is rejected."""
        x, y, _ = make_system(rng, 40, 4)
        cfg = DispatchConfig(max_queue=3, backpressure="reject",
                             max_batch=100, idle_timeout_s=1e9)
        with AsyncDispatcher(SolverServeEngine(), cfg) as disp:
            tickets = [disp.submit(_req(x, y, design_key="d"))
                       for _ in range(3)]
            with pytest.raises(QueueFullError):
                disp.submit(_req(x, y, design_key="d"))
            assert disp.stats.rejected == 1
            # Accepted requests still complete on drain.
            assert disp.drain(timeout=120)
            assert all(t.result(timeout=1).ok for t in tickets)

    def test_block_policy_completes_everything(self, rng):
        x, y, _ = make_system(rng, 40, 4)
        cfg = DispatchConfig(max_queue=2, backpressure="block",
                             max_batch=2, idle_timeout_s=0.005)
        with AsyncDispatcher(SolverServeEngine(), cfg) as disp:
            tickets = [disp.submit(_req(x, y, design_key="d"))
                       for _ in range(6)]  # blocks, never raises
            assert disp.drain(timeout=120)
        assert all(t.result(timeout=1).ok for t in tickets)
        assert disp.stats.rejected == 0
        assert disp.stats.submitted == 6

    def test_bad_backpressure_rejected(self):
        with pytest.raises(ValueError, match="backpressure"):
            AsyncDispatcher(config=DispatchConfig(backpressure="drop"))

    def test_stop_without_drain_fails_pending(self, rng):
        """stop(drain=False) abandons queued work instead of serving it."""
        from repro.serve import DispatcherStopped
        x, y, _ = make_system(rng, 40, 4)
        cfg = DispatchConfig(max_batch=100, idle_timeout_s=1e9)
        disp = AsyncDispatcher(SolverServeEngine(), cfg).start()
        tickets = [disp.submit(_req(x, y, design_key="d")) for _ in range(3)]
        disp.stop(drain=False)
        for t in tickets:
            assert t.done()
            with pytest.raises(DispatcherStopped):
                t.result(timeout=1)
        with pytest.raises(DispatcherStopped):
            disp.submit(_req(x, y))


# ------------------------------------------------------------- end to end
class TestAsyncEndToEnd:
    def test_matches_synchronous_engine(self, rng):
        """Same requests through the dispatcher and a plain engine flush
        produce identical coefficients (same batching, same programs)."""
        x_shared = rng.normal(size=(300, 24)).astype(np.float32)
        reqs = []
        for i in range(4):  # same design -> multi-RHS group
            a = rng.normal(size=(24,)).astype(np.float32)
            reqs.append((x_shared, x_shared @ a, "s"))
        for i in range(2):  # unique designs, same bucket -> vmap
            xu = rng.normal(size=(290, 20)).astype(np.float32)
            reqs.append((xu, xu @ np.ones(20, np.float32), f"u{i}"))

        sync = SolverServeEngine().serve(
            [_req(x, y, thr=16, design_key=k) for x, y, k in reqs])

        cfg = DispatchConfig(max_batch=len(reqs), idle_timeout_s=0.01)
        with AsyncDispatcher(SolverServeEngine(), cfg) as disp:
            tickets = [disp.submit(_req(x, y, thr=16, design_key=k))
                       for x, y, k in reqs]
            results = [t.result(timeout=120) for t in tickets]

        for s, r in zip(sync, results):
            assert r.ok
            assert r.batch_kind == s.batch_kind
            np.testing.assert_array_equal(r.coef, s.coef)

    def test_deadline_reporting(self, rng):
        x, y, _ = make_system(rng, 40, 4)
        cfg = DispatchConfig(max_batch=4, idle_timeout_s=0.005)
        with AsyncDispatcher(SolverServeEngine(), cfg) as disp:
            tickets = [disp.submit(_req(x, y, design_key="d"),
                                   deadline_s=120.0) for _ in range(4)]
            results = [t.result(timeout=120) for t in tickets]
        assert all(r.ok for r in results)
        assert all(t.deadline_met for t in tickets)
        assert all(t.latency_s is not None and t.latency_s >= 0
                   for t in tickets)
        assert disp.stats.deadline_misses == 0
        assert disp.stats.deadline_hit_rate == 1.0
        assert disp.stats.completed == 4


# -------------------------------------------------------------- warm starts
class TestWarmStart:
    def test_warm_matches_cold_within_rtol(self, rng):
        """A tenant's warm-started re-solve lands on the cold answer."""
        x = rng.normal(size=(300, 24)).astype(np.float32)
        a = rng.normal(size=(24,)).astype(np.float32)
        a2 = a + 0.01 * rng.normal(size=24).astype(np.float32)

        warm_eng = SolverServeEngine()
        warm_eng.serve([_req(x, x @ a, thr=16, design_key="d",
                             tenant_id="t")])
        warm, = warm_eng.serve([_req(x, x @ a2, thr=16, design_key="d",
                                     tenant_id="t")])
        cold, = SolverServeEngine().serve(
            [_req(x, x @ a2, thr=16, design_key="d")])

        assert warm.warm_start and not cold.warm_start
        np.testing.assert_allclose(warm.coef, cold.coef, rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(warm.coef, _lstsq(x, x @ a2), rtol=1e-3,
                                   atol=1e-3)

    def test_warm_and_cold_coalesce(self, rng):
        """Warm and cold tenants merge into ONE multi-RHS solve and each
        still gets the right answer (cold rides a zero a0 column)."""
        x = rng.normal(size=(300, 24)).astype(np.float32)
        eng = SolverServeEngine()
        a_warm = rng.normal(size=(24,)).astype(np.float32)
        eng.serve([_req(x, x @ a_warm, thr=16, design_key="d",
                        tenant_id="veteran")])

        a_new = rng.normal(size=(24,)).astype(np.float32)
        drifted = a_warm + 0.01 * rng.normal(size=24).astype(np.float32)
        out = eng.serve([
            _req(x, x @ drifted, thr=16, design_key="d",
                 tenant_id="veteran"),
            _req(x, x @ a_new, thr=16, design_key="d", tenant_id="rookie"),
        ])
        assert [r.batch_kind for r in out] == ["multi_rhs"] * 2
        assert out[0].warm_start and not out[1].warm_start
        np.testing.assert_allclose(out[0].coef, _lstsq(x, x @ drifted),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(out[1].coef, _lstsq(x, x @ a_new),
                                   rtol=1e-3, atol=1e-3)
        assert eng.stats.warm_starts == 1

    def test_explicit_a0_beats_cached(self, rng):
        x = rng.normal(size=(64, 8)).astype(np.float32)
        a = rng.normal(size=(8,)).astype(np.float32)
        eng = SolverServeEngine()
        eng.serve([_req(x, x @ a, design_key="d", tenant_id="t")])
        # Explicit a0 equal to the exact answer: 0-sweep convergence via
        # rtol on the already-stalled residual would still take a sweep;
        # instead check it is used (warm flag) and exact.
        served, = eng.serve([_req(x, x @ a, design_key="d", tenant_id="t",
                                  a0=a)])
        assert served.warm_start
        np.testing.assert_allclose(served.coef, a, rtol=1e-4, atol=1e-5)

    def test_warm_reduces_sweeps(self, rng):
        x = rng.normal(size=(400, 32)).astype(np.float32)
        a = rng.normal(size=(32,)).astype(np.float32)
        drift = a + 0.001 * rng.normal(size=32).astype(np.float32)
        kw = dict(thr=16, rtol=1e-4, max_iter=100, design_key="d")
        eng = SolverServeEngine()
        eng.serve([_req(x, x @ a, tenant_id="t", **kw)])
        warm, = eng.serve([_req(x, x @ drift, tenant_id="t", **kw)])
        cold, = SolverServeEngine().serve([_req(x, x @ drift, **kw)])
        assert warm.warm_start
        assert warm.n_sweeps < cold.n_sweeps

    def test_warm_cache_off_stays_cold(self, rng):
        x = rng.normal(size=(64, 8)).astype(np.float32)
        eng = SolverServeEngine(ServeConfig(warm_cache=False))
        eng.serve([_req(x, x[:, 0], design_key="d", tenant_id="t")])
        served, = eng.serve([_req(x, x[:, 0], design_key="d",
                                  tenant_id="t")])
        assert not served.warm_start
        assert eng.stats.warm_starts == 0

    def test_vmap_path_warm_and_cold(self, rng):
        """Distinct-design (vmap) batches thread per-row a0 with zero rows
        for cold members."""
        x1 = rng.normal(size=(300, 24)).astype(np.float32)
        x2 = rng.normal(size=(300, 24)).astype(np.float32)
        a1 = rng.normal(size=(24,)).astype(np.float32)
        a2 = rng.normal(size=(24,)).astype(np.float32)
        out = SolverServeEngine().serve([
            _req(x1, x1 @ a1, thr=16, a0=a1 * 0.99),
            _req(x2, x2 @ a2, thr=16),
        ])
        assert [r.batch_kind for r in out] == ["vmap"] * 2
        assert out[0].warm_start and not out[1].warm_start
        np.testing.assert_allclose(out[0].coef, a1, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(out[1].coef, a2, rtol=1e-3, atol=1e-3)

    def test_a0_broadcasts_across_rhs(self, rng):
        """A (vars,) a0 with multi-RHS y warm-starts every column."""
        import jax.numpy as jnp
        from repro.core import solvebak, solvebakp
        x = rng.normal(size=(100, 8)).astype(np.float32)
        a = rng.normal(size=(8,)).astype(np.float32)
        ys = np.stack([x @ a, x @ a], 1)
        r1 = solvebak(jnp.asarray(x), jnp.asarray(ys), max_iter=30,
                      a0=jnp.asarray(a))
        r2 = solvebakp(jnp.asarray(x), jnp.asarray(ys), thr=4, max_iter=30,
                       a0=jnp.asarray(a))
        for r in (r1, r2):
            np.testing.assert_allclose(np.asarray(r.coef),
                                       np.stack([a, a], 1), rtol=1e-4,
                                       atol=1e-5)

    def test_bad_a0_shape_rejected(self, rng):
        x, y, _ = make_system(rng, 50, 4)
        with pytest.raises(ValueError, match="a0 must be"):
            SolverServeEngine().submit(
                SolveRequest(x=x, y=y, a0=np.zeros(3, np.float32)))


# ---------------------------------------------- flush exception safety
class TestFlushExceptionSafety:
    """Regression: a solver raising mid-flush used to abort the whole flush,
    losing every already-dequeued request."""

    def test_poisoned_request_cannot_wedge_engine(self, rng):
        x, y, _ = make_system(rng, 64, 8)
        # retry_ladder=False: this test pins the raw isolation property —
        # with the ladder on the poisoned request is *recovered* instead
        # (covered in test_resilience.py).
        eng = SolverServeEngine(ServeConfig(retry_ladder=False))
        # thr=0 explodes inside solvebakp at trace time — after submit-time
        # validation, exactly the "poisoned request" class.
        poisoned = _req(x, y, method="bakp", thr=0, max_iter=5)
        healthy = [_req(x, y, design_key="d") for _ in range(2)]
        out = eng.serve([healthy[0], poisoned, healthy[1]])
        assert [r.ok for r in out] == [True, False, True]
        assert out[1].batch_kind == "error"
        assert "ZeroDivisionError" in out[1].error
        assert not out[1].converged
        np.testing.assert_allclose(out[0].coef, _lstsq(x, y), rtol=1e-3,
                                   atol=1e-3)
        assert eng.stats.failures == 1
        # The engine is not wedged: the next flush serves normally.
        again, = eng.serve([_req(x, y, design_key="d")])
        assert again.ok and again.cache_hit

    def test_poisoned_multi_rhs_group_isolated(self, rng, monkeypatch):
        """One group's failure doesn't take down sibling groups in the
        same flush."""
        x1 = rng.normal(size=(64, 8)).astype(np.float32)
        x2 = rng.normal(size=(64, 8)).astype(np.float32)
        eng = SolverServeEngine()
        real = eng._call_solver

        def boom(spec, entry, y_dev, atol, a0=None, placement=None):
            # The cached PreparedDesign's fingerprint is the design_key.
            if entry.fingerprint == "bad":
                raise RuntimeError("injected solver failure")
            return real(spec, entry, y_dev, atol, a0=a0, placement=placement)

        monkeypatch.setattr(eng, "_call_solver", boom)
        out = eng.serve([
            _req(x1, x1[:, 0], design_key="bad"),
            _req(x1, x1[:, 1], design_key="bad"),
            _req(x2, x2[:, 0], design_key="good"),
            _req(x2, x2[:, 1], design_key="good"),
        ])
        assert [r.ok for r in out] == [False, False, True, True]
        assert all("injected" in r.error for r in out[:2])
        assert eng.stats.failures == 2

    def test_failed_deadline_ticket_counts_as_miss(self, rng, monkeypatch):
        """A batch whose engine.serve raises marks deadline-carrying
        tickets as misses (hit rate must not be inflated by failures)."""
        x, y, _ = make_system(rng, 64, 8)
        eng = SolverServeEngine()
        monkeypatch.setattr(
            eng, "serve",
            lambda reqs: (_ for _ in ()).throw(RuntimeError("boom")))
        cfg = DispatchConfig(max_batch=1, idle_timeout_s=0.005)
        with AsyncDispatcher(eng, cfg) as disp:
            t = disp.submit(_req(x, y), deadline_s=120.0)
            with pytest.raises(RuntimeError, match="boom"):
                t.result(timeout=120)
        assert t.deadline_met is False
        assert disp.stats.deadline_misses == 1
        assert disp.stats.deadline_hit_rate == 0.0

    def test_dispatcher_surfaces_error_results(self, rng):
        x, y, _ = make_system(rng, 64, 8)
        cfg = DispatchConfig(max_batch=2, idle_timeout_s=0.005)
        eng = SolverServeEngine(ServeConfig(retry_ladder=False))
        with AsyncDispatcher(eng, cfg) as disp:
            bad = disp.submit(_req(x, y, method="bakp", thr=0, max_iter=5))
            good = disp.submit(_req(x, y, design_key="d"))
            bad_r = bad.result(timeout=120)
            good_r = good.result(timeout=120)
        assert not bad_r.ok and "ZeroDivisionError" in bad_r.error
        assert good_r.ok
