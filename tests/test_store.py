"""Tiered design store (repro.store) + streaming out-of-core solves.

Covers the PR 9 subsystem end to end:

  * tier transitions — admit / demote (device → host → disk) / promote with
    byte accounting, LRU victim order, disk tile round trips and the
    no-disk-tier X-byte drop that keeps a state-only stub;
  * the eviction warm-start regression fix — per-tenant warm coefficients
    (and Cholesky factors, norms, home lane) survive demotion and restore
    on promotion;
  * streaming solve parity — ``"bakp_stream"`` (double-buffered HBM kernel
    AND the store's host block loop) against ``bakp``/``bakp_fused`` across
    single/multi-RHS x warm/cold x early-exit;
  * the store-backed engine — over-budget workloads serve to completion
    with demotion → promotion churn, over-HBM requests reroute to the
    streaming method, and a concurrent-submitter hammer stays correct.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_system
from repro import obs
from repro.core.prepare import prepare
from repro.core.solvebakp import solvebakp
from repro.core.spec import (SolverSpec, UnsupportedSpecError, solver_method,
                             streaming_methods)
from repro.kernels import (fused_solve, solvebakp_stream_kernel, stream_fits,
                           stream_solve, stream_solve_blocks,
                           stream_vmem_bytes, stream_x_resident_bytes)
from repro.serve import (AsyncDispatcher, DispatchConfig, ServeConfig,
                         SolveRequest, SolverServeEngine)
from repro.store import DesignStore, HostDesign, StoreBlockSource


def _store(**kw):
    kw.setdefault("registry", obs.MetricsRegistry())
    return DesignStore(**kw)


def _design(rng, obs_n=96, vars_n=64):
    return rng.normal(size=(obs_n, vars_n)).astype(np.float32)


# ------------------------------------------------------------ registry facts
class TestRegistry:
    def test_stream_method_capabilities(self):
        entry = solver_method("bakp_stream")
        assert entry.streams and entry.iterative and entry.multi_rhs
        assert not entry.batchable and not entry.shardable
        assert entry.lane == "stream"
        assert streaming_methods() == ("bakp_stream",)
        # every other method is resident-only
        assert not solver_method("bakp").streams
        assert not solver_method("bakp_fused").streams

    def test_vmem_accounting(self):
        # the streamed x working set is two tiles, independent of vars
        assert (stream_x_resident_bytes(32, 128, 4)
                == 2 * 32 * 128 * 4)
        # doubling vars only grows the O(vars) accumulators (coef + a0 +
        # inv_cn = 12 bytes/var at k=1), never the x scratch
        grown = (stream_vmem_bytes(8192, 128, 1, 4, block=32)
                 - stream_vmem_bytes(4096, 128, 1, 4, block=32))
        assert grown == (8192 - 4096) * (2 * 4 + 4)
        assert stream_fits(1 << 20, 128, 1, 4, block=32)


# ---------------------------------------------------------- tier transitions
class TestTierTransitions:
    def test_admit_demote_promote_round_trip(self, rng):
        st = _store(device_bytes=None)
        x = _design(rng)
        entry = st.build("a", x)
        assert st.tier("a") == "device" and len(st) == 1
        assert st.device_used() == x.nbytes
        # warm a derived layout so the snapshot carries it
        entry.x_t_for(32)
        assert st.device_used() == 2 * x.nbytes

        snap = st.demote("a")
        assert st.tier("a") == "host" and len(st) == 0
        assert 32 in snap.x_t and snap.x_pad is None  # x_t suffices
        assert st.host_used() == snap.nbytes == x.nbytes
        assert st.stats.demotions_device == 1

        back = st.promote("a")
        assert st.tier("a") == "device" and back is not None
        assert np.allclose(np.asarray(back.x_pad), x, atol=1e-6)
        # the promoted entry got the snapshotted x_t prefilled
        with back._lock:
            assert 32 in back._x_t
        assert st.stats.promotions_host == 1

    def test_byte_budget_demotes_lru_not_mru(self, rng):
        x = _design(rng)
        st = _store(device_bytes=2 * x.nbytes)
        st.build("a", x)
        st.build("b", _design(rng))
        st.build("c", _design(rng))  # over budget -> LRU "a" demotes
        assert st.tier("a") == "host"
        assert st.tier("b") == "device" and st.tier("c") == "device"
        st.get("b")  # touch -> "c" becomes LRU
        st.build("d", _design(rng))
        assert st.tier("c") == "host" and st.tier("b") == "device"

    def test_last_entry_never_demoted_by_bytes(self, rng):
        x = _design(rng)
        st = _store(device_bytes=x.nbytes // 2)
        entry = st.build("solo", x)
        # fits-check routes an over-budget design non-resident instead
        assert entry.x_pad is None
        assert st.tier("solo") == "host"
        # but an admitted entry that *grew* over budget (derived layouts)
        # stays when it is the only one
        st2 = _store(device_bytes=x.nbytes + 16)
        e2 = st2.build("solo", x)
        e2.x_t_for(32)  # now ~2x over budget
        st2.admit("solo", e2)
        assert st2.tier("solo") == "device"

    def test_disk_round_trip(self, rng, tmp_path):
        x = _design(rng, 64, 48)
        st = _store(device_bytes=None, host_bytes=1,
                    disk_dir=str(tmp_path / "tiles"))
        entry = st.build("d1", x)
        entry.x_t_for(16)
        st.demote("d1")  # host budget of 1 byte -> straight to disk
        assert st.tier("d1") == "disk"
        assert st.host_used() == 0
        rec = st._disk["d1"]
        assert rec.thr == 16 and rec.nblocks == 3
        assert all(rec.tile_path(j).exists() for j in range(rec.nblocks))
        assert st.disk_used() == rec.nbytes == 3 * 16 * 64 * 4

        back = st.promote("d1")
        assert back is not None and st.tier("d1") == "device"
        assert np.allclose(np.asarray(back.x_pad), x, atol=1e-6)
        assert st.stats.promotions_disk == 1
        assert not (tmp_path / "tiles").joinpath("d1").exists()

    def test_no_disk_dir_drops_x_keeps_state(self, rng):
        x = _design(rng, 64, 32)
        st = _store(device_bytes=None, host_bytes=1, disk_dir=None)
        entry = st.build("s", x)
        entry.store_coef("tenant", np.ones(32, np.float32))
        st.demote("s")
        assert st.stats.x_drops == 1
        assert st.tier("s") == "none"  # no X bytes anywhere
        assert st.promote("s") is None
        # rebuild from source restores the stub's warm state
        fresh = st.build("s", x)
        assert fresh.warm_coef("tenant") is not None

    def test_nonresident_streams_blocks_from_any_tier(self, rng, tmp_path):
        x = _design(rng, 64, 48)
        st = _store(device_bytes=x.nbytes // 2,
                    disk_dir=str(tmp_path / "t"))
        h = st.build("big", x)
        assert h.x_pad is None and isinstance(h.blocks, StoreBlockSource)
        assert h.shape == (64, 48) and not h.resident
        x_t = np.zeros((48, 64), np.float32)
        x_t[:48] = x.T
        for j in range(h.blocks.num_blocks(16)):
            np.testing.assert_allclose(h.blocks.block_t(16, j),
                                       x_t[j * 16:(j + 1) * 16])
        # push the bytes to disk; the same handle keeps serving
        st._demote_to_disk("big")
        assert st.tier("big") == "disk"
        np.testing.assert_allclose(h.blocks.block_t(16, 2), x_t[32:48])
        # ragged block width: last tile zero-padded past vars
        pad_tile = h.blocks.block_t(32, 1)
        assert pad_tile.shape == (32, 64)
        np.testing.assert_allclose(pad_tile[:16], x_t[32:48])
        assert not pad_tile[16:].any()

    def test_nonresident_rejects_resident_methods(self, rng):
        st = _store(device_bytes=16)
        h = st.build("big", _design(rng))
        with pytest.raises(UnsupportedSpecError, match="bakp_stream"):
            h.solve(np.zeros(96, np.float32),
                    spec=SolverSpec(method="bakp", thr=32))
        with pytest.raises(UnsupportedSpecError, match="non-resident"):
            h.x_t_for(32)

    def test_metrics_tiers_and_moves(self, rng, tmp_path):
        reg = obs.MetricsRegistry()
        x = _design(rng, 64, 32)
        st = DesignStore(device_bytes=None, host_bytes=1,
                         disk_dir=str(tmp_path / "t"), registry=reg)
        st.build("m", x)
        assert reg.get("store_bytes").value(tier="device") == x.nbytes
        st.demote("m")  # -> host -> (budget) -> disk
        moves = reg.get("store_promotions_total")
        assert moves.value(**{"from": "device", "to": "host"}) == 1
        assert moves.value(**{"from": "host", "to": "disk"}) == 1
        st.promote("m")
        assert moves.value(**{"from": "disk", "to": "device"}) == 1
        assert reg.get("store_resident").value(tier="device") == 1
        assert reg.get("store_resident").value(tier="disk") == 0
        assert reg.get("store_fetch_latency_seconds").count(tier="disk") == 1


# ----------------------------------------------- warm-start eviction fix
class TestWarmSurvivesEviction:
    def test_store_level(self, rng):
        st = _store(device_bytes=None)
        x = _design(rng, 64, 32)
        entry = st.build("w", x)
        coef = rng.normal(size=32).astype(np.float32)
        entry.store_coef("t0", coef)
        entry.chol_for(16, 1e-6)
        home = entry.bind_home()
        st.demote("w")
        back = st.promote("w")
        np.testing.assert_array_equal(back.warm_coef("t0"), coef)
        assert (16, 1e-6) in back.chol  # Cholesky survived too
        assert back.home == home

    def test_engine_level_regression(self, rng):
        """The PR 9 regression fix: a tenant whose design was evicted
        (demoted) between solves still warm-starts after re-admission.
        Pre-store engines rebuilt a cold entry here and lost the warm
        coefficients silently."""
        x, y, _ = make_system(rng, 96, 48)
        design_bytes = 128 * 64 * 4  # padded bucket
        eng = SolverServeEngine(
            ServeConfig(store_device_bytes=2 * design_bytes),
            registry=obs.MetricsRegistry())

        def req(xx, yy, key, tenant=None):
            return SolveRequest(x=xx, y=yy, method="bakp", thr=16,
                                max_iter=30, rtol=1e-12, design_key=key,
                                tenant_id=tenant)

        [r0] = eng.serve([req(x, y, "target", "t0")])
        assert r0.error is None
        warm_before = eng.stats.warm_starts
        # two other designs -> "target" is demoted off the device tier
        for i in range(2):
            xi, yi, _ = make_system(np.random.default_rng(50 + i), 96, 48)
            eng.serve([req(xi, yi, f"filler-{i}")])
        assert eng.store.tier("target") == "host"
        [r1] = eng.serve([req(x, y, "target", "t0")])
        assert r1.error is None
        assert eng.store.tier("target") == "device"  # promoted back
        assert eng.stats.warm_starts == warm_before + 1
        assert eng.store.stats.promotions_host >= 1
        # promotion counts as a cache hit: the design never rebuilt
        assert eng.cache.stats.misses == 3  # the three cold builds only
        eng.shutdown()


# ------------------------------------------------------------ solve parity
class TestStreamParity:
    @pytest.mark.parametrize("nrhs", [1, 3])
    @pytest.mark.parametrize("warm", [False, True])
    def test_stream_matches_fused_bitwise(self, rng, nrhs, warm):
        x, y, _ = make_system(rng, 64, 64)
        x_t = jnp.asarray(np.ascontiguousarray(x.T))
        if nrhs > 1:
            y = rng.normal(size=(64, nrhs)).astype(np.float32)
        a0 = (rng.normal(size=(64,) if nrhs == 1 else (64, nrhs))
              .astype(np.float32) * 0.1 if warm else None)
        kw = dict(block=32, max_iter=25, atol=0.0, rtol=0.0)
        rs = stream_solve(x_t, jnp.asarray(y), a0=a0, **kw)
        rf = fused_solve(x_t, jnp.asarray(y), a0=a0, **kw)
        # identical math in a different execution schedule: interpret mode
        # evaluates both with the same fp32 ops, so parity is exact
        np.testing.assert_array_equal(np.asarray(rs.coef),
                                      np.asarray(rf.coef))
        np.testing.assert_array_equal(np.asarray(rs.residual),
                                      np.asarray(rf.residual))
        assert int(rs.n_sweeps) == int(rf.n_sweeps)

    @pytest.mark.parametrize("early", [False, True])
    def test_stream_early_exit_matches_fused(self, rng, early):
        x, y, _ = make_system(rng, 256, 32)
        x_t = jnp.asarray(np.ascontiguousarray(np.pad(x, ((0, 0), (0, 0))).T))
        kw = dict(block=16, max_iter=40,
                  rtol=1e-10 if early else 0.0)
        rs = stream_solve(x_t, jnp.asarray(y), **kw)
        rf = fused_solve(x_t, jnp.asarray(y), **kw)
        assert int(rs.n_sweeps) == int(rf.n_sweeps)
        if early:
            assert bool(rs.converged) and int(rs.n_sweeps) < 40
        np.testing.assert_array_equal(np.asarray(rs.coef),
                                      np.asarray(rf.coef))

    @pytest.mark.parametrize("nrhs", [1, 2])
    def test_host_block_loop_matches_xla(self, rng, nrhs):
        x, y, _ = make_system(rng, 80, 48)
        if nrhs > 1:
            y = rng.normal(size=(80, nrhs)).astype(np.float32)
        st = _store(device_bytes=1)  # force non-resident
        h = st.build("p", x)
        res = h.solve(y, spec=SolverSpec(method="bakp_stream", thr=16,
                                         max_iter=30, rtol=0.0))
        ref = solvebakp(x, y, thr=16, max_iter=30)
        np.testing.assert_allclose(np.asarray(res.coef),
                                   np.asarray(ref.coef),
                                   atol=1e-5, rtol=1e-5)
        assert int(res.n_sweeps) == int(ref.n_sweeps)

    def test_host_block_loop_warm_and_early_exit(self, rng):
        x, y, _ = make_system(rng, 256, 32)
        st = _store(device_bytes=1)
        h = st.build("w", x)
        spec = SolverSpec(method="bakp_stream", thr=16, max_iter=60,
                          rtol=1e-10)
        cold = h.solve(y, spec=spec, tenant_id="t")
        assert bool(cold.converged)
        warm = h.solve(y, spec=spec, tenant_id="t")
        assert int(warm.n_sweeps) < int(cold.n_sweeps)
        ref = solvebakp(x, y, thr=16, max_iter=60, rtol=1e-10)
        np.testing.assert_allclose(np.asarray(warm.coef),
                                   np.asarray(ref.coef),
                                   atol=1e-5, rtol=1e-5)

    def test_resident_method_path_matches_bakp(self, rng):
        x, y, _ = make_system(rng, 96, 64)
        p = prepare(x, SolverSpec(method="bakp_stream", thr=32, max_iter=30))
        res = p.solve(y)
        ref = solvebakp(x, y, thr=32, max_iter=30)
        np.testing.assert_allclose(np.asarray(res.coef),
                                   np.asarray(ref.coef),
                                   atol=1e-5, rtol=1e-5)

    def test_ops_entry_and_fallbacks(self, rng, monkeypatch):
        x, y, _ = make_system(rng, 64, 64)
        x_t = jnp.asarray(np.ascontiguousarray(x.T))
        res = solvebakp_stream_kernel(x_t, jnp.asarray(y), block=32,
                                      max_iter=25)
        ref = solvebakp(x, y, thr=32, max_iter=25)
        np.testing.assert_allclose(np.asarray(res.coef),
                                   np.asarray(ref.coef),
                                   atol=1e-5, rtol=1e-5)
        # a budget even the two-tile scratch busts reroutes to the
        # per-sweep stream — same answer
        import importlib
        cd = importlib.import_module("repro.kernels.cd_sweep")
        # under the two-tile scratch (~17 KiB) but over one sweep's
        # working set (~8 KiB), so only the streaming whole-solve fails
        monkeypatch.setattr(cd, "VMEM_BUDGET_BYTES", 10_000)
        r_fb = solvebakp_stream_kernel(x_t, jnp.asarray(y), block=32,
                                       max_iter=25)
        np.testing.assert_allclose(np.asarray(r_fb.coef),
                                   np.asarray(ref.coef),
                                   atol=1e-5, rtol=1e-5)

    def test_stream_rejects_bad_shapes(self, rng):
        x_t = jnp.zeros((48, 64), jnp.float32)  # 48 not a multiple of 32
        with pytest.raises(ValueError, match="multiple"):
            stream_solve(x_t, jnp.zeros(64, jnp.float32), block=32)
        with pytest.raises(ValueError, match="max_iter"):
            stream_solve(jnp.zeros((64, 64), jnp.float32),
                         jnp.zeros(64, jnp.float32), block=32, max_iter=0)

    def test_stream_solve_blocks_direct(self, rng):
        x, y, _ = make_system(rng, 64, 48)
        st = _store(device_bytes=1)
        h = st.build("sb", x)
        inv = np.asarray(prepare(x).inv_cn_for(16))
        res = stream_solve_blocks(h.blocks, jnp.asarray(y), inv_cn=inv,
                                  block=16, max_iter=20)
        ref = solvebakp(x, y, thr=16, max_iter=20)
        np.testing.assert_allclose(np.asarray(res.coef),
                                   np.asarray(ref.coef)[:48],
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------------- store-backed engine
class TestStoreEngine:
    def test_over_budget_fleet_serves_with_churn(self, rng):
        """The PR 9 acceptance workload: 64+ distinct designs whose combined
        bytes exceed the device budget serve to completion, demotion →
        promotion churn is observable, answers match an all-resident
        engine to MAPE <= 1e-4, zero capacity failures."""
        n_designs, obs_n, vars_n = 64, 48, 24
        design_bytes = 64 * 32 * 4  # padded bucket
        reg = obs.MetricsRegistry()
        store_eng = SolverServeEngine(
            ServeConfig(store_device_bytes=8 * design_bytes,
                        cache_entries=256),
            registry=reg)
        base_eng = SolverServeEngine(ServeConfig(cache_entries=256),
                                     registry=obs.MetricsRegistry())
        systems = [make_system(np.random.default_rng(1000 + i), obs_n,
                               vars_n) for i in range(n_designs)]

        def reqs():
            return [SolveRequest(x=x, y=y, method="bakp", thr=8,
                                 max_iter=60, rtol=1e-12,
                                 design_key=f"d{i}", request_id=f"r{i}")
                    for i, (x, y, _) in enumerate(systems)]

        # two passes: the second one's lookups hit demoted designs
        for _ in range(2):
            r_store = store_eng.serve(reqs())
            r_base = base_eng.serve(reqs())
        assert not [r.error for r in r_store if r.error]
        mape = float(np.mean([
            np.mean(np.abs(a.coef - b.coef)
                    / np.maximum(np.abs(b.coef), 1e-12))
            for a, b in zip(r_store, r_base)]))
        assert mape <= 1e-4
        st = store_eng.store.stats
        assert st.demotions_device > 0
        assert st.promotions_host > 0
        assert len(store_eng.store) <= 8  # device tier held its budget
        moves = reg.get("store_promotions_total")
        assert moves.value(**{"from": "device", "to": "host"}) > 0
        assert moves.value(**{"from": "host", "to": "device"}) > 0
        store_eng.shutdown()
        base_eng.shutdown()

    def test_over_hbm_requests_reroute_to_stream(self, rng):
        design_bytes = 64 * 32 * 4
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(
            ServeConfig(store_device_bytes=design_bytes), registry=reg)
        x, y, _ = make_system(rng, 128, 64)  # padded 128x64 > budget
        req = SolveRequest(x=x, y=y, method="bakp", thr=16, max_iter=40,
                           rtol=1e-12, design_key="huge")
        assert eng.spec_for(req, record=True).method == "bakp_stream"
        assert reg.get("solver_fallback_total").value(reason="over_hbm") == 1
        [res] = eng.serve([req])
        assert res.error is None
        assert eng.store.stats.builds_nonresident == 1
        ref = solvebakp(x, y, thr=16, max_iter=40, rtol=1e-12)
        np.testing.assert_allclose(res.coef, np.asarray(ref.coef),
                                   atol=1e-5, rtol=1e-5)
        # small requests keep their method (and an explicit spec wins)
        xs, ys, _ = make_system(rng, 32, 16)
        small = SolveRequest(x=xs, y=ys, method="bakp", thr=8,
                             design_key="small")
        assert eng.spec_for(small).method == "bakp"
        eng.shutdown()

    def test_no_store_config_has_no_store(self):
        eng = SolverServeEngine(ServeConfig(),
                                registry=obs.MetricsRegistry())
        assert eng.store is None and eng.cache.store is None
        eng.shutdown()

    @pytest.mark.slow
    def test_concurrent_submitters_with_churn(self, rng):
        """test_lanes-style hammer on a store-backed engine: racing
        submitters over more designs than the device tier holds — every
        ticket lands with the right answer while designs demote/promote
        under the submitters' feet."""
        design_bytes = 64 * 32 * 4
        eng = SolverServeEngine(
            ServeConfig(store_device_bytes=6 * design_bytes,
                        cache_entries=256),
            registry=obs.MetricsRegistry())
        cfg = DispatchConfig(max_batch=8, idle_timeout_s=0.005,
                             prewarm_cache=True)
        n_sub, per = 4, 10
        systems = {}
        r = np.random.default_rng(77)
        for s in range(n_sub):
            for i in range(per):
                x = r.normal(size=(48, 24)).astype(np.float32)
                a = r.normal(size=(24,)).astype(np.float32)
                systems[(s, i)] = (x, x @ a, a)
        tickets, tlock, errs = {}, threading.Lock(), []

        def submitter(s, disp):
            try:
                for i in range(per):
                    x, y, _ = systems[(s, i)]
                    # design keys collide across submitters -> churn +
                    # build races on one key
                    t = disp.submit(SolveRequest(
                        x=x, y=y, method="bakp", thr=8, max_iter=60,
                        rtol=1e-12, design_key=f"d-{(s + i) % 13}-{i}",
                        request_id=f"q-{s}-{i}"))
                    with tlock:
                        tickets[(s, i)] = t
            except Exception as exc:  # pragma: no cover - surfaced below
                errs.append(exc)

        with AsyncDispatcher(eng, cfg) as disp:
            threads = [threading.Thread(target=submitter, args=(s, disp))
                       for s in range(n_sub)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errs
            results = {k: t.result(timeout=120.0)
                       for k, t in tickets.items()}
        assert len(results) == n_sub * per
        for (s, i), res in results.items():
            x, y, a = systems[(s, i)]
            pred = x @ res.coef
            denom = np.maximum(np.abs(y), 1e-12)
            # fp32 stall floor for this small, square-ish geometry
            assert float(np.mean(np.abs(pred - y) / denom)) <= 5e-3
        assert eng.store.stats.demotions_device > 0
        assert len(eng.store) <= 6
        eng.shutdown()
