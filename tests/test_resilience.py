"""Resilience layer (PR 10): fault injection, supervised lanes, the
retry/degradation ladder and the crash-safe store.

Covers the chaos story end to end:

  * the ``repro.resilience.faults`` harness — plan coercion (dict / JSON /
    file), rule arming semantics (count / skip / match), the zero-cost
    disarmed path;
  * the ``ladder`` policy — precision-before-method rung order, registry
    fallback chains bottoming out at ``lstsq``, jittered backoff bounds;
  * supervised lanes — a dying worker thread fails only the in-flight
    unit, restarts with ``serve_lane_restarts_total`` / ``serve_lane_health``
    transitions, and a repeatedly-crashing lane trips its circuit breaker
    onto the serial fallback lane;
  * the engine ladder — raised solves retry to success, forced-diverged
    solves never poison the per-tenant warm-coefficient store, exhausted /
    deadline-bounded ladders return typed errors, vmapped batches degrade
    to per-request solves;
  * ticket hygiene — ``SolveTicket.cancel()`` settles abandoned waiters so
    ``drain()`` cannot hang on a leaked ticket;
  * the crash-safe store — CRC-headered atomic tile writes, corrupt tiles
    detected on promotion, quarantined and rebuilt from the design source.
"""
import json
import time
import zlib

import numpy as np
import pytest

from conftest import make_system
from repro import obs
from repro.resilience import (FaultInjected, FaultPlan, backoff_s, faults,
                              installed, next_rung, rungs)
from repro.core.spec import SolverSpec
from repro.serve import (AsyncDispatcher, DispatchConfig, LaneKey, LanePool,
                         LaneShutdown, LaneWork, LaneWorkerDeath, ServeConfig,
                         SolveRequest, SolverServeEngine, TicketCancelled)
from repro.serve.lanes import SERIAL_LANE
from repro.store import DesignStore
from repro.store.store import (TileCorruptionError, _TILE_HEADER,
                               _TILE_MAGIC)


@pytest.fixture(autouse=True)
def _disarmed():
    """Never leak an armed plan into (or out of) a test."""
    faults.clear()
    yield
    faults.clear()


def _req(x, y, **kw):
    kw.setdefault("max_iter", 40)
    kw.setdefault("rtol", 1e-12)
    return SolveRequest(x=x, y=y, **kw)


def _wait_for(pred, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return pred()


# ------------------------------------------------------------ fault harness
class TestFaultPlan:
    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultPlan().add("lane.wrong")

    def test_coerce_dict_json_file_and_passthrough(self, tmp_path):
        spec = {"solver.raise": {"count": 2, "match": "bakp"}}
        for obj in (spec, json.dumps(spec)):
            plan = FaultPlan.coerce(obj)
            rule = plan.rules["solver.raise"]
            assert rule.count == 2 and rule.match == "bakp"
        p = tmp_path / "plan.json"
        p.write_text(json.dumps(spec))
        assert FaultPlan.coerce(str(p)).rules["solver.raise"].count == 2
        plan = FaultPlan(spec)
        assert FaultPlan.coerce(plan) is plan
        with pytest.raises(ValueError, match="JSON object"):
            FaultPlan.from_json("[1, 2]")
        with pytest.raises(TypeError):
            FaultPlan.coerce(42)

    def test_count_skip_match_semantics(self):
        plan = FaultPlan()
        plan.add("solver.raise", count=2, skip=1, match="bakp")
        assert plan.hit("solver.raise", "lstsq") is None    # match filter
        assert plan.hit("solver.raise", "bakp") is None     # skipped
        assert plan.hit("solver.raise", "bakp") is not None
        assert plan.hit("solver.raise", "bakp_gram") is not None
        assert plan.hit("solver.raise", "bakp") is None     # count spent
        assert plan.counts()["solver.raise"] == {"seen": 4, "fired": 2}

    def test_disarmed_hooks_are_noops(self):
        assert faults.active() is None
        assert faults.hit("solver.raise", "bakp") is None
        faults.maybe_raise("solver.raise", "bakp")          # no-op
        assert not faults.maybe_delay("store.read_delay", "k")

    def test_installed_context_arms_and_disarms(self):
        with installed({"solver.raise": {"count": 1}}) as plan:
            assert faults.active() is plan
            with pytest.raises(FaultInjected, match="solver.raise"):
                faults.maybe_raise("solver.raise", "bakp")
        assert faults.active() is None


# ------------------------------------------------------------ ladder policy
class TestLadder:
    def test_precision_degrades_before_method(self):
        spec = SolverSpec(method="bakp_fused", precision="bf16")
        rung = next_rung(spec)
        assert rung.method == "bakp_fused" and rung.precision == "fp32"

    def test_registry_chain_bottoms_at_lstsq(self):
        chain = [s.method for s in rungs(SolverSpec(method="bakp_fused"))]
        assert chain == ["bakp", "bakp_stream", "lstsq"]
        assert [s.method for s in rungs(SolverSpec(method="bak_fused"))] \
            == ["bak", "lstsq"]
        assert rungs(SolverSpec(method="lstsq")) == []

    def test_backoff_bounded_and_jittered(self):
        assert backoff_s(0, 0.0) == 0.0
        for attempt in range(8):
            d = backoff_s(attempt, 0.002, cap=0.05)
            assert 0.0 < d <= 0.05 * 1.5


# --------------------------------------------------- supervised lanes (pure)
class TestLaneSupervision:
    def test_worker_death_fails_only_inflight_and_restarts(self):
        reg = obs.MetricsRegistry()
        pool = LanePool(registry=reg)
        key = LaneKey("single:test")
        with installed({"lane.worker": {"count": 1, "match": "single:test"}}):
            dead = pool.submit(key, LaneWork(lambda: None))
            assert dead.wait(10.0)
            assert isinstance(dead.error, LaneWorkerDeath)
            assert isinstance(dead.error.__cause__, FaultInjected)
            # the replacement thread serves the next work normally
            ok = pool.submit(key, LaneWork(lambda: None))
            assert ok.wait(10.0) and ok.error is None
        stats = pool.stats()["single:test"]
        assert stats["restarts"] == 1 and stats["failures"] == 1
        assert not stats["tripped"]
        assert reg.get("serve_lane_restarts_total").value(
            lane="single:test") == 1
        assert _wait_for(lambda: reg.get("serve_lane_health").value(
            lane="single:test") == 1.0)
        pool.shutdown()

    def test_circuit_breaker_trips_to_serial(self):
        reg = obs.MetricsRegistry()
        pool = LanePool(registry=reg, max_restarts=0)
        key = LaneKey("single:test")
        ran = []
        with installed({"lane.worker": {"count": 0, "match": "single:test"}}):
            first = pool.submit(key, LaneWork(lambda: ran.append("w0")))
            assert first.wait(10.0)
            assert isinstance(first.error, LaneWorkerDeath)
            assert _wait_for(lambda: pool.executor(key).tripped)
            # tripped lane reroutes new work to the serial fallback lane
            works = [pool.submit(key, LaneWork(lambda i=i: ran.append(i)))
                     for i in range(3)]
            for w in works:
                assert w.wait(10.0) and w.error is None
        assert sorted(ran) == [0, 1, 2]
        assert pool.stats()["single:test"]["tripped"]
        assert reg.get("serve_lane_health").value(lane="single:test") == 0.0
        assert pool.stats()[SERIAL_LANE.label]["requests"] >= 0
        # direct submission to the tripped executor is refused
        with pytest.raises(LaneShutdown):
            pool.executor(key).submit(LaneWork(lambda: None))
        pool.shutdown()

    def test_engine_survives_lane_death(self, rng):
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        systems = [make_system(np.random.default_rng(40 + i), 64, 16)
                   for i in range(4)]
        with installed({"lane.worker": {"count": 1, "match": "single:"}}):
            out = eng.serve([
                _req(x, y, method="bakp_gram", thr=8, design_key=f"ld{i}",
                     request_id=f"ld{i}")
                for i, (x, y, _) in enumerate(systems)])
        failed = [r for r in out if r.error]
        assert failed, "the injected worker death must fail its unit"
        assert all("LaneWorkerDeath" in r.error for r in failed)
        # the engine did NOT raise, and the restarted lane keeps serving
        again = eng.serve([
            _req(x, y, method="bakp_gram", thr=8, design_key=f"ld{i}")
            for i, (x, y, _) in enumerate(systems)])
        assert not [r.error for r in again if r.error]
        assert reg.get("serve_lane_restarts_total").value(
            lane="single:xla") == 1
        eng.shutdown()


# -------------------------------------------------------- engine ladder
class TestRetryLadder:
    def test_raised_solve_retries_to_success(self, rng):
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        x, y, a = make_system(rng, 64, 16)
        with installed({"solver.raise": {"count": 1}}):
            [res] = eng.serve([_req(x, y, method="bakp_gram", thr=8,
                                    design_key="rl")])
        assert res.ok and res.retries == 1
        assert res.telemetry is not None and res.telemetry.retries == 1
        assert eng.stats.retries == 1
        ctr = reg.get("solver_retries_total")
        assert ctr.value(reason="raise", from_path="bakp_gram",
                         to_path="bakp") == 1
        denom = np.maximum(np.abs(a), 1e-12)
        assert float(np.mean(np.abs(res.coef - a) / denom)) <= 1e-4
        eng.shutdown()

    def test_ladder_off_returns_typed_error(self, rng):
        eng = SolverServeEngine(ServeConfig(retry_ladder=False),
                                registry=obs.MetricsRegistry())
        x, y, _ = make_system(rng, 64, 16)
        with installed({"solver.raise": {"count": 1}}):
            [res] = eng.serve([_req(x, y, method="bakp_gram", thr=8,
                                    design_key="off")])
        assert not res.ok and res.retries == 0
        assert "FaultInjected" in res.error
        assert eng.stats.retries == 0
        eng.shutdown()

    def test_expired_deadline_bounds_the_ladder(self, rng):
        eng = SolverServeEngine(ServeConfig(), registry=obs.MetricsRegistry())
        x, y, _ = make_system(rng, 64, 16)
        req = _req(x, y, method="bakp_gram", thr=8, design_key="dl")
        req.deadline_at = obs.now() - 1.0  # already expired: no retry budget
        with installed({"solver.raise": {"count": 1}}):
            [res] = eng.serve([req])
        assert not res.ok and "FaultInjected" in res.error
        assert eng.stats.retries == 0
        eng.shutdown()

    def test_forced_diverge_cold_retries_then_falls_back(self, rng):
        """An unlimited forced-diverge rule walks the full recovery order:
        warm poison → (cold) same rung → method fallbacks → floor; the
        last diverged result serves (flagged, never an exception)."""
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(max_retries=2), registry=reg)
        x, y, _ = make_system(rng, 64, 16)
        [warm] = eng.serve([_req(x, y, method="bakp_gram", thr=8,
                                 design_key="fd", tenant_id="t")])
        assert warm.ok
        with installed({"solver.diverge": {"count": 0}}):
            [res] = eng.serve([_req(x, y, method="bakp_gram", thr=8,
                                    design_key="fd", tenant_id="t")])
        assert res.error is None       # diverged ≠ failed: still served
        assert res.retries == 2
        ctr = reg.get("solver_retries_total")
        assert ctr.value(reason="warm_poison", from_path="bakp_gram+warm",
                         to_path="bakp_gram") == 1
        assert ctr.value(reason="forced_diverge", from_path="bakp_gram",
                         to_path="bakp") == 1
        eng.shutdown()

    def test_diverged_solve_never_poisons_warm_store(self, rng):
        """Satellite regression: a diverged solve must NOT retain its
        coefficients for the tenant's next warm start."""
        eng = SolverServeEngine(ServeConfig(retry_ladder=False),
                                registry=obs.MetricsRegistry())
        x, y, _ = make_system(rng, 64, 16)
        req = lambda: _req(x, y, method="bakp_gram", thr=8,  # noqa: E731
                           design_key="wp", tenant_id="t0")
        [good] = eng.serve([req()])
        assert good.ok
        entry = eng.cache.get("wp", record_stats=False)
        before = np.array(entry.warm_coef("t0"), copy=True)
        with installed({"solver.diverge": {"count": 1}}):
            [bad] = eng.serve([req()])
        # a forced diverge is served (it is a retention decision, not an
        # error): only the warm store must be left untouched
        assert bad.error is None
        after = entry.warm_coef("t0")
        assert after is not None and np.array_equal(before, after), \
            "diverged coefficients leaked into the warm-start store"
        # a healthy solve afterwards updates it again
        [ok] = eng.serve([req()])
        assert ok.ok
        eng.shutdown()

    def test_vmapped_batch_degrades_to_singles(self, rng):
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(ServeConfig(), registry=reg)
        systems = [make_system(np.random.default_rng(60 + i), 64, 16)
                   for i in range(3)]
        reqs = [_req(x, y, method="bakp_gram", thr=8, design_key=f"vm{i}",
                     request_id=f"vm{i}")
                for i, (x, y, _) in enumerate(systems)]
        with installed({"solver.raise": {"count": 1, "match": "vmap:"}}):
            out = eng.serve(reqs)
        assert not [r.error for r in out if r.error]
        ctr = reg.get("solver_retries_total")
        assert ctr.value(reason="raise", from_path="vmap:bakp_gram",
                         to_path="single") == len(reqs)
        for (x, y, a), res in zip(systems, out):
            denom = np.maximum(np.abs(a), 1e-12)
            assert float(np.mean(np.abs(res.coef - a) / denom)) <= 1e-4
        eng.shutdown()

    def test_no_plan_is_bit_identical(self, rng):
        """The disarmed hooks must not perturb results at all."""
        def run():
            eng = SolverServeEngine(ServeConfig(),
                                    registry=obs.MetricsRegistry())
            x, y, _ = make_system(np.random.default_rng(7), 64, 16)
            [res] = eng.serve([_req(x, y, method="bakp_gram", thr=8,
                                    design_key="bi")])
            eng.shutdown()
            return res
        a, b = run(), run()
        assert a.ok and b.ok and a.retries == b.retries == 0
        assert np.array_equal(a.coef, b.coef)


# ------------------------------------------------------------ ticket cancel
class TestTicketCancel:
    def _engine(self):
        return SolverServeEngine(ServeConfig(),
                                 registry=obs.MetricsRegistry())

    def test_cancel_unfired_ticket_and_drain(self, rng):
        eng = self._engine()
        # huge idle timeout: the batch never fires on its own, so an
        # uncancelled leaked ticket would hang drain() forever.
        cfg = DispatchConfig(idle_timeout_s=1e9, max_batch=1000,
                             prewarm_cache=False)
        disp = AsyncDispatcher(eng, cfg).start()
        x, y, _ = make_system(rng, 40, 8)
        t = disp.submit(_req(x, y, thr=8, design_key="c0"))
        with pytest.raises(TimeoutError):
            t.result(timeout=0.01)      # the leak pattern under test
        assert t.cancel()
        assert not t.cancel()           # idempotent: already settled
        with pytest.raises(TicketCancelled):
            t.result(timeout=1.0)
        t0 = time.perf_counter()
        assert disp.drain(timeout=5.0)
        assert time.perf_counter() - t0 < 2.0
        assert disp.stats.cancelled == 1
        assert disp.stats.deadline_misses == 0   # a cancel is not a miss
        assert disp.inflight == 0
        disp.stop()
        eng.shutdown()

    def test_cancel_after_completion_returns_false(self, rng):
        eng = self._engine()
        cfg = DispatchConfig(idle_timeout_s=0.005, prewarm_cache=False)
        with AsyncDispatcher(eng, cfg) as disp:
            x, y, _ = make_system(rng, 40, 8)
            t = disp.submit(_req(x, y, thr=8, design_key="c1"))
            res = t.result(timeout=60.0)
            assert res.ok
            assert not t.cancel()
        eng.shutdown()

    def test_drain_survives_dead_lane(self, rng):
        """A worker death mid-dispatch settles the fired tickets through
        the work's failure hook — drain() completes, nothing hangs."""
        eng = self._engine()
        cfg = DispatchConfig(idle_timeout_s=0.005, prewarm_cache=False)
        disp = AsyncDispatcher(eng, cfg).start()
        x, y, _ = make_system(rng, 64, 16)
        with installed({"lane.worker": {"count": 1, "match": "single:"}}):
            tickets = [disp.submit(_req(x, y, method="bakp_gram", thr=8,
                                        design_key="dd",
                                        request_id=f"dd{i}"))
                       for i in range(4)]
            assert disp.drain(timeout=60.0)
            for t in tickets:
                assert t.done(), "ticket orphaned by the dead lane"
                try:
                    t.result(timeout=0)
                except Exception:
                    pass            # failed units surface typed errors
        # dispatcher and engine both keep serving afterwards
        t = disp.submit(_req(x, y, method="bakp_gram", thr=8,
                             design_key="dd"))
        assert t.result(timeout=60.0).ok
        assert disp.inflight == 0
        disp.stop()
        eng.shutdown()


# --------------------------------------------------------- crash-safe store
class TestCrashSafeStore:
    def _to_disk(self, rng, tmp_path, key="d1"):
        x = rng.normal(size=(64, 48)).astype(np.float32)
        self.reg = obs.MetricsRegistry()
        st = DesignStore(device_bytes=None, host_bytes=1,
                         disk_dir=str(tmp_path / "tiles"),
                         registry=self.reg)
        entry = st.build(key, x)
        entry.x_t_for(16)
        entry.store_coef("tenant", np.ones(48, np.float32))
        st.demote(key)
        assert st.tier(key) == "disk"
        return st, x

    def test_tile_format_and_atomic_writes(self, rng, tmp_path):
        st, x = self._to_disk(rng, tmp_path)
        disk = st._disk["d1"]
        assert not list(disk.tile_dir.glob("*.tmp")), \
            "temp files must never survive a tile write"
        for j in range(disk.nblocks):
            raw = disk.tile_path(j).read_bytes()
            magic, crc, nbytes = _TILE_HEADER.unpack_from(raw)
            payload = raw[_TILE_HEADER.size:]
            assert magic == _TILE_MAGIC
            assert nbytes == len(payload)
            assert crc == zlib.crc32(payload)
            np.testing.assert_array_equal(
                disk.verify_tile(j),
                np.frombuffer(payload, np.float32).reshape(16, 64))

    def test_corrupt_tile_quarantined_and_rebuilt(self, rng, tmp_path):
        st, x = self._to_disk(rng, tmp_path)
        disk = st._disk["d1"]
        path = disk.tile_path(1)
        raw = bytearray(path.read_bytes())
        raw[_TILE_HEADER.size + 5] ^= 0xFF    # flip one payload byte
        path.write_bytes(bytes(raw))
        assert st.promote("d1") is None       # detected, not served
        assert st.tier("d1") == "none"        # X bytes are gone...
        qdir = (tmp_path / "tiles" / "d1.quarantine")
        assert qdir.exists() and not (tmp_path / "tiles" / "d1").exists()
        assert st.stats.tile_corruptions == 1
        assert self.reg.get("store_tile_corruption_total").value() == 1
        # ...but a rebuild from the design source restores tenant state
        fresh = st.build("d1", x)
        assert fresh.warm_coef("tenant") is not None
        assert np.allclose(np.asarray(fresh.x_pad), x)

    def test_fault_site_corrupts_without_touching_disk(self, rng, tmp_path):
        st, x = self._to_disk(rng, tmp_path, key="d2")
        with installed({"store.tile_corrupt": {"count": 1, "match": "d2"}}):
            with pytest.raises(TileCorruptionError):
                st._disk["d2"].verify_tile(0)
        # the on-disk bytes were never mutated: a clean retry verifies
        st._disk["d2"].verify_tile(0)
        assert st.promote("d2") is not None

    def test_engine_recovers_from_corruption(self, rng, tmp_path):
        """Store-backed engine: a design demoted to disk gets its tiles
        corrupted; the next request quarantines it and rebuilds from the
        request's design source — served, counted, no error."""
        design_bytes = 64 * 32 * 4
        reg = obs.MetricsRegistry()
        eng = SolverServeEngine(
            ServeConfig(store_device_bytes=2 * design_bytes, store_host_bytes=1,
                        store_dir=str(tmp_path / "t"), cache_entries=256),
            registry=reg)
        systems = [make_system(np.random.default_rng(80 + i), 48, 24)
                   for i in range(4)]
        reqs = [_req(x, y, method="bakp", thr=8, max_iter=150,
                     design_key=f"cq{i}", request_id=f"cq{i}")
                for i, (x, y, _) in enumerate(systems)]
        eng.serve(reqs)                  # churns the early designs to disk
        victims = [k for k in ("cq0", "cq1", "cq2", "cq3")
                   if eng.store.tier(k) == "disk"]
        assert victims, "workload must demote at least one design to disk"
        disk = eng.store._disk[victims[0]]
        for j in range(disk.nblocks):
            p = disk.tile_path(j)
            raw = bytearray(p.read_bytes())
            raw[-1] ^= 0xFF
            p.write_bytes(bytes(raw))
        out = eng.serve(reqs)            # hits the corrupt tiles
        assert not [r.error for r in out if r.error]
        assert eng.store.stats.tile_corruptions >= 1
        assert reg.get("store_tile_corruption_total").value() >= 1
        for (x, y, a), res in zip(systems, out):
            denom = np.maximum(np.abs(a), 1e-12)
            assert float(np.mean(np.abs(res.coef - a) / denom)) <= 1e-4
        eng.shutdown()
