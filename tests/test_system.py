"""End-to-end behaviour tests: real training descends, checkpoint restart
resumes bit-exactly, and the paper's solver integrates with the LM stack
(linear probe on frozen activations)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.core import fit_linear_probe, solvebakf
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.model import init_model, make_smoke_batch
from repro.models.common import embed_tokens, rmsnorm
from repro.models.transformer import run_backbone
from repro.optim import make_optimizer


def _train(cfg, steps=60, batch=8, seq=32, lr=3e-3, params=None,
           opt_state=None, start=0, data=None, total=None):
    key = jax.random.PRNGKey(0)
    params = params or init_model(cfg, key)
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_state = opt_state or opt_init(params)
    data = data or SyntheticLM(cfg.vocab_size, seq, batch)
    step_fn = jax.jit(make_train_step(cfg, peak_lr=lr, warmup=10,
                                      total_steps=total or steps))
    losses = []
    for s in range(start, steps):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, b, jnp.int32(s))
        losses.append(float(m["ce_loss"]))
    return params, opt_state, losses, data


class TestTraining:
    def test_loss_descends_dense(self):
        cfg = dataclasses.replace(ARCHS["h2o-danube-1.8b"].smoke(),
                                  microbatch=1)
        _, _, losses, _ = _train(cfg, steps=60)
        assert np.mean(losses[-10:]) < 0.6 * np.mean(losses[:5]), losses

    def test_loss_descends_moe(self):
        cfg = dataclasses.replace(ARCHS["dbrx-132b"].smoke(), microbatch=1)
        _, _, losses, _ = _train(cfg, steps=60)
        assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:5]), losses

    def test_loss_descends_ssm(self):
        cfg = dataclasses.replace(ARCHS["mamba2-370m"].smoke(), microbatch=1)
        _, _, losses, _ = _train(cfg, steps=60)
        assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:5]), losses

    def test_microbatch_equivalence(self):
        """Grad accumulation must match the monolithic step numerically."""
        cfg1 = dataclasses.replace(ARCHS["h2o-danube-1.8b"].smoke(),
                                   microbatch=1)
        cfg2 = dataclasses.replace(cfg1, microbatch=2)
        key = jax.random.PRNGKey(0)
        params = init_model(cfg1, key)
        opt_init, _ = make_optimizer(cfg1.optimizer)
        batch = make_smoke_batch(cfg1, key, batch=4, seq=32)
        outs = []
        for cfg in (cfg1, cfg2):
            p, o, m = jax.jit(make_train_step(cfg))(
                params, opt_init(params), batch, jnp.int32(0))
            outs.append((float(m["ce_loss"]), p))
        assert abs(outs[0][0] - outs[1][0]) < 2e-3
        l1 = jax.tree_util.tree_leaves(outs[0][1])
        l2 = jax.tree_util.tree_leaves(outs[1][1])
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-2)


class TestFaultTolerance:
    def test_checkpoint_restart_exact(self, tmp_path):
        from repro.checkpoint import restore_checkpoint, save_checkpoint
        cfg = dataclasses.replace(ARCHS["h2o-danube-1.8b"].smoke(),
                                  microbatch=1)
        # run 30 steps straight
        p_a, o_a, losses_a, _ = _train(cfg, steps=30)
        # run 15, checkpoint, restart, run 15 more (same schedule horizon)
        p_b, o_b, losses_b1, data = _train(cfg, steps=15, total=30)
        save_checkpoint(str(tmp_path), 15, {"p": p_b, "o": o_b},
                        extras={"data_step": data.state.step})
        tree, extras, _ = restore_checkpoint(str(tmp_path),
                                             {"p": p_b, "o": o_b})
        data2 = SyntheticLM(cfg.vocab_size, 32, 8)
        data2.skip_to(extras["data_step"])
        _, _, losses_b2, _ = _train(cfg, steps=30, params=tree["p"],
                                    opt_state=tree["o"], start=15,
                                    data=data2)
        np.testing.assert_allclose(losses_a[15:], losses_b2, rtol=1e-4)


class TestSolverIntegration:
    """The paper's technique as a first-class feature of the LM stack."""

    def _features(self, cfg, params, batch):
        x = embed_tokens(params["embed"], batch["tokens"], jnp.float32)
        b, s = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, _, _ = run_backbone(cfg, params["backbone"], x, mode="train",
                               positions=pos)
        return rmsnorm(h, params["final_ln"]).reshape(-1, cfg.d_model)

    def test_linear_probe_on_activations(self):
        cfg = ARCHS["h2o-danube-1.8b"].smoke()
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), batch=8,
                                 seq=32)
        feats = self._features(cfg, params, batch)      # (256, 64) tall
        w_true = jnp.array(np.random.default_rng(2).normal(
            size=(cfg.d_model,)).astype(np.float32))
        target = feats @ w_true
        res = fit_linear_probe(feats, target, max_iter=100, rtol=1e-10)
        rel = float(jnp.linalg.norm(res.coef - w_true) /
                    jnp.linalg.norm(w_true))
        assert rel < 1e-2

    def test_feature_selection_on_activations(self):
        cfg = ARCHS["h2o-danube-1.8b"].smoke()
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), batch=8,
                                 seq=32)
        feats = self._features(cfg, params, batch)
        idx = [3, 17, 41]
        target = feats[:, idx[0]] * 2 - feats[:, idx[1]] + 3 * feats[:, idx[2]]
        sel = solvebakf(feats, target, max_feat=3)
        assert set(np.array(sel.selected).tolist()) == set(idx)
