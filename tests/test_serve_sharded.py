"""Sharded serving: placement routing, mesh parity, cache thread-safety.

The engine-level parity check runs in a subprocess with 8 forced virtual
CPU devices (the main test process keeps the single-device view, see
tests/conftest.py); placement policy and cache-locking tests run in-process
— they don't touch device state.
"""
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.serve import (Placement, PlacementPolicy, SolveRequest,
                         mesh_device_count, placement_for_group)
from repro.serve.batching import config_key
from repro.serve.cache import DesignCache

# Parity workload + assertions, executed under an 8-device mesh.  The same
# requests go through a mesh-routed engine and a plain single-device engine;
# results must line up in submission order with MAPE <= 1e-5 per request.
PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.serve import (PlacementPolicy, ServeConfig, SolveRequest,
                            SolverServeEngine, build_serve_mesh)

    K = 32  # same-design group size: exercises the k-sharded multi-RHS path

    def workload(seed):
        rng = np.random.default_rng(seed)
        reqs = []
        # big-bucket designs (pad to 512x64 >= policy threshold)
        # -> obs-sharded singles on the mesh engine
        for i in range(3):
            x = rng.normal(size=(500, 60)).astype(np.float32)
            a = rng.normal(size=(60,)).astype(np.float32)
            reqs.append(SolveRequest(
                x=x, y=x @ a, thr=16, max_iter=40, rtol=0.0,
                design_key=f"big-{i}", request_id=f"big-{i}",
                tenant_id=f"big-t{i}"))
        # giant same-design group, small bucket -> rhs-sharded multi-RHS
        xs = rng.normal(size=(200, 24)).astype(np.float32)
        A = rng.normal(size=(24, K)).astype(np.float32)
        for i in range(K):
            reqs.append(SolveRequest(
                x=xs, y=xs @ A[:, i], thr=16, max_iter=40, rtol=0.0,
                design_key="grp", request_id=f"grp-{i}",
                tenant_id=f"grp-t{i}"))
        # distinct small designs -> vmap batch (single-device on BOTH)
        for i in range(4):
            x = rng.normal(size=(100, 12)).astype(np.float32)
            a = rng.normal(size=(12,)).astype(np.float32)
            reqs.append(SolveRequest(
                x=x, y=x @ a, thr=8, max_iter=40, rtol=0.0,
                design_key=f"sm-{i}", request_id=f"sm-{i}"))
        return reqs

    policy = PlacementPolicy(obs_shard_min_cells=512 * 64, rhs_shard_min_k=32)
    eng_mesh = SolverServeEngine(ServeConfig(placement_policy=policy),
                                 mesh=build_serve_mesh("4x2"))
    eng_single = SolverServeEngine(ServeConfig())

    for rnd in range(2):  # round 2 = warm starts via tenant_id on both sides
        r_mesh = eng_mesh.serve(workload(7))
        r_single = eng_single.serve(workload(7))
        assert [r.request_id for r in r_mesh] == \\
            [r.request_id for r in r_single], "submission order diverged"
        assert not [r.error for r in r_mesh + r_single if r.error]
        placements = {r.request_id: r.placement for r in r_mesh}
        for i in range(3):
            assert placements[f"big-{i}"] == "obs_sharded", placements
        for i in range(K):
            assert placements[f"grp-{i}"] == "rhs_sharded", placements
        for i in range(4):
            assert placements[f"sm-{i}"] == "single", placements
        kinds = {r.request_id: r.batch_kind for r in r_mesh}
        assert all(kinds[f"grp-{i}"] == "multi_rhs" for i in range(K))
        assert all(kinds[f"sm-{i}"] == "vmap" for i in range(4))
        assert all(r.placement == "single" for r in r_single)
        worst = 0.0
        for m, s in zip(r_mesh, r_single):
            denom = np.maximum(np.abs(s.coef), 1e-12)
            worst = max(worst, float(np.mean(np.abs(m.coef - s.coef)
                                             / denom)))
        assert worst <= 1e-5, f"round {rnd}: parity MAPE {worst}"
        print(f"round {rnd}: worst parity MAPE {worst:.2e}")
    assert eng_mesh.stats.sharded_solves >= 8   # 3 obs + 1 rhs per round
    assert eng_mesh.stats.warm_starts > 0       # round 2 warm-started
    assert eng_single.stats.sharded_solves == 0
    print("PARITY_OK")
""")


@pytest.mark.slow
def test_sharded_engine_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    p = subprocess.run([sys.executable, "-c", PARITY_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    assert "PARITY_OK" in p.stdout


# ----------------------------------------------------------- policy (pure)
class _FakeMesh:
    """Shape-only stand-in so policy tests never touch jax device state."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _smesh(data=4, model=2):
    from repro.serve import ServeMesh
    shape = {"data": data}
    if model:
        shape["model"] = model
    return ServeMesh(mesh=_FakeMesh(shape), data_axes=("data",),
                     model_axis="model" if model else None)


class TestPlacementPolicy:
    def test_no_mesh_is_single(self):
        from repro.serve import placement_for_bucket
        p = placement_for_bucket((1 << 12, 1 << 12), "bakp_gram",
                                 PlacementPolicy(), None)
        assert p.kind == "single"

    def test_threshold_routes_obs_sharded(self):
        from repro.serve import placement_for_bucket
        pol = PlacementPolicy(obs_shard_min_cells=1 << 16)
        sm = _smesh()
        assert placement_for_bucket((512, 128), "bakp_gram", pol,
                                    sm).kind == "obs_sharded"
        assert placement_for_bucket((128, 128), "bakp_gram", pol,
                                    sm).kind == "single"
        # non-shardable methods stay single at any size
        for m in ("bak", "lstsq", "normal"):
            assert placement_for_bucket((512, 128), m, pol, sm).kind == \
                "single"

    def test_divisibility_guard(self):
        from repro.serve import placement_for_bucket
        pol = PlacementPolicy(obs_shard_min_cells=1)
        sm = _smesh(data=8, model=None)
        # obs_p=4 not divisible by 8 data devices -> single
        assert placement_for_bucket((4, 1 << 10), "bakp", pol, sm).kind == \
            "single"

    def test_mesh_2d_opt_in(self):
        from repro.serve import placement_for_bucket
        sm = _smesh()
        off = PlacementPolicy(obs_shard_min_cells=1)
        assert placement_for_bucket((512, 128), "bakp_gram", off,
                                    sm).kind == "obs_sharded"
        on = PlacementPolicy(obs_shard_min_cells=1, mesh_2d_min_cells=1 << 16)
        assert placement_for_bucket((512, 128), "bakp_gram", on,
                                    sm).kind == "mesh_2d"

    def test_group_upgrade(self):
        pol = PlacementPolicy(rhs_shard_min_k=32)
        sm = _smesh()
        single = Placement("single")
        assert placement_for_group(single, 32, pol, sm).kind == "rhs_sharded"
        assert placement_for_group(single, 16, pol, sm).kind == "single"
        # k not divisible by the data axes -> stays single
        pol2 = PlacementPolicy(rhs_shard_min_k=2)
        assert placement_for_group(single, 2, pol2, sm).kind == "single"
        # already-sharded buckets keep their placement
        obs = Placement("obs_sharded")
        assert placement_for_group(obs, 64, pol, sm).kind == "obs_sharded"

    def test_config_key_carries_placement(self, rng):
        x = rng.normal(size=(40, 6)).astype(np.float32)
        req = SolveRequest(x=x, y=x[:, 0])
        bucket = (64, 8)
        base = config_key(req, bucket)
        assert config_key(req, bucket, None) == base
        keyed = config_key(req, bucket, Placement("obs_sharded"))
        assert keyed != base
        assert keyed[:len(base)] == base

    def test_mesh_device_count(self):
        assert mesh_device_count("8") == 8
        assert mesh_device_count("4x2") == 8


# ------------------------------------------------- cache thread-safety
class TestDesignEntryLocking:
    def test_concurrent_entry_mutation(self, rng):
        """Regression: per-entry state (warm-coef OrderedDict, chol/cn_thr
        dicts) was mutated from the dispatcher pre-warm thread and the
        solver thread with no lock.  Hammer every accessor from several
        threads; under the old code this intermittently corrupted the
        OrderedDict / raised RuntimeError."""
        cache = DesignCache(max_entries=4, max_tenants=8)
        x = rng.normal(size=(64, 24)).astype(np.float32)
        entry, _ = cache.get_or_build("d0", lambda: x)
        stop = threading.Event()
        errors = []

        def hammer(tid):
            try:
                i = 0
                while not stop.is_set():
                    t = f"tenant-{tid}-{i % 13}"
                    entry.store_coef(t, np.full((24,), float(i), np.float32))
                    entry.warm_coef(t)
                    entry.warm_coef(f"tenant-{(tid + 1) % 4}-{i % 13}")
                    entry.cn_for_thr(5 + (i % 3))
                    entry.chol_for(8, 1e-6)
                    i += 1
            except Exception as exc:  # pragma: no cover - the regression
                errors.append(exc)

        threads = [threading.Thread(target=hammer, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        import time
        time.sleep(1.0)
        stop.set()
        for t in threads:
            t.join(timeout=10)
        assert not errors, errors
        # LRU bound survived the stampede
        assert len(entry._warm) <= 8

    def test_store_coef_copies(self, rng):
        cache = DesignCache()
        x = rng.normal(size=(16, 4)).astype(np.float32)
        entry, _ = cache.get_or_build("d0", lambda: x)
        coef = np.ones((4,), np.float32)
        entry.store_coef("t", coef)
        coef[:] = -1.0  # caller mutates the returned ServedSolve.coef
        np.testing.assert_array_equal(entry.warm_coef("t"),
                                      np.ones((4,), np.float32))
