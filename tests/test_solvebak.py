"""Algorithm 1 (SolveBak) — correctness, convergence theorem, tolerances."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_system
from repro.core import solve, solvebak

jax.config.update("jax_enable_x64", False)


class TestSolveBak:
    def test_exact_tall_system(self, rng):
        x, y, a_true = make_system(rng, 800, 40)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=40)
        np.testing.assert_allclose(np.array(res.coef), a_true,
                                   rtol=1e-4, atol=1e-4)
        assert float(res.sse) < 1e-5

    def test_wide_system_zero_residual(self, rng):
        # overdetermined in features: infinitely many solutions, the
        # algorithm must find one with ~zero residual (paper §1).
        x = rng.normal(size=(30, 200)).astype(np.float32)
        y = rng.normal(size=(30,)).astype(np.float32)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=200)
        assert float(res.sse) < 1e-6 * float(np.sum(y * y))

    def test_monotone_sse_theorem1(self, rng):
        """Theorem 1: SSE is non-increasing sweep over sweep."""
        x, y, _ = make_system(rng, 500, 64, noise=0.5)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=30)
        h = np.array(res.history)
        h = h[~np.isnan(h)]
        assert np.all(np.diff(h) <= 1e-3 * h[:-1] + 1e-6)

    def test_least_squares_optimum_noisy(self, rng):
        """Converges to the lstsq optimum, not just a small residual."""
        x, y, _ = make_system(rng, 600, 20, noise=1.0)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=200, rtol=1e-12)
        ref = np.linalg.lstsq(x, y, rcond=None)[0]
        np.testing.assert_allclose(np.array(res.coef), ref, rtol=1e-3,
                                   atol=1e-3)

    def test_atol_early_exit(self, rng):
        x, y, _ = make_system(rng, 400, 30)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=100, atol=1e-3)
        assert bool(res.converged)
        assert int(res.n_sweeps) < 100

    def test_rtol_early_exit(self, rng):
        x, y, _ = make_system(rng, 400, 30, noise=2.0)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=100, rtol=1e-6)
        assert bool(res.converged)
        assert int(res.n_sweeps) < 100

    def test_random_order(self, rng):
        x, y, a_true = make_system(rng, 500, 32)
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=60,
                       order="random", key=jax.random.PRNGKey(3))
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=1e-3,
                                   atol=1e-3)

    def test_zero_column_is_inert(self, rng):
        x, y, _ = make_system(rng, 300, 16)
        x[:, 7] = 0.0
        res = solvebak(jnp.array(x), jnp.array(y), max_iter=50)
        assert np.isfinite(np.array(res.coef)).all()
        assert float(np.array(res.coef)[7]) == 0.0

    def test_initial_guess_warm_start(self, rng):
        x, y, a_true = make_system(rng, 400, 24)
        res = solvebak(jnp.array(x), jnp.array(y),
                       a0=jnp.array(a_true), max_iter=1)
        assert float(res.sse) < 1e-6

    def test_bf16_storage_fp32_accum(self, rng):
        x, y, a_true = make_system(rng, 1000, 16)
        res = solvebak(jnp.array(x, dtype=jnp.bfloat16),
                       jnp.array(y), max_iter=60)
        # bf16 storage: looser tolerance, same solution
        np.testing.assert_allclose(np.array(res.coef), a_true, rtol=0.05,
                                   atol=0.05)

    def test_api_dispatch(self, rng):
        x, y, a_true = make_system(rng, 300, 12)
        for method in ("bak", "bakp", "bakp_gram", "lstsq", "normal"):
            res = solve(jnp.array(x), jnp.array(y), method=method,
                        max_iter=60, thr=8)
            np.testing.assert_allclose(np.array(res.coef), a_true,
                                       rtol=1e-2, atol=1e-2)
