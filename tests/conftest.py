"""Shared fixtures.  NOTE: no XLA device-count flags here by design — smoke
tests and benches must see the single real CPU device; only the dry-run
(repro.launch.dryrun) forces 512 host devices, and the distributed-solver
tests spawn subprocesses with their own flags."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_system(rng, obs, nvars, noise=0.0, dtype=np.float32):
    """Random consistent (or noisy) linear system."""
    x = rng.normal(size=(obs, nvars)).astype(dtype)
    a = rng.normal(size=(nvars,)).astype(dtype)
    y = x @ a
    if noise:
        y = y + noise * rng.normal(size=obs).astype(dtype)
    return x, y, a
