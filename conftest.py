"""Repo-root pytest config: seed-inherited known-failure deselection.

``tests/known_failures.txt`` tracks test failures inherited with the seed
(remat autodiff on CPU, int8-KV numerics — see ROADMAP.md); they are
deselected at collection time so the tier-1 command from ROADMAP
(``PYTHONPATH=src python -m pytest -x -q``) is green locally exactly as in
CI, and any NEW failure stops the run.  Remove lines from the file as the
root causes get fixed; run with ``--run-known-failures`` to execute the
tracked tests anyway (e.g. to check whether an entry is stale).
"""

from __future__ import annotations

import pathlib

_KNOWN = pathlib.Path(__file__).parent / "tests" / "known_failures.txt"


def pytest_addoption(parser):
    parser.addoption(
        "--run-known-failures",
        action="store_true",
        default=False,
        help="collect tests listed in tests/known_failures.txt instead of deselecting them",
    )


def _known_failures():
    try:
        lines = _KNOWN.read_text().splitlines()
    except OSError:
        return frozenset()
    stripped = (line.strip() for line in lines)
    return frozenset(line for line in stripped if line and not line.startswith("#"))


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-known-failures"):
        return
    known = _known_failures()
    if not known:
        return
    kept, deselected = [], []
    for item in items:
        # nodeids are rootdir-relative ("tests/test_x.py::test_y[param]"),
        # matching the file's entries; parametrised entries may list either
        # the exact id or the bare function.
        bare = item.nodeid.split("[", 1)[0]
        if item.nodeid in known or bare in known:
            deselected.append(item)
        else:
            kept.append(item)
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = kept
