"""Linear probing of LM activations with the BAK solver — the paper's
regression setting (tall systems: many tokens × d_model features) applied
inside the framework.

Trains a tiny qwen3-family model for a few steps, freezes it, extracts
hidden states, and fits a linear readout with SolveBakP (gram mode) —
comparing against the LAPACK path for time and agreement.

    PYTHONPATH=src python examples/linear_probe.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get
from repro.core import fit_linear_probe, solve
from repro.models.common import embed_tokens, rmsnorm
from repro.models.model import init_model, make_smoke_batch
from repro.models.transformer import run_backbone

cfg = get("qwen3-8b").smoke()
params = init_model(cfg, jax.random.PRNGKey(0))

# extract frozen features for a batch of sequences
batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), batch=16, seq=64)
x = embed_tokens(params["embed"], batch["tokens"], jnp.float32)
pos = jnp.broadcast_to(jnp.arange(64)[None], (16, 64))
h, _, _ = run_backbone(cfg, params["backbone"], x, mode="train",
                       positions=pos)
feats = rmsnorm(h, params["final_ln"]).reshape(-1, cfg.d_model)  # (1024, 64)
print(f"features: {feats.shape} (tall system — the paper's regime)")

# synthetic probe target: depends on a sparse direction of the features
w_true = jnp.zeros((cfg.d_model,)).at[jnp.array([3, 11, 40])].set(
    jnp.array([2.0, -1.5, 0.7]))
target = feats @ w_true + 0.01 * jax.random.normal(
    jax.random.PRNGKey(2), (feats.shape[0],))

t0 = time.perf_counter()
res = fit_linear_probe(feats, target, max_iter=100, rtol=1e-10)
jax.block_until_ready(res.coef)
t_bak = time.perf_counter() - t0

t0 = time.perf_counter()
ref = solve(feats, target, method="lstsq")
jax.block_until_ready(ref.coef)
t_lapack = time.perf_counter() - t0

agree = float(jnp.abs(res.coef - ref.coef).max())
print(f"bak probe: {t_bak*1e3:.1f}ms  lapack: {t_lapack*1e3:.1f}ms  "
      f"max|Δcoef|={agree:.2e}")
print(f"probe recovers planted direction: "
      f"{np.round(np.array(res.coef[jnp.array([3, 11, 40])]), 2).tolist()}")
