"""SolveBakF (Algorithm 3) for feature selection — paper §8 + Fig 2.

Selects informative columns out of a wide feature matrix and compares wall
time against classical stepwise regression (the paper's baseline).

    PYTHONPATH=src python examples/feature_selection.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solvebakf, stepwise_regression_baseline

rng = np.random.default_rng(0)
obs, nvars, k = 4000, 128, 6
x = rng.normal(size=(obs, nvars)).astype(np.float32)
idx = sorted(rng.choice(nvars, size=k, replace=False).tolist())
coef = np.zeros(nvars, np.float32)
coef[idx] = 3 * rng.normal(size=k).astype(np.float32) + 1.0
y = x @ coef + 0.05 * rng.normal(size=obs).astype(np.float32)
xj, yj = jnp.array(x), jnp.array(y)

t0 = time.perf_counter()
sel = solvebakf(xj, yj, max_feat=k)
jax.block_until_ready(sel.selected)
t_fast = time.perf_counter() - t0

t0 = time.perf_counter()
sw = stepwise_regression_baseline(xj, yj, max_feat=k)
jax.block_until_ready(sw.selected)
t_slow = time.perf_counter() - t0

print(f"planted   : {idx}")
print(f"solvebakf : {sorted(np.array(sel.selected).tolist())}  "
      f"({t_fast*1e3:.0f}ms)")
print(f"stepwise  : {sorted(np.array(sw.selected).tolist())}  "
      f"({t_slow*1e3:.0f}ms)")
print(f"speed-up  : {t_slow/t_fast:.1f}x (paper Fig 2 shows the same gap "
      f"growing with vars)")
print("SSE path  :", [f"{v:.3e}" for v in np.array(sel.sse_path)])
