"""End-to-end driver: train a reduced-config LM on the synthetic pipeline
for a few hundred steps with checkpointing, then reload and serve a few
tokens — exercising every substrate (data → train loop → checkpoint →
restore → prefill/decode).

    PYTHONPATH=src python examples/train_tiny_lm.py [--arch h2o-danube-1.8b]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models.kvcache import init_cache
from repro.models.model import init_model
from repro.optim import make_optimizer

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="h2o-danube-1.8b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = dataclasses.replace(get(args.arch).smoke(), microbatch=1)
key = jax.random.PRNGKey(0)
params = init_model(cfg, key)
opt_init, _ = make_optimizer(cfg.optimizer)
opt_state = opt_init(params)
data = SyntheticLM(cfg.vocab_size, 32, 16)
step_fn = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=20,
                                  total_steps=args.steps),
                  donate_argnums=(0, 1))

losses = []
for step in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    params, opt_state, m = step_fn(params, opt_state, batch,
                                   jnp.int32(step))
    losses.append(float(m["ce_loss"]))
    if step % 25 == 0:
        print(f"step {step:4d}  ce={losses[-1]:.4f}")

print(f"loss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")
assert np.mean(losses[-10:]) < np.mean(losses[:10])

with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, args.steps, {"params": params},
                    extras={"data_step": data.state.step})
    tree, extras, _ = restore_checkpoint(d, {"params": params})
    params = tree["params"]
    print(f"checkpoint roundtrip ok (data_step={extras['data_step']})")

# serve: prefill a learnable prompt, greedy-decode — the model should
# continue the (t+1) mod 97 pattern it was trained on.
prompt = (np.arange(16) % 97).astype(np.int32)[None, :].repeat(2, 0)
cache = init_cache(cfg, 2, cfg.max_cache_len)
prefill = jax.jit(make_prefill_step(cfg))
decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
logits, cache = prefill(params, {"tokens": jnp.asarray(prompt)}, cache)
toks = []
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for _ in range(8):
    toks.append(int(tok[0, 0]))
    logits, cache = decode(params, tok, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
print("prompt tail:", prompt[0, -4:].tolist(), " generated:", toks)
correct = sum(1 for i, t in enumerate(toks) if t == (16 + i) % 97)
print(f"pattern accuracy: {correct}/8")
