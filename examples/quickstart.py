"""Quickstart: solve linear systems with the BAK family (the paper's core).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import solve, solvebak, solvebakf

rng = np.random.default_rng(0)

# -- a tall system (the paper's main regime): 20k observations, 256 vars ---
x = rng.normal(size=(20_000, 256)).astype(np.float32)
a_true = rng.normal(size=(256,)).astype(np.float32)
y = x @ a_true + 0.01 * rng.normal(size=20_000).astype(np.float32)

res = solve(jnp.array(x), jnp.array(y), method="bakp_gram", thr=128,
            max_iter=50, rtol=1e-9)
print(f"[bakp_gram] sweeps={int(res.n_sweeps)} "
      f"rmse={float(jnp.sqrt(res.sse/20_000)):.2e} "
      f"coef_err={float(jnp.abs(res.coef - a_true).max()):.2e}")

# -- paper-faithful Algorithm 1, with SSE history (Theorem 1) --------------
res1 = solvebak(jnp.array(x), jnp.array(y), max_iter=10)
h = np.array(res1.history)
print("[bak] SSE per sweep:", " ".join(f"{v:.3e}" for v in h[:8]))
assert np.all(np.diff(h[~np.isnan(h)]) <= 1e-3 * h[~np.isnan(h)][:-1] + 1e-6), \
    "Theorem 1 violated?!"

# -- wide system: more unknowns than equations -----------------------------
xw = rng.normal(size=(128, 2048)).astype(np.float32)
yw = rng.normal(size=(128,)).astype(np.float32)
resw = solve(jnp.array(xw), jnp.array(yw), method="bakp_gram", thr=128,
             max_iter=50)
print(f"[wide] residual={float(resw.sse):.2e} (exact solution found)")

# -- greedy feature selection (Algorithm 3) --------------------------------
coef = np.zeros(256, np.float32)
planted = [7, 80, 201]
coef[planted] = [4.0, -3.0, 5.0]
ys = x @ coef + 0.01 * rng.normal(size=20_000).astype(np.float32)
sel = solvebakf(jnp.array(x), jnp.array(ys), max_feat=3)
print(f"[bakf] planted={sorted(planted)} "
      f"selected={sorted(np.array(sel.selected).tolist())}")
