"""Telemetry overhead gate: the obs layer must be ~free on the serve path.

    PYTHONPATH=src python -m benchmarks.serve_obs [--smoke] [--json PATH]

Every serving component (engine, dispatcher, design cache, kernel dispatch
shims) dual-writes its stats into a ``repro.obs.MetricsRegistry`` and
attaches a ``SolveTelemetry`` record to each result.  That bookkeeping runs
on the host, per flush — exactly where serving throughput is won — so this
benchmark measures it directly:

  * one warmed engine serves the same 64-request window repeatedly, with
    obs ON and OFF (``repro.obs.set_enabled`` — the runtime form of the
    ``REPRO_OBS_DISABLED=1`` escape hatch) in interleaved repeats;
  * wall per window is min-of-repeats (the scheduler-noise-free floor);
  * acceptance: on/off ratio <= 1.05 (telemetry overhead within 5%);
  * the final registry snapshot is checked for completeness (solve counts,
    per-kernel-path latency histograms, cache hit/miss, sweep histograms)
    and written to the JSON artifact (``BENCH_obs.json`` in CI), so the
    dashboard-facing numbers ride the same artifact diff as the gate.

The interleave matters: A/A/B/B would hand whichever mode runs second a
warmer allocator; A/B/A/B gives both modes the same drift, and the min
discards the rest.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def bench_overhead(obs_n, nvars, n_requests, designs, thr, repeats, seed=0):
    from repro import obs as robs
    from repro.serve import ServeConfig, SolveRequest, SolverServeEngine

    rng = np.random.default_rng(seed)
    xs = [rng.normal(size=(obs_n, nvars)).astype(np.float32)
          for _ in range(designs)]
    coefs = [rng.normal(size=(nvars,)).astype(np.float32)
             for _ in range(n_requests)]

    def requests():
        return [SolveRequest(x=xs[i % designs], y=xs[i % designs] @ coefs[i],
                             method="bakp_gram", thr=thr, max_iter=30,
                             rtol=1e-8, design_key=f"d{i % designs}",
                             tenant_id=f"t{i % 8}", request_id=f"r{i}")
                for i in range(n_requests)]

    reg = robs.MetricsRegistry()
    engine = SolverServeEngine(ServeConfig(), registry=reg)
    for _ in range(2):  # compile + design cache + warm-start variants
        engine.serve(requests())

    def window():
        t0 = time.perf_counter()
        served = engine.serve(requests())
        dt = time.perf_counter() - t0
        assert all(s.ok for s in served)
        return dt

    on_walls, off_walls = [], []
    for _ in range(repeats):
        prev = robs.set_enabled(True)
        try:
            on_walls.append(window())
        finally:
            robs.set_enabled(prev)
        prev = robs.set_enabled(False)
        try:
            off_walls.append(window())
        finally:
            robs.set_enabled(prev)

    # Completeness: one more obs-on window, then the snapshot must carry
    # every family the dashboards/exporters key on, with activity in it.
    served = engine.serve(requests())
    snap = reg.snapshot()
    required = ("serve_requests_total", "serve_solves_total",
                "serve_requests_served_total", "serve_solve_latency_seconds",
                "serve_sweeps", "serve_group_size",
                "serve_cache_hits_total", "serve_cache_misses_total",
                "serve_cache_entries")
    missing = [n for n in required if n not in snap
               or not snap[n]["values"]]
    tel = served[0].telemetry
    assert tel is not None and tel.kernel_path != "unknown", \
        "telemetry record missing or path unresolved on the obs-on window"

    on_min, off_min = min(on_walls), min(off_walls)
    return {
        "obs": obs_n, "vars": nvars, "n_requests": n_requests,
        "designs": designs, "repeats": repeats,
        "obs_on_wall_s": on_min,
        "obs_off_wall_s": off_min,
        "overhead_ratio": on_min / off_min,
        "overhead_pct": (on_min / off_min - 1.0) * 100.0,
        "snapshot_missing": missing,
        "kernel_path": tel.kernel_path,
        "snapshot": snap,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + extra repeats (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics + registry snapshot JSON "
                         "(e.g. BENCH_obs.json)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # Smoke sizes mirror the tier-1 serve_throughput smoke (512x64): the
    # gate asks "is telemetry free on the workload CI actually times", not
    # "is it free relative to a microscopic solve" — at 256x32 the whole
    # request is ~100us of host work and ANY per-request bookkeeping reads
    # as several percent.
    if args.smoke:
        kw = dict(obs_n=512, nvars=64, designs=4, thr=32,
                  repeats=args.repeats or 9)
    else:
        kw = dict(obs_n=1024, nvars=128, designs=4, thr=64,
                  repeats=args.repeats or 5)
    r = bench_overhead(n_requests=args.requests, seed=args.seed, **kw)

    print("name,us_per_call,derived")
    tag = f"serve_obs[o{r['obs']}xv{r['vars']}n{r['n_requests']}]"
    print(f"{tag}/on,{r['obs_on_wall_s']/r['n_requests']*1e6:.0f},"
          f"wall={r['obs_on_wall_s']*1e3:.2f}ms")
    print(f"{tag}/off,{r['obs_off_wall_s']/r['n_requests']*1e6:.0f},"
          f"wall={r['obs_off_wall_s']*1e3:.2f}ms")
    print(f"{tag}/overhead,,ratio={r['overhead_ratio']:.4f};"
          f"pct={r['overhead_pct']:+.2f}%;path={r['kernel_path']}")

    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        slim = {k: v for k, v in r.items() if k != "snapshot"}
        write_json(args.json, {"obs_overhead": slim,
                               "registry_snapshot": r["snapshot"]})
        print(f"wrote {args.json}")

    ok_snap = not r["snapshot_missing"]
    ok_ratio = r["overhead_ratio"] <= 1.05
    print(f"acceptance: overhead_ratio={r['overhead_ratio']:.4f} (<=1.05) "
          f"snapshot_missing={r['snapshot_missing'] or 'none'} -> "
          f"{'PASS' if ok_ratio and ok_snap else 'FAIL'}")
    return 0 if (ok_ratio and ok_snap) else 1


if __name__ == "__main__":
    sys.exit(main())
