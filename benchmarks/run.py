"""Benchmark harness — one section per paper table/figure + roofline rows.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark cell) and a
readable summary per section.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller Table-1 grid")
    args = ap.parse_args()

    from benchmarks.paper_tables import (convergence_profile,
                                         fig2_feature_selection, table1)
    from benchmarks.solver_roofline import (measured_sweep_throughput,
                                            solver_roofline_rows)

    print("name,us_per_call,derived")

    rows = table1(rows=[(100, 1_000), (100, 50_000), (1_000, 10_000)]
                  if args.fast else None)
    for r in rows:
        tag = f"table1[v{r['vars']}xo{r['obs']}]"
        print(f"{tag}/lapack,{r['lapack_s']*1e6:.0f},mape={r['lapack_mape']:.2e}")
        print(f"{tag}/normal,{r['normal_s']*1e6:.0f},")
        print(f"{tag}/bak,{r['bak_s']*1e6:.0f},"
              f"mape={r['bak_mape']:.2e};speedup={r['speedup_vs_lapack_bak']:.2f}")
        print(f"{tag}/bakp,{r['bakp_s']*1e6:.0f},"
              f"mape={r['bakp_mape']:.2e};speedup={r['speedup_vs_lapack_bakp']:.2f}")
        print(f"{tag}/bakp_gram,{r['bakp_gram_s']*1e6:.0f},"
              f"mape={r['bakp_gram_mape']:.2e}")
        print(f"{tag}/mem,0,lapack_mib={r['lapack_mem_mib']:.1f};"
              f"bak_aux_mib={r['bak_aux_mem_mib']:.3f}")

    for r in fig2_feature_selection():
        tag = f"fig2[o{r['obs']}xv{r['vars']}k{r['k']}]"
        print(f"{tag}/bakf,{r['bakf_s']*1e6:.0f},recovered={r['recovered']}")
        print(f"{tag}/stepwise,{r['stepwise_s']*1e6:.0f},"
              f"speedup={r['speedup']:.1f}")

    for r in convergence_profile():
        print(f"convergence/{r['method']},0,sweeps={r['sweeps_to_tol']};"
              f"rmse={r['final_rmse']:.2e};converged={r['converged']}")

    for r in solver_roofline_rows():
        tag = f"roofline[o{r['obs']}xv{r['vars']}]"
        print(f"{tag},0,ai={r['ai_flops_per_byte']:.2f};"
              f"bottleneck={r['bottleneck']};"
              f"frac_peak={r['frac_of_peak']:.4f};"
              f"mem_term_s={r['mem_term_s']:.2e}")

    m = measured_sweep_throughput()
    print(f"measured_cpu_sweep,{m['cpu_s_per_sweep']*1e6:.0f},"
          f"gbytes_per_s={m['cpu_gbytes_per_s']:.2f}")


if __name__ == "__main__":
    main()
