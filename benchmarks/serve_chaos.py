"""Chaos suite: fault-injected serving must recover at every site.

    PYTHONPATH=src python -m benchmarks.serve_chaos [--smoke] \
        [--json BENCH_chaos.json]

Each scenario builds a production engine + async dispatcher with a
``FaultPlan`` wired through ``ServeConfig.fault_plan`` (the real chaos
entry point, same as ``repro.launch.solver_serve --fault-plan``), fires a
fleet of known-truth requests into the armed stack, then disarms and
replays the identical workload as the recovery pass:

  ================ ========================================================
  scenario         armed site
  ================ ========================================================
  baseline         none — the disarmed-hooks control
  lane_crash       ``lane.worker`` — a lane executor thread dies mid-batch
  solver_raise     ``solver.raise`` — solves raise into the retry ladder
  diverge          ``solver.diverge`` — forced divergence (cold retry +
                   method fallback, warm-store retention skipped)
  corrupt_tile     ``store.tile_corrupt`` — a demoted design's disk tile
                   fails CRC on promotion (quarantine + rebuild)
  deadline_storm   ``lane.delay`` — slow lanes under tight ticket deadlines
  ================ ========================================================

Gates (the ISSUE acceptance):

  * every scenario **recovers** — the disarmed replay serves every request
    with zero errors;
  * **zero hung tickets** — every ticket of every pass settles (served,
    typed error, or cancellation; never a leaked waiter);
  * parity MAPE <= 1e-4 against the known truth on all served requests.

Writes a ``chaos`` section into the JSON report (BENCH_chaos.json in CI).
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import numpy as np


def _mape(coef, ref):
    return float(np.mean(np.abs(coef - ref) / np.maximum(np.abs(ref),
                                                         1e-12)))


SCENARIOS = (
    ("baseline", None, {}),
    ("lane_crash", {"lane.worker": {"count": 1, "match": "single:"}}, {}),
    ("solver_raise", {"solver.raise": {"count": 2}}, {}),
    ("diverge", {"solver.diverge": {"count": 2}}, {}),
    ("corrupt_tile", {"store.tile_corrupt": {"count": 1}},
     {"store": True, "populate": True}),
    ("deadline_storm",
     {"lane.delay": {"count": 0, "delay_s": 0.002, "match": "single:"}},
     {"deadline_s": 0.25}),
)


def _run_scenario(name, plan, *, n=12, obs_n=96, nvars=24, thr=8,
                  max_iter=150, store=False, populate=False,
                  deadline_s=None, seed=0):
    from repro import obs
    from repro.resilience import faults
    from repro.serve import (AsyncDispatcher, DispatchConfig, ServeConfig,
                             SolveRequest, SolverServeEngine)

    rng = np.random.default_rng(seed)
    systems = []
    for i in range(n):
        x = rng.normal(size=(obs_n, nvars)).astype(np.float32)
        a = rng.normal(size=(nvars,)).astype(np.float32)
        systems.append((f"{name}-{i}", x, x @ a, a))

    cfg_kw = {}
    tmp = None
    if store:
        # budgets sized so the fleet churns through host to the disk tier
        design_bytes = obs_n * nvars * 4
        tmp = tempfile.TemporaryDirectory(prefix="repro-chaos-")
        cfg_kw = dict(store_device_bytes=2 * design_bytes,
                      store_host_bytes=1, store_dir=tmp.name,
                      cache_entries=4 * n)
    reg = obs.MetricsRegistry()
    eng = SolverServeEngine(ServeConfig(fault_plan=plan, **cfg_kw),
                            registry=reg)
    disp = AsyncDispatcher(eng, DispatchConfig(
        max_batch=n, idle_timeout_s=0.005, prewarm_cache=False)).start()

    def one_pass():
        tickets = [disp.submit(
            SolveRequest(x=x, y=y, method="bakp", thr=thr,
                         max_iter=max_iter, rtol=1e-12, design_key=key,
                         request_id=key), deadline_s=deadline_s)
            for key, x, y, _ in systems]
        disp.drain(timeout=120.0)
        served, errors, hung, worst = [], 0, 0, 0.0
        for (key, _, _, a), t in zip(systems, tickets):
            if not t.done():
                hung += 1
                t.cancel()      # settle the leak so shutdown stays clean
                continue
            try:
                res = t.result(timeout=0)
            except Exception:
                errors += 1     # typed failure (e.g. LaneWorkerDeath)
                continue
            if res.error is not None:
                errors += 1
                continue
            served.append(res)
            worst = max(worst, _mape(res.coef, a))
        return {"served": len(served), "errors": errors, "hung": hung,
                "mape_worst": worst}

    try:
        if populate:
            one_pass()          # build + demote; the armed site needs a
            #                     disk-resident design to corrupt
        t0 = time.perf_counter()
        chaos = one_pass()
        chaos_s = time.perf_counter() - t0
        armed = faults.active()
        fault_counts = armed.counts() if armed is not None else {}
        faults.clear()          # disarm: the recovery pass is production
        recovery = one_pass()

        lane_stats = eng.lanes.stats()
        out = {
            "requests": n,
            "chaos": chaos, "recovery": recovery,
            "chaos_s": chaos_s,
            "retries": eng.stats.retries,
            "lane_restarts": sum(s["restarts"]
                                 for s in lane_stats.values()),
            "lanes_tripped": sum(bool(s["tripped"])
                                 for s in lane_stats.values()),
            "tile_corruptions": (eng.store.stats.tile_corruptions
                                 if eng.store is not None else 0),
            "fault_counts": fault_counts,
        }
        out["recovered"] = (chaos["hung"] == 0
                            and recovery["hung"] == 0
                            and recovery["errors"] == 0
                            and recovery["served"] == n
                            and chaos["mape_worst"] <= 1e-4
                            and recovery["mape_worst"] <= 1e-4)
        return out
    finally:
        faults.clear()
        disp.stop(drain=False)
        eng.shutdown()
        if tmp is not None:
            tmp.cleanup()


def run(n=12, obs_n=96, nvars=24, thr=8, max_iter=150, seed=0):
    from repro.resilience import faults
    faults.clear()
    out = {}
    for i, (name, plan, kw) in enumerate(SCENARIOS):
        out[name] = _run_scenario(name, plan, n=n, obs_n=obs_n,
                                  nvars=nvars, thr=thr, max_iter=max_iter,
                                  seed=seed + i, **kw)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + recovery gates (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report "
                         "(BENCH_chaos.json)")
    args = ap.parse_args()

    if args.smoke:
        r = run(n=8, obs_n=64, nvars=16, thr=8, max_iter=120)
    else:
        r = run()
    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"chaos": r})

    print("name,us_per_call,derived")
    for name, s in r.items():
        per = (s["chaos_s"] / s["requests"]) * 1e6
        print(f"serve_chaos/{name},{per:.0f},"
              f"served={s['chaos']['served']}/{s['requests']};"
              f"errors={s['chaos']['errors']};"
              f"hung={s['chaos']['hung']};"
              f"retries={s['retries']};"
              f"restarts={s['lane_restarts']};"
              f"corruptions={s['tile_corruptions']};"
              f"recovered={'yes' if s['recovered'] else 'NO'}")

    hung = sum(s["chaos"]["hung"] + s["recovery"]["hung"]
               for s in r.values())
    mape = max(max(s["chaos"]["mape_worst"], s["recovery"]["mape_worst"])
               for s in r.values())
    bad = [name for name, s in r.items() if not s["recovered"]]
    # the armed sites must actually have fired (a chaos run where nothing
    # broke proves nothing)
    signals = (r["solver_raise"]["retries"] >= 1
               and r["lane_crash"]["lane_restarts"] >= 1
               and r["corrupt_tile"]["tile_corruptions"] >= 1)
    ok = not bad and hung == 0 and mape <= 1e-4 and signals
    print(f"acceptance: recovered={len(r) - len(bad)}/{len(r)} "
          f"(all){' FAILING:' + ','.join(bad) if bad else ''} "
          f"hung_tickets={hung} (==0) "
          f"worst_mape={mape:.2e} (<=1e-4) "
          f"faults_fired={'yes' if signals else 'NO'} -> "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
