"""Async serving benchmark: deadline-aware dispatch + warm-start savings.

    PYTHONPATH=src python -m benchmarks.serve_async [--smoke] [--json PATH]

Two experiments on the ``repro.serve`` stack:

  1. **Warm starts** — T tenants repeatedly re-solve against one design
     with a slowly drifting ``y`` (the repeated-design serving workload).
     Cold pass: per-tenant coefficient retention off, every round starts
     from zeros.  Warm pass: retention on, every round after the first
     starts from the tenant's previous solution.  Both stop on the same
     absolute tolerance, so accuracy (MAPE vs fp64 lstsq) is unchanged and
     the sweep-count drop is pure warm-start profit — structure a one-shot
     sketching solver cannot exploit.

  2. **Async dispatch** — the same 64-request Poisson arrival trace is
     served by (a) the synchronous engine flushed every ``max_batch``
     arrivals (intake and device solves serialize) and (b) the
     ``AsyncDispatcher`` (host-side bucketing overlaps in-flight solves;
     batches fire on full/deadline-margin/idle).  Reports per-request
     latency p50/p95, deadline hit rate and end-to-end throughput.

Acceptance (full mode): warm-start mean sweeps ≤ 0.7× cold at unchanged
MAPE; async throughput ≥ sync; deadline misses < 5%.  Smoke mode (CI) only
gates on MAPE ≤ 1e-4 — wall-clock ratios on shared CI runners are noise —
and still writes every metric to the JSON report (``--json``) so
regressions are visible as artifact diffs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _mape(coef, ref):
    denom = np.maximum(np.abs(ref), 1e-12)
    return float(np.mean(np.abs(np.asarray(coef) - ref) / denom))


def write_json(path, metrics):
    """Merge ``metrics`` into a JSON report, preserving other benches' keys
    (CI runs serve_throughput and serve_async into one BENCH_serve.json)."""
    existing = {}
    try:
        with open(path) as f:
            existing = json.load(f)
    except (OSError, ValueError):
        pass
    existing.update(metrics)
    with open(path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)


# ----------------------------------------------------------- warm starts
def bench_warm_start(obs, nvars, tenants, rounds, drift, rtol, thr, seed=0):
    """Drifting-y tenant stream, cold vs warm engines.  Returns metrics.

    Stopping is ``rtol`` (per-sweep relative improvement): it is scale-free
    and fires when the solve stalls at its accuracy floor, so cold and warm
    passes reach the SAME final accuracy — the sweep-count difference is
    purely how far from that floor each pass started.  (An absolute ``atol``
    here would be fragile: set below the fp32 stall floor it never fires
    and both passes burn ``max_iter``; set loose it caps accuracy.)
    """
    from repro.serve import ServeConfig, SolveRequest, SolverServeEngine

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(obs, nvars)).astype(np.float32)
    base = rng.normal(size=(tenants, nvars)).astype(np.float32)
    # Per-round drifted truths, shared by both passes.
    truths = [base.copy()]
    for _ in range(1, rounds):
        truths.append(truths[-1]
                      + drift * rng.normal(size=base.shape).astype(np.float32))

    def requests(r):
        return [SolveRequest(x=x, y=x @ truths[r][t], method="bakp_gram",
                             thr=thr, max_iter=200, rtol=rtol,
                             design_key="warm-design", tenant_id=f"t{t}")
                for t in range(tenants)]

    def run(warm_cache):
        eng = SolverServeEngine(ServeConfig(warm_cache=warm_cache))
        sweeps, mapes = [], []
        for r in range(rounds):
            served = eng.serve(requests(r))
            ref = np.linalg.lstsq(x.astype(np.float64),
                                  (x @ truths[r].T).astype(np.float64),
                                  rcond=None)[0]
            for t, s in enumerate(served):
                assert s.ok, s.error
                mapes.append(_mape(s.coef, ref[:, t]))
            if r > 0:  # round 0 is cold for both passes
                sweeps.extend(s.n_sweeps for s in served)
        return float(np.mean(sweeps)), float(np.max(mapes)), eng.stats

    cold_sweeps, cold_mape, _ = run(warm_cache=False)
    warm_sweeps, warm_mape, warm_stats = run(warm_cache=True)
    return {
        "obs": obs, "vars": nvars, "tenants": tenants, "rounds": rounds,
        "drift": drift, "rtol": rtol,
        "cold_mean_sweeps": cold_sweeps,
        "warm_mean_sweeps": warm_sweeps,
        "sweep_savings": 1.0 - warm_sweeps / cold_sweeps,
        "cold_mape_worst": cold_mape,
        "warm_mape_worst": warm_mape,
        "warm_starts": warm_stats.warm_starts,
    }


# --------------------------------------------------------- async dispatch
def _make_trace(rng, xs, n, rate):
    """Poisson arrival offsets + per-request true coefficients."""
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    coefs = [rng.normal(size=(xs[i % len(xs)].shape[1],)).astype(np.float32)
             for i in range(n)]
    return arrivals, coefs


def _request(xs, coefs, i, thr, deadline_s, tenants):
    from repro.serve import SolveRequest

    d = i % len(xs)
    return SolveRequest(x=xs[d], y=xs[d] @ coefs[i], method="bakp_gram",
                        thr=thr, max_iter=60, rtol=1e-10,
                        design_key=f"d{d}", deadline_s=deadline_s,
                        tenant_id=f"t{i % tenants}", request_id=f"req-{i}")


def _prewarm(engine, xs, sizes, thr):
    """Compile every (bucket, k_pad) program the trace can hit — cold AND
    warm-start (a0) variants, which are separate jit signatures — and build
    the design-cache entries, so neither run pays compiles mid-stream."""
    from repro.serve import SolveRequest

    rng = np.random.default_rng(123)
    for d, x in enumerate(xs):
        for k in sizes:
            for _ in range(2):  # second pass warm-starts off the first
                reqs = [SolveRequest(
                    x=x,
                    y=x @ rng.normal(size=(x.shape[1],)).astype(np.float32),
                    method="bakp_gram", thr=thr, max_iter=60, rtol=1e-10,
                    design_key=f"d{d}", tenant_id=f"warm-{i}")
                    for i in range(k)]
                engine.serve(reqs)
    for _ in range(2):  # one singleton per design: the vmap-stacked path
        engine.serve([SolveRequest(
            x=x, y=x @ rng.normal(size=(x.shape[1],)).astype(np.float32),
            method="bakp_gram", thr=thr, max_iter=60, rtol=1e-10,
            design_key=f"d{d}", tenant_id="warm-v")
            for d, x in enumerate(xs)])


def bench_async(obs, nvars, n, rate, deadline_s, max_batch, thr, seed=0,
                designs=3, tenants=16):
    from repro import obs as robs
    from repro.serve import (AsyncDispatcher, DispatchConfig, ServeConfig,
                             SolverServeEngine)

    rng = np.random.default_rng(seed)
    xs = [rng.normal(size=(obs, nvars)).astype(np.float32)
          for _ in range(designs)]
    arrivals, coefs = _make_trace(rng, xs, n, rate)
    prewarm_sizes = sorted({1, 2, 4, max_batch, n // designs + 1})

    # ---- synchronous baseline: flush every max_batch arrivals
    # Per-run registries: each engine records into its own, so the sync
    # baseline's histograms never mix into the async run's and the
    # percentiles reported below come from the SAME families the engine /
    # dispatcher record (no hand-rolled latency lists).
    reg_sync = robs.MetricsRegistry()
    sync_engine = SolverServeEngine(ServeConfig(), registry=reg_sync)
    _prewarm(sync_engine, xs, prewarm_sizes, thr)
    # Arrival->completion latency is a benchmark-level observable (the sync
    # engine has no arrival clock), recorded into the same registry.
    h_sync = reg_sync.histogram(
        "bench_request_latency_seconds",
        "arrival-to-completion latency (sync-baseline window flush)",
        buckets=robs.LATENCY_BUCKETS)
    misses_sync = 0
    t0 = time.perf_counter()
    pending = []
    for i in range(n):
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
        pending.append((arrivals[i],
                        _request(xs, coefs, i, thr, deadline_s, tenants)))
        if len(pending) >= max_batch or i == n - 1:
            sync_engine.serve([r for _, r in pending])
            done = time.perf_counter() - t0
            for arr, _ in pending:
                lat = done - arr
                h_sync.observe(lat)
                misses_sync += lat > deadline_s
            pending = []
    sync_wall = time.perf_counter() - t0

    # ---- async dispatcher, same trace
    reg_async = robs.MetricsRegistry()
    async_engine = SolverServeEngine(ServeConfig(), registry=reg_async)
    _prewarm(async_engine, xs, prewarm_sizes, thr)
    # Idle timeout must exceed the mean inter-arrival gap (1/rate) or every
    # batch fires with one request and coalescing never happens; deadline
    # pressure still bounds worst-case wait via the margin.
    dcfg = DispatchConfig(max_queue=4 * n, max_batch=max_batch,
                          deadline_margin_s=deadline_s / 4,
                          idle_timeout_s=4.0 / rate)
    tickets = []
    with AsyncDispatcher(async_engine, dcfg) as disp:
        t0 = time.perf_counter()
        for i in range(n):
            now = time.perf_counter() - t0
            if arrivals[i] > now:
                time.sleep(arrivals[i] - now)
            tickets.append(
                disp.submit(_request(xs, coefs, i, thr, deadline_s, tenants)))
        disp.drain()
        async_wall = time.perf_counter() - t0
        served = [t.result(timeout=60) for t in tickets]
        stats = disp.stats
    # submit ≈ arrival (the loop sleeps until each arrival), so the
    # dispatcher's own submit->complete histogram IS the request latency.
    h_async = reg_async.get("serve_request_latency_seconds")
    misses_async = sum(t.deadline_met is False for t in tickets)

    # accuracy vs fp64 lstsq, both paths exact-tolerance solves
    mapes = []
    for i, s in enumerate(served):
        assert s.ok, s.error
        d = i % len(xs)
        ref = np.linalg.lstsq(xs[d].astype(np.float64),
                              (xs[d] @ coefs[i]).astype(np.float64),
                              rcond=None)[0]
        mapes.append(_mape(s.coef, ref))

    return {
        "obs": obs, "vars": nvars, "n_requests": n, "rate_hz": rate,
        "deadline_s": deadline_s, "max_batch": max_batch,
        "sync_wall_s": sync_wall,
        "async_wall_s": async_wall,
        "sync_solves_per_s": n / sync_wall,
        "async_solves_per_s": n / async_wall,
        "throughput_ratio": sync_wall / async_wall,
        "sync_p50_s": h_sync.percentile(50),
        "sync_p95_s": h_sync.percentile(95),
        "sync_p99_s": h_sync.percentile(99),
        "async_p50_s": h_async.percentile(50),
        "async_p95_s": h_async.percentile(95),
        "async_p99_s": h_async.percentile(99),
        "async_queue_wait_p95_s":
            reg_async.get("serve_queue_wait_seconds").percentile(95),
        "sync_deadline_misses": int(misses_sync),
        "async_deadline_misses": int(misses_async),
        "async_miss_rate": misses_async / n,
        "deadline_hit_rate": stats.deadline_hit_rate,
        "fired_full": stats.fired_full,
        "fired_deadline": stats.fired_deadline,
        "fired_idle": stats.fired_idle,
        "mape_worst": max(mapes),
        "warm_starts": async_engine.stats.warm_starts,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + MAPE-only gate (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_serve.json)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        warm_kw = dict(obs=256, nvars=32, tenants=8, rounds=4, drift=0.001,
                       rtol=1e-3, thr=16)
        async_kw = dict(obs=256, nvars=32, n=min(args.requests, 32),
                        rate=100.0, deadline_s=2.0, max_batch=8, thr=16)
    else:
        warm_kw = dict(obs=2048, nvars=256, tenants=16, rounds=6, drift=0.001,
                       rtol=1e-3, thr=128)
        async_kw = dict(obs=1024, nvars=128, n=args.requests, rate=150.0,
                        deadline_s=1.0, max_batch=16, thr=128)

    warm = bench_warm_start(seed=args.seed, **warm_kw)
    asyn = bench_async(seed=args.seed, **async_kw)

    print("name,us_per_call,derived")
    wtag = (f"serve_warm[o{warm['obs']}xv{warm['vars']}"
            f"t{warm['tenants']}r{warm['rounds']}]")
    print(f"{wtag},,cold_sweeps={warm['cold_mean_sweeps']:.2f};"
          f"warm_sweeps={warm['warm_mean_sweeps']:.2f};"
          f"savings={warm['sweep_savings']:.1%};"
          f"mape_cold={warm['cold_mape_worst']:.2e};"
          f"mape_warm={warm['warm_mape_worst']:.2e}")
    atag = (f"serve_async[o{asyn['obs']}xv{asyn['vars']}"
            f"n{asyn['n_requests']}@{asyn['rate_hz']:.0f}hz]")
    print(f"{atag}/sync,{asyn['sync_wall_s']/asyn['n_requests']*1e6:.0f},"
          f"solves_per_s={asyn['sync_solves_per_s']:.1f};"
          f"p95={asyn['sync_p95_s']*1e3:.1f}ms;"
          f"misses={asyn['sync_deadline_misses']}")
    print(f"{atag}/async,{asyn['async_wall_s']/asyn['n_requests']*1e6:.0f},"
          f"solves_per_s={asyn['async_solves_per_s']:.1f};"
          f"p95={asyn['async_p95_s']*1e3:.1f}ms;"
          f"misses={asyn['async_deadline_misses']};"
          f"hit_rate={asyn['deadline_hit_rate']:.1%};"
          f"mape={asyn['mape_worst']:.2e}")

    metrics = {"warm_start": warm, "async": asyn,
               "mode": "smoke" if args.smoke else "full"}
    if args.json:
        write_json(args.json, metrics)
        print(f"wrote {args.json}")

    mape_worst = max(warm["warm_mape_worst"], warm["cold_mape_worst"],
                     asyn["mape_worst"])
    ok_mape = mape_worst <= 1e-4
    if args.smoke:
        print(f"acceptance (smoke): worst_mape={mape_worst:.2e} (<=1e-4) -> "
              f"{'PASS' if ok_mape else 'FAIL'}")
        return 0 if ok_mape else 1
    ok_warm = warm["sweep_savings"] >= 0.30
    ok_tput = asyn["throughput_ratio"] >= 1.0
    ok_miss = asyn["async_miss_rate"] < 0.05
    print(f"acceptance: sweep_savings={warm['sweep_savings']:.1%} (>=30%) "
          f"tput_ratio={asyn['throughput_ratio']:.2f} (>=1.0) "
          f"miss_rate={asyn['async_miss_rate']:.1%} (<5%) "
          f"worst_mape={mape_worst:.2e} (<=1e-4) -> "
          f"{'PASS' if ok_mape and ok_warm and ok_tput and ok_miss else 'FAIL'}")
    return 0 if (ok_mape and ok_warm and ok_tput and ok_miss) else 1


if __name__ == "__main__":
    sys.exit(main())
