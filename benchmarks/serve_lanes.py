"""Execution-lane throughput: per-placement executors vs the serial thread.

    PYTHONPATH=src python -m benchmarks.serve_lanes [--smoke] \
        [--json BENCH_lanes.json]

A mixed workload — single-device XLA solves (``bakp_gram``), fused Pallas
megakernel solves (``bakp_fused``) and obs-sharded mesh solves (forced
2-virtual-device CPU mesh, set up before jax loads: run as a fresh
process) — is flushed through the same engine twice:

  * **lanes** — ``ServeConfig(lane_execution=True)`` (default): each flush
    fans its batches out across the per-(device set, kernel path) executor
    threads, so the three program families overlap;
  * **serial** — ``lane_execution=False``: every batch drains through ONE
    executor thread, the pre-lane architecture and the baseline the lane
    refactor must beat.

Both runs execute identical batch compositions (the flush grouping is
deterministic and placement-keyed), so results are directly comparable and
the MAPE parity gate is tight.  Reports ``name,us_per_call,derived`` CSV
rows like ``benchmarks.run`` and writes a ``lanes`` section into the JSON
report (BENCH_lanes.json in CI).

Gates: parity MAPE <= 1e-4 vs numpy lstsq, at least two live lanes with
populated per-lane stats, and the lane engine's wall time no worse than
serial (full mode tightens to the ISSUE acceptance: lanes < 0.9x serial).
Wall-clock note: CPU "devices" share physical cores, so smoke mode (CI)
gates correctness + no-regression only, like the other serve benches.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

MESH_SPEC = "2"


def _ensure_devices():
    """Force the virtual CPU mesh before jax initialises its backend."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.solver_serve import ensure_mesh_devices
    ensure_mesh_devices(MESH_SPEC)


def _mape(coef, ref):
    return float(np.mean(np.abs(coef - ref) / np.maximum(np.abs(ref),
                                                         1e-12)))


def run(obs=1024, nvars=128, n_xla=8, n_fused=4, n_mesh=4, thr=64,
        max_iter=40, repeats=3, seed=0):
    from repro.serve import (PlacementPolicy, ServeConfig, SolveRequest,
                             SolverSpec, SolverServeEngine, build_serve_mesh)

    rng = np.random.default_rng(seed)
    policy = PlacementPolicy(obs_shard_min_cells=obs * nvars,
                             rhs_shard_min_k=10 ** 9)

    def spec(method, nv):
        # cap thr below the var count: the solvers need >= 2 column blocks
        # (thr == nvars degenerates the fused kernel's block sweep).
        return SolverSpec(method=method, thr=min(thr, nv // 2),
                          max_iter=max_iter, rtol=0.0)

    systems = []  # (tag, x, a, method)
    for i in range(n_xla):  # small bucket -> single:xla
        x = rng.normal(size=(obs // 4, nvars // 2)).astype(np.float32)
        systems.append((f"xla-{i}", x,
                        rng.normal(size=(nvars // 2,)).astype(np.float32),
                        "bakp_gram"))
    for i in range(n_fused):  # small bucket -> single:fused
        x = rng.normal(size=(obs // 4, nvars // 2)).astype(np.float32)
        systems.append((f"fused-{i}", x,
                        rng.normal(size=(nvars // 2,)).astype(np.float32),
                        "bakp_fused"))
    for i in range(n_mesh):  # big bucket -> mesh:obs_sharded
        x = rng.normal(size=(obs, nvars)).astype(np.float32)
        systems.append((f"mesh-{i}", x,
                        rng.normal(size=(nvars,)).astype(np.float32),
                        "bakp_gram"))

    def reqs():
        return [SolveRequest(x=x, y=x @ a, spec=spec(m, x.shape[1]),
                             design_key=tag, request_id=tag)
                for tag, x, a, m in systems]

    smesh = build_serve_mesh(MESH_SPEC)
    engines = {}
    for name, lane_exec in (("lanes", True), ("serial", False)):
        engines[name] = SolverServeEngine(
            ServeConfig(placement_policy=policy, lane_execution=lane_exec,
                        vmap_batch=False),
            mesh=smesh)
        engines[name].serve(reqs())  # warm: compile + design cache

    walls = {}
    results = {}
    for name, eng in engines.items():
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            results[name] = eng.serve(reqs())
            best = min(best, time.perf_counter() - t0)
        walls[name] = best

    served = results["lanes"]
    assert not [r.error for r in served + results["serial"] if r.error]
    refs = {tag: np.linalg.lstsq(x.astype(np.float64),
                                 (x @ a).astype(np.float64), rcond=None)[0]
            for tag, x, a, _ in systems}
    mape = max(_mape(r.coef, refs[r.request_id]) for r in served)
    parity = max(_mape(m.coef, s.coef)
                 for m, s in zip(served, results["serial"]))

    lane_stats = engines["lanes"].lanes.stats()
    serial_stats = engines["serial"].lanes.stats()
    n = len(systems)
    out = {
        "requests": n,
        "lanes_s": walls["lanes"], "serial_s": walls["serial"],
        "lanes_solves_per_s": n / walls["lanes"],
        "serial_solves_per_s": n / walls["serial"],
        # >1 means the lane engine beat the single-solver-thread baseline.
        "speedup": walls["serial"] / walls["lanes"],
        "mape_worst": mape,
        "parity_mape_worst": parity,
        "lane_stats": lane_stats,
        "serial_lane_stats": serial_stats,
        "live_lanes": sorted(lane_stats),
        "mesh": MESH_SPEC,
        "obs": obs, "vars": nvars,
    }
    for eng in engines.values():
        eng.shutdown()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + correctness/no-regression gate (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report (BENCH_lanes.json)")
    args = ap.parse_args()

    _ensure_devices()
    if args.smoke:
        r = run(obs=512, nvars=64, n_xla=6, n_fused=3, n_mesh=2, thr=32,
                max_iter=40, repeats=3)
    else:
        r = run()
    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"lanes": r})

    print("name,us_per_call,derived")
    tag = f"serve_lanes[o{r['obs']}xv{r['vars']}/mesh{r['mesh']}]"
    print(f"{tag}/lanes,{r['lanes_s']/r['requests']*1e6:.0f},"
          f"solves_per_s={r['lanes_solves_per_s']:.1f};"
          f"speedup={r['speedup']:.2f};mape={r['mape_worst']:.2e};"
          f"parity={r['parity_mape_worst']:.2e}")
    print(f"{tag}/serial,{r['serial_s']/r['requests']*1e6:.0f},"
          f"solves_per_s={r['serial_solves_per_s']:.1f}")
    for label, ls in sorted(r["lane_stats"].items()):
        print(f"{tag}/lane:{label},,batches={ls['batches']};"
              f"requests={ls['requests']};busy_ms={ls['busy_s']*1e3:.1f}")

    lanes_live = (len(r["live_lanes"]) >= 2
                  and all(ls["batches"] >= 1 and ls["requests"] >= 1
                          for ls in r["lane_stats"].values())
                  and set(r["serial_lane_stats"]) == {"serial"})
    # Smoke (CI, virtual CPU devices): correctness-gated — the "devices"
    # share physical cores, so lane overlap buys nothing reliable there and
    # the wall-time ratio is informational, with a loose floor that only
    # catches catastrophic serialisation (lanes accidentally running the
    # whole workload twice, a lane deadlock resolving through timeouts).
    # Full mode enforces the acceptance criterion: mixed-lane wall < 0.9x
    # the single-solver-thread wall (run on hardware where lanes own real
    # devices).
    need = 0.5 if args.smoke else 1.0 / 0.9
    ok = (r["mape_worst"] <= 1e-4 and r["parity_mape_worst"] <= 1e-5
          and lanes_live and r["speedup"] >= need)
    print(f"acceptance: worst_mape={r['mape_worst']:.2e} (<=1e-4) "
          f"parity={r['parity_mape_worst']:.2e} (<=1e-5) "
          f"lanes={r['live_lanes']} (>=2 live) "
          f"speedup={r['speedup']:.2f}x (>={need:.2f}) -> "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
