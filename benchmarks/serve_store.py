"""Tiered design store: over-budget fleets + streaming out-of-core solves.

    PYTHONPATH=src python -m benchmarks.serve_store [--smoke] \
        [--json BENCH_store.json]

A fleet of distinct designs whose combined bytes exceed a shrunken
in-process device budget is served twice through a store-backed engine
(``ServeConfig(store_device_bytes=..., store_host_bytes=...,
store_dir=...)``), so the second pass hits designs that were demoted to
the host and disk tiers and promotes them back.  One extra design is
sized past the device budget entirely: the engine reroutes it to the
``"bakp_stream"`` out-of-core method, which fetches X tiles per block
through the store instead of holding the matrix on device.

An identical workload runs through a storeless all-resident engine as the
accuracy baseline, and both passes are timed for the CSV rows.  Writes a
``store`` section into the JSON report (BENCH_store.json in CI).

Gates (the ISSUE acceptance):

  * parity MAPE <= 1e-4 vs the all-resident engine, zero errors;
  * at least one disk-tier round trip (``promotions_disk >= 1``) — a
    design demoted device → host → disk must climb all the way back;
  * the streamed solve's resident x bytes (double-buffered tile pair,
    ``stream_x_resident_bytes``) under 0.25x the full-resident matrix,
    and the over-HBM reroute observed in ``solver_fallback_total``.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import numpy as np


def _mape(coef, ref):
    return float(np.mean(np.abs(coef - ref) / np.maximum(np.abs(ref),
                                                         1e-12)))


def run(n_designs=24, obs_n=256, nvars=64, thr=16, big_obs=512, big_vars=256,
        big_thr=16, max_iter=60, device_designs=6, host_designs=4, seed=0):
    from repro import obs
    from repro.kernels.stream_solve import stream_x_resident_bytes
    from repro.serve import (ServeConfig, SolveRequest, SolverServeEngine)

    rng = np.random.default_rng(seed)
    systems = []  # (key, x, a, thr)
    for i in range(n_designs):
        x = rng.normal(size=(obs_n, nvars)).astype(np.float32)
        systems.append((f"d{i}", x,
                        rng.normal(size=(nvars,)).astype(np.float32), thr))
    xb = rng.normal(size=(big_obs, big_vars)).astype(np.float32)
    systems.append(("big", xb,
                    rng.normal(size=(big_vars,)).astype(np.float32),
                    big_thr))

    def reqs():
        return [SolveRequest(x=x, y=x @ a, method="bakp", thr=t,
                             max_iter=max_iter, rtol=1e-12,
                             design_key=key, request_id=key)
                for key, x, a, t in systems]

    design_bytes = obs_n * nvars * 4  # fleet designs land in one bucket
    with tempfile.TemporaryDirectory(prefix="repro-store-bench-") as tmp:
        reg = obs.MetricsRegistry()
        store_eng = SolverServeEngine(
            ServeConfig(store_device_bytes=device_designs * design_bytes,
                        store_host_bytes=host_designs * design_bytes,
                        store_dir=tmp, cache_entries=4 * n_designs),
            registry=reg)
        base_eng = SolverServeEngine(
            ServeConfig(cache_entries=4 * n_designs),
            registry=obs.MetricsRegistry())

        walls = {}
        results = {}
        for name, eng in (("store", store_eng), ("resident", base_eng)):
            eng.serve(reqs())  # pass 1: compile, populate, demote
            t0 = time.perf_counter()
            results[name] = eng.serve(reqs())  # pass 2: promotion churn
            walls[name] = time.perf_counter() - t0

        errors = [r.error for r in results["store"] if r.error]
        mape = max(_mape(a.coef, b.coef) for a, b in
                   zip(results["store"], results["resident"]))
        st = store_eng.store.stats.as_dict()
        tiers = {"device": store_eng.store.device_used(),
                 "host": store_eng.store.host_used(),
                 "disk": store_eng.store.disk_used()}
        rerouted = reg.get("solver_fallback_total").value(reason="over_hbm")
        store_eng.shutdown()
        base_eng.shutdown()

    # Streamed-solve x residency: the double-buffered tile pair the kernel
    # keeps on chip vs the matrix bytes a resident method would hold.
    x_resident = stream_x_resident_bytes(big_thr, big_obs, 4)
    x_full = big_vars * big_obs * 4
    n = len(systems)
    return {
        "requests": n, "designs": n,
        "device_budget_designs": device_designs,
        "store_s": walls["store"], "resident_s": walls["resident"],
        "store_solves_per_s": n / walls["store"],
        "resident_solves_per_s": n / walls["resident"],
        "mape_worst": mape, "errors": len(errors),
        "over_hbm_reroutes": rerouted,
        "stream_x_resident_bytes": x_resident,
        "stream_x_full_bytes": x_full,
        "stream_x_resident_ratio": x_resident / x_full,
        "tier_bytes": tiers,
        "store_stats": st,
        "obs": obs_n, "vars": nvars, "thr": thr,
        "big_obs": big_obs, "big_vars": big_vars, "big_thr": big_thr,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + correctness gates (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report (BENCH_store.json)")
    args = ap.parse_args()

    if args.smoke:
        r = run(n_designs=16, obs_n=128, nvars=32, thr=8, big_obs=256,
                big_vars=128, big_thr=8, max_iter=60)
    else:
        r = run()
    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"store": r})

    st = r["store_stats"]
    print("name,us_per_call,derived")
    tag = f"serve_store[o{r['obs']}xv{r['vars']}/{r['designs']}designs]"
    print(f"{tag}/store,{r['store_s']/r['requests']*1e6:.0f},"
          f"solves_per_s={r['store_solves_per_s']:.1f};"
          f"mape={r['mape_worst']:.2e};"
          f"demotions={st['demotions_device']};"
          f"promotions={st['promotions_host'] + st['promotions_disk']}")
    print(f"{tag}/resident,{r['resident_s']/r['requests']*1e6:.0f},"
          f"solves_per_s={r['resident_solves_per_s']:.1f}")
    print(f"{tag}/stream,,x_resident_ratio="
          f"{r['stream_x_resident_ratio']:.3f};"
          f"over_hbm_reroutes={r['over_hbm_reroutes']:.0f};"
          f"disk_round_trips={st['promotions_disk']}")

    ok = (r["errors"] == 0 and r["mape_worst"] <= 1e-4
          and st["promotions_disk"] >= 1
          and r["over_hbm_reroutes"] >= 1
          and r["stream_x_resident_ratio"] < 0.25)
    print(f"acceptance: worst_mape={r['mape_worst']:.2e} (<=1e-4) "
          f"errors={r['errors']} (==0) "
          f"disk_round_trips={st['promotions_disk']} (>=1) "
          f"over_hbm={r['over_hbm_reroutes']:.0f} (>=1) "
          f"x_resident_ratio={r['stream_x_resident_ratio']:.3f} (<0.25) -> "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
