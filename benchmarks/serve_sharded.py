"""Sharded-serving throughput: placement-routed mesh solves vs single-device.

    PYTHONPATH=src python -m benchmarks.serve_sharded [--smoke] \
        [--json BENCH_shard.json]

Exercises the two serving mesh placements on a forced 8-virtual-device CPU
mesh (set up before jax loads, so run this as a fresh process):

  * **obs-sharded** — distinct big-bucket designs routed to
    ``solvebakp_obs_sharded`` (rows over the data axes), vs the same
    workload on a mesh-less engine;
  * **rhs-sharded** — one giant same-design group (k right-hand sides)
    routed to ``solvebakp_rhs_sharded`` (k over the data axes, ``x``
    replicated), vs the single-device coalesced multi-RHS solve.

Reports ``name,us_per_call,derived`` CSV rows like ``benchmarks.run`` and
writes a ``sharded`` section into the JSON report (BENCH_shard.json in CI).
Wall-clock note: virtual CPU "devices" share the same physical cores, so
sharded throughput here measures dispatch overhead + correctness, not real
mesh scaling — the gate is therefore MAPE-only (<= 1e-4), with the
throughput numbers informational, exactly like the other serve benches'
``--smoke`` mode.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

MESH_SPEC = "4x2"


def _ensure_devices():
    """Force the virtual CPU mesh before jax initialises its backend."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.solver_serve import ensure_mesh_devices
    ensure_mesh_devices(MESH_SPEC)


def _mape(coef, ref, denom):
    return float(np.mean(np.abs(coef - ref) / denom))


def _serve_timed(engine, reqs):
    t0 = time.perf_counter()
    out = engine.serve(reqs)
    return out, time.perf_counter() - t0


def run(obs=2048, nvars=256, n_designs=8, k=64, thr=128, max_iter=40,
        seed=0):
    from repro.serve import (PlacementPolicy, ServeConfig, SolveRequest,
                             SolverSpec, SolverServeEngine, build_serve_mesh)

    smesh = build_serve_mesh(MESH_SPEC)
    spec = SolverSpec(method="bakp_gram", thr=thr, max_iter=max_iter,
                      rtol=0.0)
    # Thresholds sized so the benchmark's big bucket (obs × vars) routes
    # obs-sharded and the k-group routes rhs-sharded — the policy under
    # test is the routing machinery, not the default production numbers.
    policy = PlacementPolicy(obs_shard_min_cells=obs * nvars,
                            rhs_shard_min_k=min(k, 32))
    rng = np.random.default_rng(seed)

    # obs-sharded scenario: n_designs distinct big designs, no coalescing.
    big = [rng.normal(size=(obs, nvars)).astype(np.float32)
           for _ in range(n_designs)]
    big_a = [rng.normal(size=(nvars,)).astype(np.float32) for _ in big]

    def obs_reqs():
        return [SolveRequest(x=x, y=x @ a, spec=spec,
                             design_key=f"big-{i}", request_id=f"big-{i}")
                for i, (x, a) in enumerate(zip(big, big_a))]

    # rhs-sharded scenario: one small-bucket design shared by k tenants.
    xs = rng.normal(size=(obs // 4, nvars // 4)).astype(np.float32)
    A = rng.normal(size=(nvars // 4, k)).astype(np.float32)
    ys = xs @ A

    def rhs_reqs():
        return [SolveRequest(x=xs, y=ys[:, i], spec=spec, design_key="grp",
                             request_id=f"grp-{i}")
                for i in range(k)]

    eng_mesh = SolverServeEngine(
        ServeConfig(placement_policy=policy, vmap_batch=False), mesh=smesh)
    eng_single = SolverServeEngine(ServeConfig(vmap_batch=False))

    # Warm both engines (compile + design cache), then time a second pass.
    for eng in (eng_mesh, eng_single):
        eng.serve(obs_reqs())
        eng.serve(rhs_reqs())

    out = {}
    for name, mk, xref, aref in (
            ("obs_sharded", obs_reqs, None, None),
            ("rhs_sharded", rhs_reqs, xs, A)):
        served_m, t_m = _serve_timed(eng_mesh, mk())
        served_s, t_s = _serve_timed(eng_single, mk())
        if name == "obs_sharded":
            assert all(r.placement == "obs_sharded" for r in served_m), \
                [r.placement for r in served_m]
            refs = [np.linalg.lstsq(x.astype(np.float64),
                                    (x @ a).astype(np.float64),
                                    rcond=None)[0]
                    for x, a in zip(big, big_a)]
        else:
            assert all(r.placement == "rhs_sharded" for r in served_m), \
                [r.placement for r in served_m]
            assert all(r.batch_kind == "multi_rhs" for r in served_m)
            refs = list(np.linalg.lstsq(xref.astype(np.float64),
                                        (xref @ aref).astype(np.float64),
                                        rcond=None)[0].T)
        assert all(r.placement == "single" for r in served_s)
        mapes_m = [_mape(r.coef, ref, np.maximum(np.abs(ref), 1e-12))
                   for r, ref in zip(served_m, refs)]
        # Sharded-vs-single parity (the acceptance criterion the tests pin
        # at 1e-5; reported here so regressions show up in the JSON too).
        parity = [_mape(m.coef, s.coef, np.maximum(np.abs(s.coef), 1e-12))
                  for m, s in zip(served_m, served_s)]
        out[name] = {
            "requests": len(served_m),
            "sharded_s": t_m, "single_s": t_s,
            "sharded_solves_per_s": len(served_m) / t_m,
            "single_solves_per_s": len(served_s) / t_s,
            "mape_worst": max(mapes_m),
            "parity_mape_worst": max(parity),
        }
    out["mesh"] = MESH_SPEC
    out["obs"], out["vars"], out["k"] = obs, nvars, k
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + MAPE-only gate (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report (BENCH_shard.json)")
    args = ap.parse_args()

    _ensure_devices()
    obs, nvars, k = (512, 64, 32) if args.smoke else (2048, 256, 64)
    r = run(obs=obs, nvars=nvars, n_designs=4 if args.smoke else 8, k=k,
            thr=min(128, nvars))
    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"sharded": r})

    print("name,us_per_call,derived")
    for name in ("obs_sharded", "rhs_sharded"):
        m = r[name]
        tag = f"serve_sharded[{name}/o{r['obs']}xv{r['vars']}/mesh{r['mesh']}]"
        print(f"{tag}/sharded,{m['sharded_s']/m['requests']*1e6:.0f},"
              f"solves_per_s={m['sharded_solves_per_s']:.1f};"
              f"mape={m['mape_worst']:.2e};"
              f"parity={m['parity_mape_worst']:.2e}")
        print(f"{tag}/single,{m['single_s']/m['requests']*1e6:.0f},"
              f"solves_per_s={m['single_solves_per_s']:.1f}")
    worst = max(r[n]["mape_worst"] for n in ("obs_sharded", "rhs_sharded"))
    parity = max(r[n]["parity_mape_worst"]
                 for n in ("obs_sharded", "rhs_sharded"))
    # Both gates run in CI: accuracy vs lstsq AND the ISSUE acceptance
    # criterion that placement-routed results match the single-device
    # engine at MAPE <= 1e-5 (the slow-marked parity test is deselected in
    # the tier-1 job, so this is its CI enforcement point).
    ok = worst <= 1e-4 and parity <= 1e-5
    print(f"acceptance: worst_mape={worst:.2e} (<=1e-4) "
          f"parity={parity:.2e} (<=1e-5) -> "
          f"{'PASS' if ok else 'FAIL'} (throughput informational on "
          f"virtual-device CPU meshes)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
