"""Serving-engine throughput: multi-RHS coalescing vs sequential solve().

    PYTHONPATH=src python -m benchmarks.serve_throughput [--fast]

The acceptance scenario for ``repro.serve``: 64 tenants share one design
matrix (the repeated-X workload serving is built for).  The baseline answers
them with 64 sequential one-shot ``repro.core.solve`` calls; a second
baseline holds a ``prepare(x, spec)`` handle and runs 64 per-RHS
``handle.solve(y)`` calls (the design state — column norms, Gram factors —
amortised, but still one stream of ``x`` per request); the engine coalesces
them into ONE multi-RHS solve — one stream of ``x`` serves all 64.  All
paths are jit-warmed before timing, so the speedups are steady-state
compute, not compile time.

Prints ``name,us_per_call,derived`` CSV rows like ``benchmarks.run`` and
exits non-zero if speedup < 5x or any per-request MAPE vs lstsq > 1e-3.
``--smoke`` (CI) gates on MAPE <= 1e-4 only — wall-clock speedup ratios on
shared runners are noise — and still reports the speedup.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def run(obs=2048, nvars=256, n_requests=64, method="bakp_gram", thr=128,
        max_iter=40, rtol=1e-10, seed=0):
    import jax
    import jax.numpy as jnp

    from repro import obs as robs
    from repro.core import SolverSpec, prepare, solve
    from repro.serve import ServeConfig, SolveRequest, SolverServeEngine

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(obs, nvars)).astype(np.float32)
    coefs = rng.normal(size=(nvars, n_requests)).astype(np.float32)
    ys = (x @ coefs).astype(np.float32)
    xd = jnp.asarray(x)
    spec = SolverSpec(method=method, max_iter=max_iter, rtol=rtol, thr=thr)

    def sequential():
        out = []
        for i in range(n_requests):
            res = solve(xd, jnp.asarray(ys[:, i]), spec=spec)
            jax.block_until_ready(res.coef)
            out.append(np.asarray(res.coef))
        return out

    handle = prepare(xd, spec)

    def prepared_sequential():
        out = []
        for i in range(n_requests):
            res = handle.solve(jnp.asarray(ys[:, i]))
            jax.block_until_ready(res.coef)
            out.append(np.asarray(res.coef))
        return out

    def make_requests():
        return [SolveRequest(x=x, y=ys[:, i], spec=spec,
                             design_key="bench-design",
                             request_id=f"req-{i}")
                for i in range(n_requests)]

    # Private registry so the timed window's histograms are not polluted by
    # the warmup flush (reset after warming, below).
    reg = robs.MetricsRegistry()
    engine = SolverServeEngine(ServeConfig(), registry=reg)

    # Warm all paths (jit compile + design state + engine design cache).
    sequential()
    prepared_sequential()
    engine.serve(make_requests())
    reg.reset()

    t0 = time.perf_counter()
    seq_coefs = sequential()
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    prep_coefs = prepared_sequential()
    t_prep = time.perf_counter() - t0

    t0 = time.perf_counter()
    served = engine.serve(make_requests())
    t_eng = time.perf_counter() - t0

    ref = np.linalg.lstsq(x.astype(np.float64), ys.astype(np.float64),
                          rcond=None)[0]
    denom = np.maximum(np.abs(ref), 1e-12)
    mape_eng = [float(np.mean(np.abs(served[i].coef - ref[:, i]) / denom[:, i]))
                for i in range(n_requests)]
    mape_seq = [float(np.mean(np.abs(seq_coefs[i] - ref[:, i]) / denom[:, i]))
                for i in range(n_requests)]
    mape_prep = [float(np.mean(np.abs(prep_coefs[i] - ref[:, i])
                               / denom[:, i]))
                 for i in range(n_requests)]

    assert all(r.batch_kind == "multi_rhs" for r in served), \
        "engine failed to coalesce same-design requests"
    assert all(r.cache_hit for r in served), "design cache missed on warm run"

    # Percentiles come from the registry the engine itself records into —
    # the same families a production scrape would see, not a parallel
    # hand-rolled latency list.
    lat = reg.get("serve_solve_latency_seconds")
    path = (served[0].telemetry.kernel_path
            if served[0].telemetry is not None else "unknown")
    return {
        "obs": obs, "vars": nvars, "n_requests": n_requests,
        "method": method,
        "seq_s": t_seq, "prepared_s": t_prep, "engine_s": t_eng,
        "speedup": t_seq / t_eng,
        "prepared_speedup": t_seq / t_prep,
        "seq_solves_per_s": n_requests / t_seq,
        "prepared_solves_per_s": n_requests / t_prep,
        "engine_solves_per_s": n_requests / t_eng,
        "engine_solve_p50_s": lat.percentile(50),
        "engine_solve_p95_s": lat.percentile(95),
        "engine_solve_p99_s": lat.percentile(99),
        "engine_kernel_path": path,
        "mape_worst": max(mape_eng),
        "mape_seq_worst": max(mape_seq),
        "mape_prepared_worst": max(mape_prep),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller system")
    ap.add_argument("--smoke", action="store_true",
                    help="--fast sizes + MAPE-only gate (CI: wall-clock "
                         "speedup ratios are noise on shared runners)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--method", default="bakp_gram")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report (BENCH_serve.json)")
    args = ap.parse_args()

    obs, nvars = (512, 64) if (args.fast or args.smoke) else (2048, 256)
    r = run(obs=obs, nvars=nvars, n_requests=args.requests,
            method=args.method)
    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"throughput": r})

    print("name,us_per_call,derived")
    tag = f"serve[o{r['obs']}xv{r['vars']}k{r['n_requests']}/{r['method']}]"
    print(f"{tag}/sequential,{r['seq_s']/r['n_requests']*1e6:.0f},"
          f"solves_per_s={r['seq_solves_per_s']:.1f};"
          f"mape={r['mape_seq_worst']:.2e}")
    print(f"{tag}/prepared,{r['prepared_s']/r['n_requests']*1e6:.0f},"
          f"solves_per_s={r['prepared_solves_per_s']:.1f};"
          f"mape={r['mape_prepared_worst']:.2e};"
          f"speedup={r['prepared_speedup']:.2f}")
    print(f"{tag}/engine,{r['engine_s']/r['n_requests']*1e6:.0f},"
          f"solves_per_s={r['engine_solves_per_s']:.1f};"
          f"mape={r['mape_worst']:.2e};speedup={r['speedup']:.2f};"
          f"path={r['engine_kernel_path']};"
          f"solve_p50={r['engine_solve_p50_s']*1e3:.2f}ms;"
          f"solve_p99={r['engine_solve_p99_s']*1e3:.2f}ms")
    if args.smoke:
        ok = r["mape_worst"] <= 1e-4
        print(f"acceptance (smoke): worst_mape={r['mape_worst']:.2e} "
              f"(<=1e-4) -> {'PASS' if ok else 'FAIL'} "
              f"(speedup={r['speedup']:.2f}x, informational)")
        return 0 if ok else 1
    ok = r["speedup"] >= 5.0 and r["mape_worst"] <= 1e-3
    print(f"acceptance: speedup={r['speedup']:.2f}x (>=5x) "
          f"worst_mape={r['mape_worst']:.2e} (<=1e-3) -> "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
