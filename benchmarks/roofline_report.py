"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    return f"{b/2**30:.2f}GiB"


def load_all(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def row_key(r):
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    return (r["arch"], order[r["cell"]], r["mesh"])


def table(rows, mesh="16x16"):
    out = []
    hdr = ("| arch | cell | compute_s | memory_s | coll_s | bottleneck | "
           "useful/total | fits16G | peak/dev | compile_s |")
    out.append(hdr)
    out.append("|" + "---|" * 10)
    for r in sorted(rows, key=row_key):
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        m = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['cell']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{t['bottleneck'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{'Y' if m['fits_16gb'] else 'N'} | "
            f"{fmt_bytes(m['peak_bytes_per_device'])} | "
            f"{r['compile_s']:.0f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_all(args.dir)
    print(f"{len(rows)} cells loaded")
    print(table(rows, args.mesh))
    # candidates for hillclimbing
    sp = [r for r in rows if r["mesh"] == "16x16"]
    worst = sorted(sp, key=lambda r: r["useful_flops_ratio"])[:5]
    coll = sorted(sp, key=lambda r: -r["roofline"]["collective_s"] /
                  max(max(r["roofline"]["compute_s"],
                          r["roofline"]["memory_s"]), 1e-12))[:5]
    print("\nworst useful-flops ratio:",
          [(r["arch"], r["cell"], round(r["useful_flops_ratio"], 3))
           for r in worst])
    print("most collective-bound:",
          [(r["arch"], r["cell"],
            round(r["roofline"]["collective_s"] /
                  max(r["roofline"]["memory_s"],
                      r["roofline"]["compute_s"], 1e-12), 2))
           for r in coll])


if __name__ == "__main__":
    main()
