"""Mixed-precision X streaming benchmark (SolverSpec.precision).

The solver is memory-bound (~4 flops per x byte — see solver_roofline.py),
so storing the streamed design in bf16 halves the dominant HBM term and
doubles the design size that fits the fused megakernel's VMEM budget.  The
accuracy cost is bounded by the fp32 polish: ``precision="bf16_fp32acc"``
re-runs ``refine_sweeps`` fp32 sweeps from the bf16 solution.

Two regimes per run, both solved through the public ``prepare``/``solve``
API with the in-process ``VMEM_BUDGET_BYTES`` shrunk to force each regime
(kernels run in interpret mode on CPU; the bytes accounting is analytic and
charges each path the x bytes it *actually* streams, using the recorded
dispatch path and executed sweep counts — rtol=atol=0 so the counts are
exact, ``max_iter`` for the low-precision pass plus ``refine_sweeps`` for
the polish):

  streaming — budget below every fused footprint: fp32 falls back to the
    XLA per-sweep stream (4 bytes/elt/sweep) while bf16 keeps the per-sweep
    Pallas stream at 2 bytes/elt/sweep + fp32 polish sweeps;
  vmem-expansion — budget strictly between the bf16 and fp32 fused
    working sets: the SAME design dispatches FUSED at bf16 (x crosses HBM
    once) and falls off the fused path at fp32.

CI gates (--smoke):
  * post-refinement error vs an fp64 lstsq reference, MAPE
    (sum |coef - ref| / sum |ref|) <= 1e-4 on every shape;
  * bf16_fp32acc moves < 0.6x the fp32 x bytes on every shape;
  * at least one shape dispatches fused at bf16 while fp32 does not.

    PYTHONPATH=src python -m benchmarks.solver_precision --smoke \
        --json BENCH_precision.json
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
from typing import Dict, List

import numpy as np

_CD = importlib.import_module("repro.kernels.cd_sweep")


def _make_design(rng, obs: int, nvars: int) -> np.ndarray:
    """Well-conditioned design (singular values in [1, 2]): the fp32/bf16
    gap is then pure representation error, not conditioning amplification,
    and the fp32 polish contracts it geometrically."""
    u, _ = np.linalg.qr(rng.normal(size=(obs, nvars)))
    v, _ = np.linalg.qr(rng.normal(size=(nvars, nvars)))
    return ((u * np.linspace(1.0, 2.0, nvars)) @ v).astype(np.float32)


def _x_bytes_moved(obs: int, nvars: int, *, precision: str, path: str,
                   n_lp: int, n_polish: int, polish_path: str) -> int:
    """Analytic x-HBM-traffic for one solve, charging executed sweeps.

    fused crosses x once per (sub)solve; persweep/xla stream it per sweep.
    The polish always streams fp32.
    """
    x32 = obs * nvars * 4
    x16 = obs * nvars * 2
    lp_elt = x32 if precision == "fp32" else x16
    total = lp_elt if path == "fused" else n_lp * lp_elt
    if n_polish:
        total += x32 if polish_path == "fused" else n_polish * x32
    return total


def bench_precision(shapes=None, *, seed=0) -> List[Dict]:
    import jax

    from repro import obs as obs_mod
    from repro.core import SolverSpec, prepare
    from repro.kernels.fused_solve import fused_vmem_bytes

    if shapes is None:
        shapes = [
            # (name, regime, obs, nvars, thr, max_iter, refine)
            ("tall", "streaming", 4096, 256, 32, 150, 8),
            ("square", "vmem_expansion", 1024, 1024, 128, 40, 8),
        ]
    rng = np.random.default_rng(seed)
    saved_budget = _CD.VMEM_BUDGET_BYTES
    rows = []
    try:
        for name, regime, obs, nvars, thr, max_iter, refine in shapes:
            x = _make_design(rng, obs, nvars)
            a = rng.normal(size=(nvars,)).astype(np.float32)
            y = (x @ a).astype(np.float32)
            ref = np.linalg.lstsq(x.astype(np.float64),
                                  y.astype(np.float64), rcond=None)[0]

            need32 = fused_vmem_bytes(nvars, obs, 1, 4, max_iter=max_iter)
            need16 = fused_vmem_bytes(nvars, obs, 1, 2, max_iter=max_iter)
            # streaming: just below the smallest fused footprint (bf16), so
            # nothing fuses but every per-sweep tile still fits; the
            # vmem-expansion budget sits strictly between the bf16 and fp32
            # fused working sets.
            budget = (need16 - 1 if regime == "streaming"
                      else (need16 + need32) // 2)
            _CD.VMEM_BUDGET_BYTES = budget
            polish_fits = fused_vmem_bytes(
                nvars, obs, 1, 4, max_iter=refine) <= budget

            # rtol=atol=0: every sweep in the budget executes, so the
            # analytic bytes accounting below is exact, not modelled.
            base = SolverSpec(method="bakp_fused", thr=thr,
                              max_iter=max_iter)
            design = prepare(x, base)
            row = {"shape": name, "regime": regime, "obs": obs,
                   "vars": nvars, "thr": thr, "max_iter": max_iter,
                   "refine_sweeps": refine,
                   "vmem_budget_bytes": budget,
                   "fused_bytes_fp32": need32,
                   "fused_bytes_bf16": need16}
            for precision in ("fp32", "bf16", "bf16_fp32acc"):
                spec = base.replace(precision=precision,
                                    refine_sweeps=refine)
                jax.block_until_ready(design.solve(y, spec=spec).coef)
                obs_mod.consume_dispatch()
                t0 = time.perf_counter()
                res = design.solve(y, spec=spec)
                jax.block_until_ready(res.coef)
                wall = time.perf_counter() - t0
                path = obs_mod.consume_dispatch()
                n_pol = refine if precision == "bf16_fp32acc" else 0
                bytes_moved = _x_bytes_moved(
                    obs, nvars, precision=precision, path=path,
                    n_lp=int(res.n_sweeps) - n_pol, n_polish=n_pol,
                    polish_path="fused" if polish_fits else "persweep")
                coef = np.asarray(res.coef, np.float64)
                row[precision] = {
                    "path": path, "n_sweeps": int(res.n_sweeps),
                    "wall_s": wall, "x_bytes_moved": bytes_moved,
                    "max_abs_err_vs_lstsq":
                        float(np.max(np.abs(coef - ref))),
                    "mape_vs_lstsq":
                        float(np.sum(np.abs(coef - ref))
                              / np.sum(np.abs(ref))),
                }
            row["bf16acc_bytes_ratio_vs_fp32"] = (
                row["bf16_fp32acc"]["x_bytes_moved"]
                / row["fp32"]["x_bytes_moved"])
            rows.append(row)
    finally:
        _CD.VMEM_BUDGET_BYTES = saved_budget
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + CI gates: post-refinement MAPE "
                         "<= 1e-4, bf16 x bytes < 0.6x fp32, and the "
                         "vmem-expansion shape dispatches fused at bf16 "
                         "only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report "
                         "(BENCH_precision.json)")
    args = ap.parse_args()

    if args.smoke:
        shapes = [("tall", "streaming", 2048, 128, 32, 150, 8),
                  ("square", "vmem_expansion", 512, 64, 16, 40, 8)]
    else:
        shapes = None
    rows = bench_precision(shapes)

    print("name,us_per_call,derived")
    for r in rows:
        for prec in ("fp32", "bf16", "bf16_fp32acc"):
            p = r[prec]
            print(f"precision[{r['shape']}:o{r['obs']}xv{r['vars']}"
                  f"/{r['regime']}]/{prec},{p['wall_s']*1e6:.0f},"
                  f"path={p['path']};n_sweeps={p['n_sweeps']};"
                  f"xbytes={p['x_bytes_moved']};"
                  f"mape={p['mape_vs_lstsq']:.2e}")

    worst_mape = max(r["bf16_fp32acc"]["mape_vs_lstsq"] for r in rows)
    worst_ratio = max(r["bf16acc_bytes_ratio_vs_fp32"] for r in rows)
    vmem_rows = [r for r in rows if r["regime"] == "vmem_expansion"]
    fused_expansion = any(
        r["bf16_fp32acc"]["path"] == "fused" and r["fp32"]["path"] != "fused"
        for r in vmem_rows)
    gates = {
        "worst_post_refine_mape": worst_mape,
        "mape_pass": worst_mape <= 1e-4,
        "worst_bf16acc_bytes_ratio": worst_ratio,
        "bytes_pass": worst_ratio < 0.6,
        "bf16_only_fused_dispatch_pass": fused_expansion,
    }

    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"precision_paths": rows,
                               "precision_gates": gates})

    ok = (gates["mape_pass"] and gates["bytes_pass"]
          and gates["bf16_only_fused_dispatch_pass"])
    print(f"acceptance: post-refinement MAPE {worst_mape:.2e} (<=1e-4) -> "
          f"{'PASS' if gates['mape_pass'] else 'FAIL'}; "
          f"bf16acc x-bytes {worst_ratio:.2f}x fp32 (<0.6) -> "
          f"{'PASS' if gates['bytes_pass'] else 'FAIL'}; "
          f"bf16-only fused dispatch -> "
          f"{'PASS' if fused_expansion else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
