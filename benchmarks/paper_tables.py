"""Paper-table reproductions, scaled to this container (CPU, 1 core).

Table 1  — execution time / accuracy (MAPE) / memory for BAK vs BAKP vs the
           LAPACK path (numpy lstsq = LAPACK gelsd, and the normal-equation
           Cholesky which is the *fast* direct baseline for tall systems).
           The paper's largest cases (obs 1e7, vars 1e4) exceed this
           container; the (vars, obs) grid keeps the paper's tall/wide
           aspect ratios at feasible sizes and EXPERIMENTS.md maps each row
           to the corresponding paper row.
Fig 1    — speed-up columns derived from Table 1.
Fig 2    — SolveBakF vs stepwise-regression speed-up.
"""
from __future__ import annotations

import time
import tracemalloc
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve, solvebakf, stepwise_regression_baseline

REPEATS = 3


def _time(fn: Callable, *args) -> float:
    fn(*args)  # warmup / compile
    ts = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (tuple, jax.Array)) else None
        ts.append(time.perf_counter() - t0)
    return min(ts)


def mape(x, y, coef) -> float:
    pred = x @ np.asarray(coef)
    denom = np.maximum(np.abs(y), 1e-6)
    return float(np.mean(np.abs((pred - y) / denom)))


def table1(rows=None) -> List[Dict]:
    """Returns list of dicts, one per (vars, obs) system."""
    rng = np.random.default_rng(0)
    rows = rows or [(100, 1_000), (100, 100_000), (1_000, 10_000),
                    (1_000, 100_000), (50, 2_000), (2_000, 4_000)]
    out = []
    for nvars, obs in rows:
        x = rng.normal(size=(obs, nvars)).astype(np.float32)
        a = rng.normal(size=(nvars,)).astype(np.float32)
        y = (x @ a).astype(np.float32)
        xj, yj = jnp.array(x), jnp.array(y)

        def run_lapack():
            return np.linalg.lstsq(x, y, rcond=None)[0]

        def run_normal():
            g = x.T @ x + 1e-6 * np.eye(nvars, dtype=np.float32)
            return np.linalg.solve(g, x.T @ y)

        def run_bak():
            return solve(xj, yj, method="bak", max_iter=60, rtol=1e-10).coef

        def run_bakp():
            return solve(xj, yj, method="bakp", max_iter=60, rtol=1e-10,
                         thr=min(64, nvars)).coef

        def run_bakp_gram():
            return solve(xj, yj, method="bakp_gram", max_iter=60,
                         rtol=1e-10, thr=min(128, nvars)).coef

        rec = {"vars": nvars, "obs": obs}
        tracemalloc.start()
        t0 = tracemalloc.get_traced_memory()[1]
        coef = run_lapack()
        rec["lapack_mem_mib"] = (tracemalloc.get_traced_memory()[1] - t0) / 2**20
        tracemalloc.stop()
        rec["lapack_s"] = _time(run_lapack)
        rec["lapack_mape"] = mape(x, y, coef)
        rec["normal_s"] = _time(run_normal)
        for name, fn in (("bak", run_bak), ("bakp", run_bakp),
                         ("bakp_gram", run_bakp_gram)):
            c = fn()
            rec[f"{name}_s"] = _time(fn)
            rec[f"{name}_mape"] = mape(x, y, np.asarray(c))
        # paper's memory story: solver aux = residual + coefs (+ blocks)
        rec["bak_aux_mem_mib"] = (obs + nvars) * 4 / 2**20
        rec["speedup_vs_lapack_bak"] = rec["lapack_s"] / rec["bak_s"]
        rec["speedup_vs_lapack_bakp"] = rec["lapack_s"] / rec["bakp_s"]
        out.append(rec)
    return out


def fig2_feature_selection(sizes=((2000, 64, 6), (2000, 128, 6),
                                  (4000, 96, 8))) -> List[Dict]:
    rng = np.random.default_rng(1)
    out = []
    for obs, nvars, k in sizes:
        x = rng.normal(size=(obs, nvars)).astype(np.float32)
        idx = rng.choice(nvars, size=k, replace=False)
        coef = np.zeros(nvars, np.float32)
        coef[idx] = 3 * rng.normal(size=k).astype(np.float32) + 1
        y = x @ coef + 0.01 * rng.normal(size=obs).astype(np.float32)
        xj, yj = jnp.array(x), jnp.array(y)

        t_fast = _time(lambda: solvebakf(xj, yj, max_feat=k).selected)
        t_slow = _time(lambda: stepwise_regression_baseline(
            xj, yj, max_feat=k).selected)
        sel_fast = set(np.array(solvebakf(xj, yj, max_feat=k).selected)
                       .tolist())
        out.append({"obs": obs, "vars": nvars, "k": k,
                    "bakf_s": t_fast, "stepwise_s": t_slow,
                    "speedup": t_slow / t_fast,
                    "recovered": sel_fast == set(idx.tolist())})
    return out


def convergence_profile() -> List[Dict]:
    """Sweeps-to-tolerance: paper variants vs beyond-paper gram mode."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(4000, 256)).astype(np.float32)
    # correlated columns stress block CD
    x[:, 128:] = x[:, :128] + 0.3 * rng.normal(size=(4000, 128)).astype(
        np.float32)
    a = rng.normal(size=(256,)).astype(np.float32)
    y = x @ a
    xj, yj = jnp.array(x), jnp.array(y)
    out = []
    for method, kw in (("bak", {}), ("bakp", {"thr": 32, "omega": 0.7}),
                       ("bakp_gram", {"thr": 128})):
        res = solve(xj, yj, method=method, max_iter=100, atol=1e-2, **kw)
        out.append({"method": method,
                    "sweeps_to_tol": int(res.n_sweeps),
                    "final_rmse": float(np.sqrt(res.sse / 4000)),
                    "converged": bool(res.converged)})
    return out
