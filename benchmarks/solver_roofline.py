"""Roofline accounting for the solver kernels (the paper's workload itself).

One BAK/BAKP sweep over an (obs × vars) system:
  flops       ≈ 4·obs·vars      (dot + axpy per column/block)
  hbm bytes   ≈ obs·vars·dtype  (x streamed once; e resident in VMEM)
  ⇒ arithmetic intensity = 4/dtype_bytes flops/byte (2.0 for bf16) —
    firmly MEMORY-BOUND on v5e (ridge at 197e12/819e9 ≈ 240 flops/byte).

Per-device roofline time for one sweep and the achievable effective
flops/s are derived analytically; the distributed solvers add one (thr,)
psum per block step (obs-sharded) — collective bytes = vars·4 per sweep,
negligible vs the x stream.  Measured CPU wall times are printed for
context only (this container is not the target hardware).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import solvebakp_kernel

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def solver_roofline_rows(cases=((1 << 14, 1024, 2), (1 << 16, 4096, 2),
                                (1 << 20, 8192, 2))) -> List[Dict]:
    rows = []
    for obs, nvars, dtype_bytes in cases:
        bytes_per_sweep = obs * nvars * dtype_bytes
        flops_per_sweep = 4.0 * obs * nvars
        t_mem = bytes_per_sweep / HBM_BW
        t_comp = flops_per_sweep / PEAK_FLOPS
        rows.append({
            "obs": obs, "vars": nvars, "dtype_bytes": dtype_bytes,
            "ai_flops_per_byte": flops_per_sweep / bytes_per_sweep,
            "mem_term_s": t_mem, "compute_term_s": t_comp,
            "bottleneck": "memory" if t_mem > t_comp else "compute",
            "roofline_flops_eff": flops_per_sweep / max(t_mem, t_comp),
            "frac_of_peak": (flops_per_sweep / max(t_mem, t_comp))
            / PEAK_FLOPS,
        })
    return rows


def measured_sweep_throughput() -> Dict:
    """CPU-measured kernel sweep throughput (context only)."""
    rng = np.random.default_rng(0)
    obs, nvars = 8192, 512
    x_t = jnp.array(rng.normal(size=(nvars, obs)).astype(np.float32))
    y = jnp.array(rng.normal(size=(obs,)).astype(np.float32))

    def run():
        return solvebakp_kernel(x_t, y, block=128, max_iter=4)

    r = run()
    jax.block_until_ready(r.coef)
    t0 = time.perf_counter()
    r = run()
    jax.block_until_ready(r.coef)
    dt = time.perf_counter() - t0
    sweeps = 4
    return {"obs": obs, "vars": nvars, "sweeps": sweeps,
            "cpu_s_per_sweep": dt / sweeps,
            "cpu_gbytes_per_s": obs * nvars * 4 * sweeps / dt / 1e9}
