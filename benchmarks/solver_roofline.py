"""Roofline accounting + kernel-path benchmark for the solver (the paper's
workload itself).

One BAK/BAKP sweep over an (obs × vars) system:
  flops       ≈ 4·obs·vars      (dot + axpy per column/block)
  hbm bytes   ≈ obs·vars·dtype  (x streamed once; e resident in VMEM)
  ⇒ arithmetic intensity = 4/dtype_bytes flops/byte (2.0 for bf16) —
    firmly MEMORY-BOUND on v5e (ridge at 197e12/819e9 ≈ 240 flops/byte).

The fused megakernel (``repro.kernels.fused_solve``) changes the *solve*
traffic: x crosses HBM once per solve instead of once per sweep, so its
roofline bound is ``obs·vars·dtype / HBM_BW`` per solve, not per sweep.

``bench_kernel_paths`` measures the three execution models against each
other on tall / wide / square systems, cold-start vs early-converging:

  fused     — one pallas_call for the whole solve (new hot path),
  persweep  — one pallas_call per sweep from a host while_loop (the
              pre-fusion model, ``solvebakp_persweep_kernel``),
  xla       — plain-XLA ``solvebakp`` (mode="jacobi").

Measured CPU wall times run the kernels in interpret mode — the relative
ordering (fused ≥ persweep on early-converging solves: no post-convergence
sweeps, no per-sweep residual round-trip) holds there too and is what the
``--smoke`` gate asserts, together with fused-vs-persweep parity.  Absolute
GB/s numbers on CPU are context only (this container is not the target
hardware); the analytic per-device roofline rows are the TPU reference.

    PYTHONPATH=src python -m benchmarks.solver_roofline --smoke \
        --json BENCH_core.json
"""
from __future__ import annotations

import argparse
import functools
import sys
import time
from typing import Dict, List

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def solver_roofline_rows(cases=((1 << 14, 1024, 2), (1 << 16, 4096, 2),
                                (1 << 20, 8192, 2))) -> List[Dict]:
    rows = []
    for obs, nvars, dtype_bytes in cases:
        bytes_per_sweep = obs * nvars * dtype_bytes
        flops_per_sweep = 4.0 * obs * nvars
        t_mem = bytes_per_sweep / HBM_BW
        t_comp = flops_per_sweep / PEAK_FLOPS
        rows.append({
            "obs": obs, "vars": nvars, "dtype_bytes": dtype_bytes,
            "ai_flops_per_byte": flops_per_sweep / bytes_per_sweep,
            "mem_term_s": t_mem, "compute_term_s": t_comp,
            "bottleneck": "memory" if t_mem > t_comp else "compute",
            "roofline_flops_eff": flops_per_sweep / max(t_mem, t_comp),
            "frac_of_peak": (flops_per_sweep / max(t_mem, t_comp))
            / PEAK_FLOPS,
        })
    return rows


def measured_sweep_throughput() -> Dict:
    """CPU-measured kernel sweep throughput (context only)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import solvebakp_kernel

    rng = np.random.default_rng(0)
    obs, nvars = 8192, 512
    x_t = jnp.array(rng.normal(size=(nvars, obs)).astype(np.float32))
    y = jnp.array(rng.normal(size=(obs,)).astype(np.float32))

    def run():
        # donate=False: the same y is passed on every repeat — donation
        # would invalidate it after the first call on accelerator backends.
        return solvebakp_kernel(x_t, y, block=128, max_iter=4,
                                donate=False)

    r = run()
    jax.block_until_ready(r.coef)
    t0 = time.perf_counter()
    r = run()
    jax.block_until_ready(r.coef)
    dt = time.perf_counter() - t0
    sweeps = 4
    return {"obs": obs, "vars": nvars, "sweeps": sweeps,
            "cpu_s_per_sweep": dt / sweeps,
            "cpu_gbytes_per_s": obs * nvars * 4 * sweeps / dt / 1e9}


def _time(fn, repeats: int) -> float:
    import jax

    jax.block_until_ready(fn().coef)       # warm the compile cache
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn().coef)
    return (time.perf_counter() - t0) / repeats


def _make_design(rng, obs: int, nvars: int) -> np.ndarray:
    """Well-conditioned design (singular values in [1, 2]) — the paper's
    consistent-system setting, where SolveBakP converges to the f32 floor
    well inside any reasonable sweep budget for every aspect ratio."""
    m = min(obs, nvars)
    u, _ = np.linalg.qr(rng.normal(size=(obs, m)))
    v, _ = np.linalg.qr(rng.normal(size=(nvars, m)))
    s = rng.uniform(1.0, 2.0, size=m)
    return ((u * s) @ v.T).astype(np.float32)


def bench_kernel_paths(shapes=None, *, max_iter=100, full_iter=30,
                       rtol=1e-6, repeats=3, seed=0) -> List[Dict]:
    """fused vs per-sweep-launch vs XLA solvebakp, per shape.

    Each shape runs two regimes:
      * early  — consistent system + ``rtol`` stopping under a generous
        ``max_iter`` budget, so the solve converges in ``n_sweeps ≪
        max_iter`` (the serving steady state);
      * full   — no tolerances, all ``full_iter`` sweeps run (worst case).

    ``achieved_gbps`` charges each path the x bytes it actually reads:
    n_sweeps·obs·vars·4 for the streaming paths, obs·vars·4 once for fused.
    """
    import jax.numpy as jnp

    from repro.core import solvebakp
    from repro.kernels import fused_solve, solvebakp_persweep_kernel

    if shapes is None:
        shapes = [("tall", 4096, 256, 64), ("wide", 512, 1024, 64),
                  ("square", 1024, 1024, 128)]
    rng = np.random.default_rng(seed)
    rows = []
    for name, obs, nvars, block in shapes:
        x = _make_design(rng, obs, nvars)
        a = rng.normal(size=(nvars,)).astype(np.float32)
        y = (x @ a).astype(np.float32)
        xd, x_t, yd = jnp.asarray(x), jnp.asarray(x.T), jnp.asarray(y)
        for regime, common in (
                ("early", dict(max_iter=max_iter, rtol=rtol)),
                ("full", dict(max_iter=full_iter))):
            # donate=False everywhere: each path re-solves the SAME yd
            # device array `repeats` times — default-on accelerator
            # donation would delete it after the first call.
            runs = {
                "fused": functools.partial(
                    fused_solve, x_t, yd, block=block, donate=False,
                    **common),
                "persweep": functools.partial(
                    solvebakp_persweep_kernel, x_t, yd, block=block,
                    donate=False, **common),
                "xla": functools.partial(
                    solvebakp, xd, yd, thr=block, mode="jacobi",
                    donate=False, **common),
            }
            res = {k: f() for k, f in runs.items()}
            times = {k: _time(f, repeats) for k, f in runs.items()}
            n = {k: int(r.n_sweeps) for k, r in res.items()}
            parity = float(np.max(np.abs(
                np.asarray(res["fused"].coef)
                - np.asarray(res["persweep"].coef))))
            x_bytes = obs * nvars * 4
            rows.append({
                "shape": name, "obs": obs, "vars": nvars, "block": block,
                "regime": regime, "max_iter": common["max_iter"],
                "n_sweeps": n["fused"],
                "n_sweeps_persweep": n["persweep"],
                "fused_s": times["fused"],
                "persweep_s": times["persweep"],
                "xla_s": times["xla"],
                "fused_speedup_vs_persweep":
                    times["persweep"] / times["fused"],
                "fused_speedup_vs_xla": times["xla"] / times["fused"],
                # x-bytes each path actually reads / wall time
                "fused_gbps": x_bytes / times["fused"] / 1e9,
                "persweep_gbps":
                    n["persweep"] * x_bytes / times["persweep"] / 1e9,
                "roofline_sweep_s": x_bytes / HBM_BW,
                "parity_fused_vs_persweep": parity,
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + CI gate: fused beats the per-sweep "
                         "launch loop on the early-converging solves and "
                         "matches it numerically (<= 1e-5)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge metrics into a JSON report (BENCH_core.json)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args()

    if args.smoke:
        shapes = [("tall", 2048, 128, 32), ("wide", 256, 512, 64),
                  ("square", 512, 512, 64)]
        repeats = args.repeats or 3
    else:
        shapes = None
        repeats = args.repeats or 5
    rows = bench_kernel_paths(shapes, repeats=repeats)
    roofline = solver_roofline_rows()

    if args.json:
        try:
            from benchmarks.serve_async import write_json
        except ImportError:  # run as a bare script instead of -m
            from serve_async import write_json
        write_json(args.json, {"core_kernel_paths": rows,
                               "core_roofline_analytic": roofline})

    print("name,us_per_call,derived")
    for r in rows:
        tag = (f"solver[{r['shape']}:o{r['obs']}xv{r['vars']}"
               f"b{r['block']}/{r['regime']}]")
        print(f"{tag}/fused,{r['fused_s']*1e6:.0f},"
              f"n_sweeps={r['n_sweeps']};gbps={r['fused_gbps']:.2f};"
              f"speedup_vs_persweep={r['fused_speedup_vs_persweep']:.2f};"
              f"speedup_vs_xla={r['fused_speedup_vs_xla']:.2f}")
        print(f"{tag}/persweep,{r['persweep_s']*1e6:.0f},"
              f"n_sweeps={r['n_sweeps_persweep']};"
              f"gbps={r['persweep_gbps']:.2f}")
        print(f"{tag}/xla,{r['xla_s']*1e6:.0f},")

    early = [r for r in rows if r["regime"] == "early"]
    worst_parity = max(r["parity_fused_vs_persweep"] for r in rows)
    assert all(r["n_sweeps"] < r["max_iter"] for r in early), \
        "early-converging cases must stop before max_iter"
    fused_wins = all(r["fused_speedup_vs_persweep"] > 1.0 for r in early)
    ok = fused_wins and worst_parity <= 1e-5
    mean_speedup = float(np.mean(
        [r["fused_speedup_vs_persweep"] for r in early]))
    print(f"acceptance: fused beats per-sweep launch on all "
          f"{len(early)} early-converging solves "
          f"(mean speedup {mean_speedup:.2f}x) -> "
          f"{'PASS' if fused_wins else 'FAIL'}; "
          f"parity fused-vs-persweep {worst_parity:.2e} (<=1e-5) -> "
          f"{'PASS' if worst_parity <= 1e-5 else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
