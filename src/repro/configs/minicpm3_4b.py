"""minicpm3-4b — multi-head latent attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf]  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 — the KV
cache stores only the 256+32-wide latent stream (decode uses absorbed
matmuls; repro.models.attention.mla_decode).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,         # MLA is effectively MHA over latent-expanded K/V
    head_dim=96,           # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    microbatch=4,
    max_cache_len=32768,
)
