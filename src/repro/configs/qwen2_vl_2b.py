"""qwen2-vl-2b — VLM text backbone with M-RoPE (vision frontend stubbed;
input_specs provides M-RoPE position streams; patch embeddings enter as
regular embedded positions).

[arXiv:2409.12191; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, head_dim=128, mrope sections (16, 24, 24).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    microbatch=2,
    max_cache_len=32768,
)
