"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone (audio
frontend stubbed; input_specs provides precomputed frame embeddings).

[arXiv:2308.11596; hf]  24 encoder + 24 decoder layers, d_model=1024 16H
(kv=16, MHA) d_ff=8192 vocab=256206.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    n_enc_layers=24,
    n_dec_layers=24,
    enc_input_dim=1024,
    src_len_for_decode=4096,
    microbatch=2,
    max_cache_len=32768,
)
