"""repro.configs — one module per assigned architecture (see registry.py)."""
