"""arctic-480b — Snowflake Arctic base: dense-MoE hybrid.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128 experts top-2 + parallel dense residual MLP.
Adafactor: AdamW state does not fit 256×16GB for 480B params (DESIGN.md §7).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual_d_ff=4864,
    optimizer="adafactor",
    microbatch=8,
    max_cache_len=32768,
)
