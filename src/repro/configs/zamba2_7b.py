"""zamba2-7b — hybrid: Mamba2 backbone + shared attention block with
per-invocation LoRA deltas.

[arXiv:2411.15242; unverified]  81 layers = 13 units × (5 mamba2 + 1 shared
attn invocation) + 3 trailing mamba2; d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64.  The shared block's QKV weights are one set,
specialised per invocation by rank-128 LoRA (stacked over units).
Sub-quadratic backbone: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    hybrid_units=13,
    mamba_per_unit=5,
    trailing_mamba=3,
    shared_lora_rank=128,
    microbatch=4,
    max_cache_len=524288,
)
