"""gemma2-9b — alternating local(4096-window)/global attention, logit
softcaps, pre+post RMSNorm.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, head_dim=256; attn softcap 50.0, final softcap 30.0.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern="alt_local_global",
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    tie_embeddings=True,
    microbatch=4,
    max_cache_len=32768,
)
