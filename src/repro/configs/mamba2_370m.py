"""mamba2-370m — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1024, ssm_state=128,
expand=2 → d_inner=2048, head_dim=64 → 32 SSM heads, vocab=50280.
Sub-quadratic: runs the long_500k cell (O(1) decode state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,            # = d_inner / ssm_head_dim (informational)
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,                # attention-free, no MLP (Mamba2 block only)
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    microbatch=4,
    max_cache_len=524288,
)
