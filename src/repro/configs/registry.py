"""Architecture registry: ``--arch <id>`` → ModelConfig, plus the
cell-applicability matrix (DESIGN.md §5)."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import (arctic_480b, dbrx_132b, gemma2_9b,
                           h2o_danube_1_8b, mamba2_370m, minicpm3_4b,
                           qwen2_vl_2b, qwen3_8b, seamless_m4t_large_v2,
                           zamba2_7b)
from repro.configs.base import SHAPE_CELLS, ModelConfig, ShapeCell

ARCHS: Dict[str, ModelConfig] = {
    "arctic-480b": arctic_480b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "mamba2-370m": mamba2_370m.CONFIG,
    "qwen3-8b": qwen3_8b.CONFIG,
    "gemma2-9b": gemma2_9b.CONFIG,
    "minicpm3-4b": minicpm3_4b.CONFIG,
    "h2o-danube-1.8b": h2o_danube_1_8b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
    "qwen2-vl-2b": qwen2_vl_2b.CONFIG,
}

# Sub-quadratic archs run the 500k-context decode cell; pure full-attention
# archs skip it (DESIGN.md §5 records the rationale per arch).
LONG_CONTEXT_ARCHS = {"mamba2-370m", "zamba2-7b", "h2o-danube-1.8b"}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def cells_for(name: str) -> List[ShapeCell]:
    cells = [SHAPE_CELLS["train_4k"], SHAPE_CELLS["prefill_32k"],
             SHAPE_CELLS["decode_32k"]]
    if name in LONG_CONTEXT_ARCHS:
        cells.append(SHAPE_CELLS["long_500k"])
    return cells


def all_cells() -> List[Tuple[str, ShapeCell]]:
    return [(a, c) for a in ARCHS for c in cells_for(a)]
