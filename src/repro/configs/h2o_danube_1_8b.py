"""h2o-danube-1.8b — llama/mistral-style dense with sliding-window attention.

[arXiv:2401.16818; hf]  24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, SWA window 4096 on every layer → ring KV cache of 4096 slots;
sub-quadratic, runs the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    layer_pattern="swa",
    sliding_window=4096,
    microbatch=2,
    max_cache_len=524288,
)
