"""Model / run configuration schema.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<arch>.py`` with the exact public-literature numbers; reduced
smoke variants are derived with ``.smoke()``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# ---------------------------------------------------------------------------
# Input-shape cells (assigned to every LM arch; DESIGN.md §5 lists the skips).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    # backbone -------------------------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads
    # attention features -----------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla
    qk_norm: bool = False           # qwen3
    attn_softcap: float = 0.0       # gemma2 (30.0)
    final_softcap: float = 0.0      # gemma2 (50.0)
    sliding_window: int = 0         # >0: SWA window
    layer_pattern: str = "global"   # global | swa | alt_local_global
    post_norm: bool = False         # gemma2 post-block RMSNorm
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) dims
    # MLA (minicpm3 / deepseek-style) ---------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE --------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual_d_ff: int = 0    # arctic: parallel dense MLP
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_coef: float = 1e-2
    # SSM (mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # hybrid (zamba2) ---------------------------------------------------------
    hybrid_units: int = 0           # units of (mamba_per_unit mamba + 1 shared attn)
    mamba_per_unit: int = 0
    trailing_mamba: int = 0
    shared_lora_rank: int = 0
    # enc-dec (seamless) ------------------------------------------------------
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_input_dim: int = 0          # stubbed modality frontend output dim
    src_len_for_decode: int = 4096  # encoder length used by decode cells
    # vlm ----------------------------------------------------------------------
    vision_embed_dim: int = 0       # stubbed patch-embedding dim
    # training / numerics -------------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "full"             # none | full | dots
    optimizer: str = "adamw"        # adamw | adafactor
    tie_embeddings: bool = False
    microbatch: int = 1             # grad-accumulation splits of the global batch
    # attention chunking (flash-style scan) -------------------------------------
    q_chunk: int = 512
    k_chunk: int = 1024
    causal_mode: str = "masked"     # masked | triangular (perf lever, §Perf)
    replicate_kv: bool = False      # replicate K/V projections over the model
                                    # axis (perf lever: avoids head-dim
                                    # splitting when n_kv_heads < model axis)
    # serving -----------------------------------------------------------------
    max_cache_len: int = 32768
    kv_quant: str = "none"          # none | int8 — per-(token,head) symmetric
                                    # KV-cache quantization (serving lever;
                                    # supported for gqa dense/moe/vlm patterns)

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table and
        logits shard evenly over the 16-wide model axis (MaxText-style
        padding; labels never index the pad rows)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def n_params(self) -> float:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        d, L, hd = self.d_model, self.n_layers, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            din = self.ssm_expand * d
            per = d * (2 * din + 2 * self.ssm_ngroups * self.ssm_state
                       + din // self.ssm_head_dim) + din * d
            return emb + L * per
        if self.family == "hybrid":
            din = self.ssm_expand * d
            n_mamba = self.hybrid_units * self.mamba_per_unit + self.trailing_mamba
            mamba = n_mamba * (d * (2 * din + 2 * self.ssm_ngroups * self.ssm_state
                                    + din // self.ssm_head_dim) + din * d)
            attn = (self.n_heads + 2 * self.n_kv_heads) * hd * d + \
                self.n_heads * hd * d + 3 * d * self.d_ff
            return emb + mamba + attn
        if self.attn_type == "mla":
            attn = d * self.q_lora_rank \
                + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim) \
                + d * (self.kv_lora_rank + self.qk_rope_dim) \
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim) \
                + self.n_heads * self.v_head_dim * d
        else:
            attn = (self.n_heads + 2 * self.n_kv_heads) * hd * d \
                + self.n_heads * hd * d
        if self.n_experts:
            ffn = 3 * d * self.moe_d_ff * self.n_experts + d * self.n_experts
            ffn += 3 * d * self.dense_residual_d_ff
        else:
            ffn = 3 * d * self.d_ff
        n_lay = (self.n_enc_layers + self.n_dec_layers) if self.is_encdec else L
        cross = self.n_dec_layers * ((self.n_heads + self.n_kv_heads) * hd * d
                                     + self.n_heads * hd * d) if self.is_encdec else 0
        return emb + n_lay * (attn + ffn) + cross

    def n_active_params(self) -> float:
        """Active params per token (MoE top-k) for MODEL_FLOPS of MoE archs."""
        if not self.n_experts:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        full = self.n_params()
        all_experts = L * 3 * d * self.moe_d_ff * self.n_experts
        active = L * 3 * d * self.moe_d_ff * self.experts_per_token
        return full - all_experts + active

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=128,
            q_chunk=32,
            k_chunk=32,
            max_cache_len=64,
            remat="none",
            dtype="float32",
        )
        if self.n_experts:
            kw.update(n_experts=4, experts_per_token=min(2, self.experts_per_token),
                      moe_d_ff=64,
                      dense_residual_d_ff=64 if self.dense_residual_d_ff else 0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=16)
        if self.family == "hybrid":
            kw.update(hybrid_units=2, mamba_per_unit=2, trailing_mamba=1,
                      shared_lora_rank=4)
        if self.is_encdec:
            kw.update(n_enc_layers=2, n_dec_layers=2, enc_input_dim=64,
                      src_len_for_decode=32)
        if self.attn_type == "mla":
            kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16)
        if self.mrope_sections:
            kw.update(mrope_sections=(4, 2, 2))  # sums to head_dim//2 = 8
        if self.sliding_window:
            kw.update(sliding_window=32)
        kw.update(name=self.name + "-smoke")
        return ModelConfig(**kw)
