"""DesignStore — the tiered (device / host / disk) design residency store.

The paper's memory claim — "for each iteration, only one dimension of the
given input matrix X is utilized" — means a solve's *working set* is one
column block plus the small accumulators, while our serving stack (through
PR 8) still kept every tenant's full design device-resident, capping fleet
scale at HBM size.  This module removes that ceiling: device memory becomes
the *hot tier* of a three-tier store, and the tenant count is bounded by
disk, not HBM.

Tiers, hottest first:

  * **device** — today's behaviour: a ``PreparedDesign`` with ``x_pad`` (and
    its lazily built ``x_t_for``/``x_bf16_for``/sharded copies) resident on
    the accelerator.  Bounded by ``device_bytes`` and ``max_entries``.
  * **host** — a ``HostDesign`` snapshot in host RAM: numpy copies of the
    per-block ``x_t_for``/``x_bf16_for`` layouts (or the raw ``x_pad`` when
    none were built) plus the small derived state — column norms, block-Gram
    Cholesky factors — and, crucially, the per-tenant warm-coefficient LRU,
    so a returning tenant after re-admission still warm-starts (the PR 9
    eviction regression fix).  Bounded by ``host_bytes``.
  * **disk** — memmapped per-block tile files under
    ``<disk_dir>/<fingerprint>/``, one ``(thr, obs)`` fp32 tile per column
    block of the transposed layout.  The small state stays in RAM on the
    ``DiskDesign`` record.  Unbounded (disk is the floor).

Transitions are **demotions, not deletions**: the device tier over budget
demotes its LRU entry to host; host over budget demotes to disk (or, with
no ``disk_dir``, drops only the X bytes and keeps a state-only record so
warm coefficients and Cholesky factors survive a rebuild).  ``promote``
climbs back up — restoring every piece of snapshotted state onto the fresh
``PreparedDesign`` — and a disk promotion deletes its tile files (one full
round trip).  Promotion is *async by construction*: the serving cache's
``get_or_build`` promotes, and the async dispatcher's pre-warm calls it on
the dispatch thread, so a cold-tier design is climbing tiers while its
request still waits in the intake queue.

Designs whose padded X exceeds ``device_bytes`` outright never become
device-resident: ``build`` keeps their bytes in the host/disk tiers and
returns a *non-resident* ``PreparedDesign`` (``x_pad=None``) whose
``blocks`` attribute is a ``StoreBlockSource`` — the per-block fetch
interface the ``"bakp_stream"`` solver method consumes (see
``repro.kernels.stream_solve``).

Metrics (PR 6 registry): ``store_bytes{tier}`` / ``store_resident{tier}``
gauges, ``store_promotions_total{from,to}`` counting every tier move in
both directions, and a ``store_fetch_latency_seconds{tier}`` histogram over
promotions and streaming block fetches.

Concurrency: one store ``RLock`` guards the tier maps; per-design state is
additionally guarded by each ``PreparedDesign``'s own lock.  A demotion
concurrent with an in-flight solve is safe — the solve keeps its reference
to the old handle (its device buffers stay alive until the last reference
drops); at worst a warm-coefficient write landing on the demoted handle
*after* its snapshot is lost, which is the pre-existing best-effort warm
contract.

Crash safety (PR 10): every tile file carries a 16-byte header — magic,
CRC32 of the payload, payload byte count — and is written via temp file +
``fsync`` + atomic ``os.replace``, so a crash mid-demotion can never leave
a truncated tile masquerading as data.  Reads verify lazily (once per tile
per ``DiskDesign``); promotion verifies every tile.  A tile that fails
verification raises ``TileCorruptionError`` and the whole design is
*quarantined*: its tile directory is renamed aside, the disk record and any
streaming handle are dropped, and a state-only stub (warm coefficients,
Cholesky, norms) survives so the next ``build`` from the design source
restores the tenant state.  Counted as ``store_tile_corruption_total``.
"""
from __future__ import annotations

import logging
import os
import shutil
import struct
import threading
import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro import obs
from repro.core.prepare import PreparedDesign, prepare
from repro.resilience import faults

_log = logging.getLogger(__name__)

#: Tile width used when a design reaches the disk tier without any
#: transposed layout built yet (no solve touched it while resident).
DEFAULT_TILE = 128

#: Tile-file header: magic, CRC32 of the payload, payload byte count.
_TILE_MAGIC = b"DTL1"
_TILE_HEADER = struct.Struct("<4sIQ")


class TileCorruptionError(RuntimeError):
    """A disk tile failed its integrity check (bad magic/length/CRC or an
    unreadable file).  Carries the design ``key`` and tile ``path``; the
    store quarantines the whole design before this propagates, so the
    caller's recovery is to rebuild from the design source (the serving
    engine's retry ladder does exactly that)."""

    def __init__(self, key: str, path: Path, detail: str):
        super().__init__(
            f"design {key!r}: corrupt tile {path.name} ({detail})")
        self.key = key
        self.path = path


def _write_tile_atomic(path: Path, tile: np.ndarray) -> None:
    """Crash-safe tile write: header + payload into a temp file, flushed
    and ``fsync``ed, then atomically renamed over ``path``.  A reader (or
    a restart) can only ever observe the old file, no file, or the
    complete new file — never a torn write."""
    payload = np.ascontiguousarray(tile, np.float32).tobytes()
    header = _TILE_HEADER.pack(_TILE_MAGIC, zlib.crc32(payload),
                               len(payload))
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _entry_device_bytes(entry: PreparedDesign) -> int:
    """Device bytes a resident ``PreparedDesign`` holds: the padded design
    plus every lazily built tier (transposed, bf16, sharded copies).  The
    small vectors (norms, Cholesky) are ignored — they are O(vars), noise
    next to O(obs·vars)."""
    with entry._lock:
        total = entry.x_pad.size * entry.x_pad.dtype.itemsize
        for d in (entry._x_t, entry._x_bf16, entry._sharded):
            for a in d.values():
                total += a.size * a.dtype.itemsize
    return total


@dataclass
class HostDesign:
    """Host-RAM snapshot of one demoted design (see module doc).

    ``x_t``/``x_bf16`` hold the per-block kernel layouts that were resident
    at demotion time; ``x_pad`` is kept only when no transposed layout
    existed (so the design is always reconstructible from exactly one
    representation).  A *state-only* record (all three empty) survives an
    X-byte drop and still restores warm/Cholesky state on rebuild.
    """

    key: str
    shape: Tuple[int, int]                      # (obs_p, vars_p)
    max_tenants: int = 64
    x_pad: Optional[np.ndarray] = None          # (obs, vars) fp32
    x_t: Dict[int, np.ndarray] = field(default_factory=dict)
    x_bf16: Dict[int, np.ndarray] = field(default_factory=dict)
    cn: Optional[np.ndarray] = None
    chol: Dict[Tuple[int, float], np.ndarray] = field(default_factory=dict)
    warm: "OrderedDict[str, np.ndarray]" = field(default_factory=OrderedDict)
    home: Optional[str] = None

    @property
    def nbytes(self) -> int:
        total = 0 if self.x_pad is None else self.x_pad.nbytes
        for d in (self.x_t, self.x_bf16):
            for a in d.values():
                total += a.nbytes
        return total

    def has_x(self) -> bool:
        return self.x_pad is not None or bool(self.x_t)

    def drop_x(self) -> None:
        self.x_pad = None
        self.x_t = {}
        self.x_bf16 = {}

    def read_cols(self, lo: int, hi: int) -> np.ndarray:
        """Columns ``lo:hi`` of the design in transposed layout, (hi-lo,
        obs) fp32.  Rows at/above ``vars_p`` come back zero (thr padding)."""
        obs_p, vars_p = self.shape
        out = np.zeros((hi - lo, obs_p), np.float32)
        real = min(hi, vars_p) - lo
        if real <= 0:
            return out
        if self.x_t:
            src = next(iter(self.x_t.values()))
            stop = min(hi, src.shape[0])
            out[: stop - lo] = src[lo:stop]
        elif self.x_pad is not None:
            out[:real] = self.x_pad[:, lo:lo + real].T
        else:
            raise RuntimeError(
                f"design {self.key!r}: X bytes were dropped (host budget "
                f"exceeded with no disk tier configured); only warm/derived "
                f"state survives — configure DesignStore(disk_dir=...)")
        return out


@dataclass
class DiskDesign:
    """Disk-tier record: memmapped per-block tile files plus the small
    state that stays in RAM (norms, Cholesky, warm coefficients)."""

    key: str
    shape: Tuple[int, int]
    tile_dir: Path
    thr: int                                     # tile width of the files
    nblocks: int
    max_tenants: int = 64
    cn: Optional[np.ndarray] = None
    chol: Dict[Tuple[int, float], np.ndarray] = field(default_factory=dict)
    warm: "OrderedDict[str, np.ndarray]" = field(default_factory=OrderedDict)
    home: Optional[str] = None
    _verified: Set[int] = field(default_factory=set, repr=False)

    @property
    def nbytes(self) -> int:
        return self.nblocks * self.thr * self.shape[0] * 4

    def tile_path(self, j: int) -> Path:
        return self.tile_dir / f"t{self.thr}_b{j}.bin"

    def verify_tile(self, j: int) -> np.ndarray:
        """Full checked read of one (thr, obs) fp32 tile: header magic,
        payload length and CRC32 all validated.  Raises
        ``TileCorruptionError`` on any mismatch (or an unreadable file)."""
        path = self.tile_path(j)
        try:
            with open(path, "rb") as f:
                header = f.read(_TILE_HEADER.size)
                payload = f.read()
        except OSError as exc:
            raise TileCorruptionError(self.key, path, f"unreadable: {exc}")
        try:
            magic, crc, nbytes = _TILE_HEADER.unpack(header)
        except struct.error:
            raise TileCorruptionError(self.key, path, "truncated header")
        # Chaos site: flip one payload byte (on a copy) so the CRC check
        # below trips exactly like real media corruption would.
        if faults.hit("store.tile_corrupt", self.key) is not None \
                and payload:
            payload = bytearray(payload)
            payload[0] ^= 0xFF
            payload = bytes(payload)
        if magic != _TILE_MAGIC:
            raise TileCorruptionError(self.key, path, "bad magic")
        if len(payload) != nbytes \
                or nbytes != self.thr * self.shape[0] * 4:
            raise TileCorruptionError(
                self.key, path,
                f"payload is {len(payload)} bytes, header says {nbytes}")
        if zlib.crc32(payload) != crc:
            raise TileCorruptionError(self.key, path, "CRC32 mismatch")
        self._verified.add(j)
        return np.frombuffer(payload, np.float32).reshape(
            self.thr, self.shape[0])

    def tile(self, j: int) -> np.ndarray:
        """Memmap one (thr, obs) fp32 tile (read-only).  The first touch
        of each tile runs the full integrity check; later reads map the
        payload directly (16-byte header offset) at zero copy cost."""
        if j not in self._verified:
            self.verify_tile(j)
        return np.memmap(self.tile_path(j), dtype=np.float32, mode="r",
                         shape=(self.thr, self.shape[0]),
                         offset=_TILE_HEADER.size)

    def read_cols(self, lo: int, hi: int) -> np.ndarray:
        obs_p, vars_p = self.shape
        out = np.zeros((hi - lo, obs_p), np.float32)
        stop = min(hi, self.nblocks * self.thr)
        pos = lo
        while pos < stop:
            j = pos // self.thr
            t_lo = pos - j * self.thr
            t_hi = min(self.thr, stop - j * self.thr)
            out[pos - lo: pos - lo + (t_hi - t_lo)] = self.tile(j)[t_lo:t_hi]
            pos = j * self.thr + t_hi
        return out

    def delete_tiles(self) -> None:
        shutil.rmtree(self.tile_dir, ignore_errors=True)


class StoreBlockSource:
    """Per-block fetch interface of a non-resident design.

    The ``"bakp_stream"`` method's host fallback (and any future kernel
    that streams from host memory) pulls (thr, obs) fp32 tiles of the
    transposed layout through this, wherever the bytes currently live
    (host RAM or disk — the source re-resolves the tier on every fetch, so
    a design demoted to disk mid-solve keeps serving blocks).
    """

    def __init__(self, store: "DesignStore", key: str,
                 shape: Tuple[int, int]):
        self._store = store
        self.key = key
        self.shape = tuple(shape)               # (obs_p, vars_p)

    def num_blocks(self, thr: int) -> int:
        return -(-self.shape[1] // thr)

    def block_t(self, thr: int, j: int) -> np.ndarray:
        """Tile ``j`` of the thr-blocked transposed layout, (thr, obs)
        fp32, zero-padded past the real column count."""
        return self._store._fetch_block(self.key, thr, j)


@dataclass
class StoreStats:
    """Per-store counters (convenience mirror of the ``store_*`` metric
    families; see ``CacheStats`` for the pattern)."""

    admits: int = 0
    builds_nonresident: int = 0
    demotions_device: int = 0      # device → host
    demotions_disk: int = 0        # host → disk
    promotions_host: int = 0       # host → device
    promotions_disk: int = 0       # disk → device
    x_drops: int = 0               # host X bytes dropped (no disk tier)
    tile_corruptions: int = 0      # designs quarantined off the disk tier

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class DesignStore:
    """Three-tier byte-budgeted design residency store (see module doc).

    Args:
      device_bytes: device-tier budget.  None = unbounded (every design is
        admitted resident; only ``max_entries`` demotes).  A design whose
        padded X alone exceeds this is *never* admitted resident — it is
        built non-resident with its bytes on the host/disk tiers.
      host_bytes: host-tier budget; overflow demotes LRU host entries to
        disk (or drops their X bytes when no ``disk_dir`` is set).
      disk_dir: directory for the memmapped tile files; None disables the
        disk tier.
      max_entries: LRU entry-count bound on the device tier (the historical
        ``DesignCache.max_entries`` semantics; None = bytes-only).
      registry: ``repro.obs`` metrics registry (process default if None).
    """

    def __init__(self, device_bytes: Optional[int] = None,
                 host_bytes: Optional[int] = None,
                 disk_dir: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 registry: Optional[obs.MetricsRegistry] = None):
        self.device_bytes = device_bytes
        self.host_bytes = host_bytes
        self.disk_dir = Path(disk_dir) if disk_dir is not None else None
        self.max_entries = max_entries
        self.stats = StoreStats()
        reg = registry or obs.default_registry()
        g_bytes = reg.gauge("store_bytes",
                            "bytes resident per design-store tier")
        g_res = reg.gauge("store_resident",
                          "designs resident per design-store tier")
        self._g_bytes = {t: g_bytes.labels(tier=t)
                         for t in ("device", "host", "disk")}
        self._g_res = {t: g_res.labels(tier=t)
                       for t in ("device", "host", "disk")}
        self._m_moves = reg.counter(
            "store_promotions_total",
            "design tier transitions (demotions AND promotions), "
            "by from/to tier")
        h_fetch = reg.histogram(
            "store_fetch_latency_seconds",
            "tier-promotion and streaming block-fetch latency, by source "
            "tier", buckets=obs.LATENCY_BUCKETS)
        self._h_fetch = {t: h_fetch.labels(tier=t)
                         for t in ("host", "disk")}
        self._m_corruption = reg.counter(
            "store_tile_corruption_total",
            "designs quarantined after a disk tile failed its CRC/header "
            "check")
        self._lock = threading.RLock()
        self._device: "OrderedDict[str, PreparedDesign]" = OrderedDict()
        self._host: "OrderedDict[str, HostDesign]" = OrderedDict()
        self._disk: "OrderedDict[str, DiskDesign]" = OrderedDict()
        # Non-resident handles (x_pad=None, blocks=StoreBlockSource): kept
        # alive here so repeat requests reuse one handle (and its warm
        # coefficients / lazily-built inv norms).
        self._nonres: Dict[str, PreparedDesign] = {}

    # ------------------------------------------------------------ accounting
    def __len__(self) -> int:
        """Device-resident design count (the ``DesignCache`` contract)."""
        with self._lock:
            return len(self._device)

    def device_used(self) -> int:
        with self._lock:
            return sum(_entry_device_bytes(e) for e in self._device.values())

    def host_used(self) -> int:
        with self._lock:
            return sum(h.nbytes for h in self._host.values())

    def disk_used(self) -> int:
        with self._lock:
            return sum(d.nbytes for d in self._disk.values())

    def tier(self, key: str) -> str:
        """Where a design's X bytes currently live: "device" / "host" /
        "disk" / "none"."""
        with self._lock:
            if key in self._device:
                return "device"
            h = self._host.get(key)
            if h is not None and h.has_x():
                return "host"
            if key in self._disk:
                return "disk"
            return "none"

    def _update_gauges(self) -> None:
        self._g_bytes["device"].set(self.device_used())
        self._g_bytes["host"].set(self.host_used())
        self._g_bytes["disk"].set(self.disk_used())
        self._g_res["device"].set(len(self._device))
        self._g_res["host"].set(len(self._host))
        self._g_res["disk"].set(len(self._disk))

    def _move(self, src: str, dst: str) -> None:
        self._m_moves.inc(1, **{"from": src, "to": dst})

    # ----------------------------------------------------------------- reads
    def get(self, key: str) -> Optional[PreparedDesign]:
        """The servable handle for ``key``: the device-resident entry or
        the non-resident streaming handle.  LRU-touches; never promotes —
        promotion is an explicit ``promote``/``get_or_build`` step so cold
        lookups stay O(1)."""
        with self._lock:
            entry = self._device.get(key)
            if entry is not None:
                self._device.move_to_end(key)
                return entry
            nr = self._nonres.get(key)
            if nr is not None:
                if key in self._host:
                    self._host.move_to_end(key)
                return nr
            return None

    # ------------------------------------------------------------- admission
    def admit(self, key: str, entry: PreparedDesign) -> PreparedDesign:
        """Insert a resident design into the device tier, demoting LRU
        entries while over budget.  Build races resolve first-writer-wins,
        exactly like the pre-store ``DesignCache.put``."""
        with self._lock:
            existing = self._device.get(key)
            if existing is not None:
                self._device.move_to_end(key)
                return existing
            self._device[key] = entry
            self.stats.admits += 1
            self._enforce_device()
            self._update_gauges()
            return entry

    def _enforce_device(self) -> None:
        """Demote LRU device entries while over the byte budget or entry
        cap.  Never demotes down to zero entries on the byte check: the
        most recent admission stays resident even when it alone exceeds
        the budget (designs *known* to exceed it are built non-resident
        instead — see ``build``)."""
        if self.max_entries is not None:
            while len(self._device) > self.max_entries:
                self._demote_lru()
        if self.device_bytes is not None:
            while (len(self._device) > 1
                   and self.device_used() > self.device_bytes):
                self._demote_lru()

    def _demote_lru(self) -> None:
        key, _ = next(iter(self._device.items()))
        self.demote(key)

    # -------------------------------------------------------------- demotion
    def demote(self, key: str) -> Optional[HostDesign]:
        """Device → host: snapshot every reusable piece of the resident
        handle — kernel layouts, norms, Cholesky factors and the per-tenant
        warm-coefficient LRU — into a ``HostDesign``, then release the
        device entry.  Enforces the host budget afterwards (host → disk)."""
        with self._lock:
            entry = self._device.pop(key, None)
            if entry is None:
                return None
            with entry._lock:
                snap = HostDesign(
                    key=key, shape=tuple(entry.x_pad.shape),
                    max_tenants=entry.max_tenants,
                    x_t={t: np.asarray(a) for t, a in entry._x_t.items()},
                    x_bf16={t: np.asarray(a)
                            for t, a in entry._x_bf16.items()},
                    cn=(np.asarray(entry._cn)
                        if entry._cn is not None else None),
                    chol={k: np.asarray(v) for k, v in entry.chol.items()},
                    warm=OrderedDict((t, np.array(c, np.float32))
                                     for t, c in entry._warm.items()),
                    home=entry.home,
                )
                if not snap.x_t:
                    snap.x_pad = np.asarray(entry.x_pad)
            self._host[key] = snap
            self._host.move_to_end(key)
            self.stats.demotions_device += 1
            self._move("device", "host")
            self._enforce_host()
            self._update_gauges()
            return snap

    def _enforce_host(self) -> None:
        if self.host_bytes is None:
            return
        while self.host_used() > self.host_bytes:
            # LRU order, skipping records that no longer hold X bytes
            # (state-only stubs cost nothing and must survive).
            victim = next((k for k, h in self._host.items() if h.has_x()),
                          None)
            if victim is None:
                return
            self._demote_to_disk(victim)

    def _demote_to_disk(self, key: str) -> None:
        host = self._host[key]
        if self.disk_dir is None:
            # No disk tier: drop the X bytes, keep the state-only record so
            # warm coefficients / Cholesky factors still restore on rebuild.
            host.drop_x()
            self.stats.x_drops += 1
            return
        obs_p, vars_p = host.shape
        thr = next(iter(host.x_t)) if host.x_t else min(DEFAULT_TILE, vars_p)
        nblocks = -(-vars_p // thr)
        tile_dir = self.disk_dir / _fs_key(key)
        tile_dir.mkdir(parents=True, exist_ok=True)
        rec = DiskDesign(key=key, shape=host.shape, tile_dir=tile_dir,
                         thr=thr, nblocks=nblocks,
                         max_tenants=host.max_tenants, cn=host.cn,
                         chol=host.chol, warm=host.warm, home=host.home)
        for j in range(nblocks):
            tile = host.read_cols(j * thr, (j + 1) * thr)
            _write_tile_atomic(rec.tile_path(j), tile)
        del self._host[key]
        self._disk[key] = rec
        self._disk.move_to_end(key)
        self.stats.demotions_disk += 1
        self._move("host", "disk")

    # ------------------------------------------------------------- promotion
    def promote(self, key: str) -> Optional[PreparedDesign]:
        """Climb ``key`` back to the hottest tier it fits.

        host/disk → device rebuilds the ``PreparedDesign`` from the
        snapshotted bytes and restores every piece of state — norms,
        Cholesky, kernel layouts and the warm-coefficient LRU (the PR 9
        eviction-regression fix).  A disk promotion deletes its tile files
        (round trip complete).  Designs too large for the device budget
        come back as (or keep) their non-resident streaming handle.
        Returns None when the key is unknown or only a state-only stub
        remains (caller rebuilds from source, then ``build`` restores the
        stub's state)."""
        with self._lock:
            hit = self._device.get(key)
            if hit is not None:
                self._device.move_to_end(key)
                return hit
            host = self._host.get(key)
            if host is not None and host.has_x():
                t0 = obs.now()
                entry = self._rebuild_from_host(host)
                if entry is None:          # over device budget: stays put
                    return self._nonres_handle(key, host.shape)
                del self._host[key]
                if key in self._nonres:
                    del self._nonres[key]
                self.stats.promotions_host += 1
                self._move("host", "device")
                self._h_fetch["host"].observe(obs.now() - t0)
                return self.admit(key, entry)
            disk = self._disk.get(key)
            if disk is not None:
                t0 = obs.now()
                try:
                    entry = self._rebuild_from_disk(disk)
                except TileCorruptionError as exc:
                    # Quarantine the damaged design; the caller sees a
                    # miss and rebuilds from the design source (with the
                    # stub's warm/derived state restored by ``build``).
                    self._quarantine(key, disk, exc)
                    return None
                if entry is None:
                    return self._nonres_handle(key, disk.shape)
                disk.delete_tiles()
                del self._disk[key]
                if key in self._nonres:
                    del self._nonres[key]
                self.stats.promotions_disk += 1
                self._move("disk", "device")
                self._h_fetch["disk"].observe(obs.now() - t0)
                return self.admit(key, entry)
            return None

    def _fits_device(self, shape: Tuple[int, int]) -> bool:
        return (self.device_bytes is None
                or shape[0] * shape[1] * 4 <= self.device_bytes)

    def _rebuild_from_host(self, host: HostDesign
                           ) -> Optional[PreparedDesign]:
        import jax.numpy as jnp
        if not self._fits_device(host.shape):
            return None
        obs_p, vars_p = host.shape
        if host.x_pad is not None:
            x_pad = host.x_pad
        else:
            x_t = next(iter(host.x_t.values()))
            x_pad = np.ascontiguousarray(x_t[:vars_p].T)
        entry = prepare(x_pad, fingerprint=host.key,
                        max_tenants=host.max_tenants)
        self._restore_state(entry, host.cn, host.chol, host.warm, host.home)
        with entry._lock:
            for thr, a in host.x_t.items():
                entry._x_t[thr] = jnp.asarray(a)
            for thr, a in host.x_bf16.items():
                entry._x_bf16[thr] = jnp.asarray(a)
        return entry

    def _rebuild_from_disk(self, disk: DiskDesign
                           ) -> Optional[PreparedDesign]:
        import jax.numpy as jnp
        if not self._fits_device(disk.shape):
            return None
        obs_p, vars_p = disk.shape
        # verify_tile, not tile: promotion reads every byte anyway, so it
        # is THE place to pay for a full integrity sweep — a tile the lazy
        # streaming path already blessed still gets re-checked here.
        x_t = np.concatenate([disk.verify_tile(j)
                              for j in range(disk.nblocks)], axis=0)
        x_pad = np.ascontiguousarray(x_t[:vars_p].T)
        entry = prepare(x_pad, fingerprint=disk.key,
                        max_tenants=disk.max_tenants)
        self._restore_state(entry, disk.cn, disk.chol, disk.warm, disk.home)
        with entry._lock:
            entry._x_t[disk.thr] = jnp.asarray(x_t)
        return entry

    @staticmethod
    def _restore_state(entry: PreparedDesign, cn, chol, warm, home) -> None:
        import jax.numpy as jnp
        with entry._lock:
            if cn is not None:
                entry._cn = jnp.asarray(cn)
            for k, v in chol.items():
                entry.chol[k] = jnp.asarray(v)
            for t, c in warm.items():
                entry._warm[t] = np.array(c, np.float32)
            if home is not None and entry.home is None:
                entry.home = home

    # ------------------------------------------------------------------ build
    def build(self, key: str, x_pad: np.ndarray, *,
              max_tenants: int = 64) -> PreparedDesign:
        """Build the servable handle for a design from its padded matrix.

        Fits the device budget → a resident ``prepare``d handle, admitted
        to the device tier (demoting LRU entries as needed).  Over budget →
        the bytes land on the host tier (spilling to disk under the host
        budget) and a non-resident streaming handle comes back.  Either
        way, a surviving state-only stub (warm coefficients, Cholesky) from
        an earlier X-byte drop is restored onto the new handle."""
        x_pad = np.asarray(x_pad, np.float32)
        with self._lock:
            existing = self.get(key)
            if existing is not None:
                return existing
            stub = self._host.get(key)
            if self._fits_device(x_pad.shape):
                entry = prepare(x_pad, fingerprint=key,
                                max_tenants=max_tenants)
                if stub is not None:
                    self._restore_state(entry, stub.cn, stub.chol,
                                        stub.warm, stub.home)
                    del self._host[key]
                return self.admit(key, entry)
            # Non-resident: X bytes live on the host tier; the handle
            # streams blocks through the store.
            host = stub if stub is not None else HostDesign(
                key=key, shape=tuple(x_pad.shape), max_tenants=max_tenants)
            host.shape = tuple(x_pad.shape)
            host.max_tenants = max_tenants
            if not host.has_x():
                host.x_pad = x_pad
            if host.cn is None:
                host.cn = np.einsum("ij,ij->j", x_pad, x_pad,
                                    dtype=np.float32)
            self._host[key] = host
            self._host.move_to_end(key)
            self.stats.builds_nonresident += 1
            entry = self._nonres_handle(key, host.shape)
            self._enforce_host()
            self._update_gauges()
            return entry

    def _nonres_handle(self, key: str,
                       shape: Tuple[int, int]) -> PreparedDesign:
        import jax.numpy as jnp
        handle = self._nonres.get(key)
        if handle is not None:
            return handle
        rec = self._host.get(key) or self._disk.get(key)
        cn = rec.cn if rec is not None else None
        handle = PreparedDesign(
            x_pad=None, fingerprint=key,
            max_tenants=rec.max_tenants if rec is not None else 64,
            blocks=StoreBlockSource(self, key, shape),
            _cn=jnp.asarray(cn) if cn is not None else None,
        )
        if rec is not None:
            self._restore_state(handle, None, rec.chol, rec.warm, rec.home)
        self._nonres[key] = handle
        return handle

    # --------------------------------------------------------- quarantine
    def _quarantine(self, key: str, disk: DiskDesign,
                    exc: TileCorruptionError) -> None:
        """Take a damaged design off the disk tier (must hold the lock).

        The tile directory is renamed aside (``.quarantine``) for forensic
        inspection rather than deleted, the disk record AND any live
        streaming handle are dropped (a stale handle would keep fetching
        the dead tiles), and a state-only ``HostDesign`` stub keeps the
        warm coefficients / Cholesky / norms so a rebuild from the design
        source restores the tenant state."""
        _log.warning("quarantining design %r: %s", key, exc)
        del self._disk[key]
        self._nonres.pop(key, None)
        qdir = disk.tile_dir.with_name(disk.tile_dir.name + ".quarantine")
        try:
            shutil.rmtree(qdir, ignore_errors=True)
            os.replace(disk.tile_dir, qdir)
        except OSError:
            shutil.rmtree(disk.tile_dir, ignore_errors=True)
        if key not in self._host:
            self._host[key] = HostDesign(
                key=key, shape=disk.shape, max_tenants=disk.max_tenants,
                cn=disk.cn, chol=disk.chol, warm=disk.warm, home=disk.home)
        self.stats.tile_corruptions += 1
        self._m_corruption.inc(1)
        self._update_gauges()

    # ----------------------------------------------------------- block fetch
    def _fetch_block(self, key: str, thr: int, j: int) -> np.ndarray:
        t0 = obs.now()
        with self._lock:
            host = self._host.get(key)
            if host is not None and host.has_x():
                out = host.read_cols(j * thr, (j + 1) * thr)
                self._h_fetch["host"].observe(obs.now() - t0)
                return out
            disk = self._disk.get(key)
            if disk is not None:
                # Chaos site: stall the disk read (deadline storms against
                # the streaming path).
                faults.maybe_delay("store.read_delay", key)
                try:
                    out = disk.read_cols(j * thr, (j + 1) * thr)
                except TileCorruptionError as exc:
                    self._quarantine(key, disk, exc)
                    raise
                self._h_fetch["disk"].observe(obs.now() - t0)
                return out
            entry = self._device.get(key)
            if entry is not None:
                # A promoted-mid-solve design: serve blocks off the
                # resident copy (host view of the device array).
                x = np.asarray(entry.x_pad)
                lo, hi = j * thr, (j + 1) * thr
                out = np.zeros((thr, x.shape[0]), np.float32)
                real = min(hi, x.shape[1]) - lo
                if real > 0:
                    out[:real] = x[:, lo:lo + real].T
                self._h_fetch["host"].observe(obs.now() - t0)
                return out
        raise KeyError(f"design {key!r} has no X bytes in any store tier")

    # ------------------------------------------------------------- lifecycle
    def keys(self) -> List[str]:
        with self._lock:
            return list({*self._device, *self._host, *self._disk,
                         *self._nonres})

    def close(self) -> None:
        """Drop every tier (deleting disk tiles).  For tests/benchmarks;
        production stores live as long as their engine."""
        with self._lock:
            for rec in self._disk.values():
                rec.delete_tiles()
            self._device.clear()
            self._host.clear()
            self._disk.clear()
            self._nonres.clear()
            self._update_gauges()


def _fs_key(key: str) -> str:
    """Filesystem-safe tile-directory name for a design fingerprint."""
    return "".join(c if c.isalnum() or c in "._-" else "_" for c in key)
