"""repro.store — tiered (device / host / disk) design residency store.

``DesignStore`` turns device memory into the hot tier of a three-tier
store: LRU demotion replaces eviction (device → host-RAM snapshot → disk
tile files), promotion restores every piece of snapshotted state (norms,
Cholesky factors, per-tenant warm-start coefficients), and designs too
large for the device budget are served through a non-resident streaming
handle (``StoreBlockSource`` + the ``"bakp_stream"`` solver method).  See
``repro.store.store`` for the full design.
"""
from repro.store.store import (DesignStore, DiskDesign, HostDesign,
                               StoreBlockSource, StoreStats)

__all__ = [
    "DesignStore",
    "DiskDesign",
    "HostDesign",
    "StoreBlockSource",
    "StoreStats",
]
