"""Deterministic synthetic token pipeline — per-host sharded, resumable.

Production framing: each host generates (or in a real deployment, reads) only
its shard of the global batch; the iterator state is a plain (step, seed)
pair that checkpoints with the model, so restart resumes the exact stream
(fault tolerance requirement).  The synthetic stream is a fixed-vocabulary
Markov-ish mixture that a small LM can actually learn (used by the e2e
training example to show loss descent).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLM:
    """Synthetic next-token stream with learnable structure.

    Tokens follow a periodic template corrupted with noise: token t is
    ``(phase + t) % base`` with probability (1-noise), uniform otherwise.
    Perfectly learnable by any of the zoo families; loss floor ≈ the noise
    entropy.
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 *, host_count: int = 1, host_id: int = 0,
                 noise: float = 0.05, seed: int = 17):
        assert global_batch % host_count == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // host_count
        self.host_id = host_id
        self.noise = noise
        self.state = DataState(seed=seed, step=0)
        self.base = min(97, vocab - 1)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.state.seed, self.state.step, self.host_id))
        b, s = self.local_batch, self.seq_len
        phase = rng.integers(0, self.base, size=(b, 1))
        seq = (phase + np.arange(s + 1)[None, :]) % self.base
        noise_mask = rng.random((b, s + 1)) < self.noise
        noise_tok = rng.integers(0, self.vocab, size=(b, s + 1))
        seq = np.where(noise_mask, noise_tok, seq).astype(np.int32)
        self.state.step += 1
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def skip_to(self, step: int):
        """Fast-forward after checkpoint restore (no data replay needed —
        the stream is a pure function of (seed, step, host))."""
        self.state.step = step
