"""repro.data — deterministic, resumable synthetic data pipeline."""
from repro.data.pipeline import DataState, SyntheticLM
__all__ = ["DataState", "SyntheticLM"]
