"""Fault-injection harness for the serving stack's chaos tests.

A ``FaultPlan`` is a process-wide set of rules, each bound to a named
**injection site** compiled into the production code paths:

  ===================== ====================================================
  site                  where it fires
  ===================== ====================================================
  ``lane.worker``       ``LaneExecutor._loop`` — raises *outside* the
                        per-work try, simulating worker-thread death (the
                        supervisor must fail only the in-flight unit and
                        restart the thread).
  ``lane.delay``        same spot, but sleeps ``delay_s`` instead of
                        raising — slow-device / deadline-storm simulation.
  ``solver.raise``      ``SolverServeEngine`` solve body — the solver call
                        raises before running (retry-ladder input).
  ``solver.diverge``    after a solve returns — the engine treats the
                        result as diverged (cold-retry / ladder input,
                        warm-coefficient retention must be skipped).
  ``store.tile_corrupt`` ``DiskDesign`` tile verification — the payload is
                        bit-flipped in memory before the CRC check, so the
                        checksum machinery detects "corruption" without
                        mutating the on-disk file.
  ``store.read_delay``  ``DesignStore._fetch_block`` disk reads — sleeps
                        ``delay_s`` per fetch (slow-disk simulation).
  ===================== ====================================================

The harness is **zero-cost when disarmed**: every hook starts with a
module-global ``None`` check, so production behaviour (and results) with no
plan installed is bit-identical to a build without the hooks.  Plans are
activated through ``ServeConfig.fault_plan`` (the engine installs at
construction) or ``repro.launch.solver_serve --fault-plan`` (JSON text or a
path to a JSON file), so chaos runs exercise the real production binary.

JSON shape — a mapping of site name to rule knobs::

    {"lane.worker": {"count": 2},
     "solver.raise": {"count": 1, "skip": 3, "match": "bakp"},
     "store.read_delay": {"count": 0, "delay_s": 0.005}}

``count`` bounds how many times the rule arms (``0`` = unlimited);
``skip`` lets the first N matching hits through unarmed; ``match`` is a
substring filter on the hook's tag (lane label, method name, design key).

Thread-safety: rule counters mutate under the plan's lock; hooks are
called from lane threads, the dispatch thread and solver bodies
concurrently.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Union

#: The sites compiled into the serving stack (see module doc).
SITES = ("lane.worker", "lane.delay", "solver.raise", "solver.diverge",
         "store.tile_corrupt", "store.read_delay")


class FaultInjected(RuntimeError):
    """An injected fault (never raised unless a ``FaultPlan`` is armed)."""

    def __init__(self, site: str, tag: str = ""):
        self.site = site
        self.tag = tag
        super().__init__(f"injected fault at {site!r}"
                         + (f" (tag={tag!r})" if tag else ""))


@dataclass
class FaultRule:
    """One armed rule at one site.

    ``count`` bounds arming (0 = unlimited); ``skip`` passes the first N
    matching hits through unarmed; ``match`` substring-filters the hook
    tag; ``delay_s`` is the sleep the latency sites inject.
    ``seen``/``fired`` are live counters (plan-lock guarded).
    """

    site: str
    count: int = 1
    skip: int = 0
    delay_s: float = 0.0
    match: str = ""
    seen: int = 0
    fired: int = 0

    def _arm(self, tag: str) -> bool:
        """Decide (and record) whether this hit arms.  Plan-lock held."""
        if self.match and self.match not in tag:
            return False
        self.seen += 1
        if self.seen <= self.skip:
            return False
        if self.count > 0 and self.fired >= self.count:
            return False
        self.fired += 1
        return True


class FaultPlan:
    """A set of ``FaultRule``s, keyed by injection site."""

    def __init__(self, rules: Optional[Dict[str, dict]] = None):
        self._lock = threading.Lock()
        self.rules: Dict[str, FaultRule] = {}
        for site, knobs in (rules or {}).items():
            self.add(site, **(knobs or {}))

    def add(self, site: str, **knobs) -> FaultRule:
        if site not in SITES:
            raise ValueError(
                f"unknown fault site {site!r}; sites: {SITES}")
        rule = FaultRule(site=site, **knobs)
        with self._lock:
            self.rules[site] = rule
        return rule

    def hit(self, site: str, tag: str = "") -> Optional[FaultRule]:
        """The armed rule for this hit, or None (counts the hit)."""
        with self._lock:
            rule = self.rules.get(site)
            if rule is None or not rule._arm(tag):
                return None
            return rule

    def counts(self) -> Dict[str, dict]:
        """Per-site ``{seen, fired}`` counters (chaos-run reporting)."""
        with self._lock:
            return {s: {"seen": r.seen, "fired": r.fired}
                    for s, r in self.rules.items()}

    # ---------------------------------------------------------- coercion
    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        """Parse the ``--fault-plan`` JSON mapping (see module doc)."""
        data = json.loads(text)
        if not isinstance(data, dict):
            raise ValueError(
                f"fault plan must be a JSON object of site -> rule knobs, "
                f"got {type(data).__name__}")
        return cls(data)

    @classmethod
    def coerce(cls, obj: Union["FaultPlan", dict, str]) -> "FaultPlan":
        """Accept a ``FaultPlan``, a rules dict, inline JSON text, or a
        path to a JSON file (the ``ServeConfig.fault_plan`` contract)."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(obj)
        if isinstance(obj, str):
            if os.path.exists(obj):
                with open(obj) as f:
                    return cls.from_json(f.read())
            return cls.from_json(obj)
        raise TypeError(
            f"fault_plan must be a FaultPlan, dict or JSON str, "
            f"got {type(obj).__name__}")


# Process-wide armed plan.  ``None`` (the default) short-circuits every
# hook before any work happens — the bit-identical-when-unset guarantee.
_PLAN: Optional[FaultPlan] = None


def install(plan: Union[FaultPlan, dict, str, None]) -> Optional[FaultPlan]:
    """Arm a plan process-wide (None disarms).  Returns the armed plan."""
    global _PLAN
    _PLAN = None if plan is None else FaultPlan.coerce(plan)
    return _PLAN


def clear() -> None:
    """Disarm fault injection (restores bit-identical production paths)."""
    install(None)


def active() -> Optional[FaultPlan]:
    return _PLAN


class installed:
    """Context manager arming ``plan`` for the block (tests/benchmarks)."""

    def __init__(self, plan: Union[FaultPlan, dict, str]):
        self.plan = FaultPlan.coerce(plan)

    def __enter__(self) -> FaultPlan:
        install(self.plan)
        return self.plan

    def __exit__(self, *exc) -> None:
        clear()


# ------------------------------------------------------------------ hooks
def hit(site: str, tag: str = "") -> Optional[FaultRule]:
    """The armed rule for this hit, or None.  The one-load ``_PLAN is
    None`` fast path is the entire disarmed cost."""
    plan = _PLAN
    if plan is None:
        return None
    return plan.hit(site, tag)


def maybe_raise(site: str, tag: str = "") -> None:
    """Raise ``FaultInjected`` when the site's rule arms (no-op unarmed)."""
    rule = hit(site, tag)
    if rule is not None:
        if rule.delay_s > 0:
            time.sleep(rule.delay_s)
        raise FaultInjected(site, tag)


def maybe_delay(site: str, tag: str = "") -> bool:
    """Sleep ``delay_s`` when the site's rule arms; True if it did."""
    rule = hit(site, tag)
    if rule is None:
        return False
    if rule.delay_s > 0:
        time.sleep(rule.delay_s)
    return True
