"""repro.resilience — fault injection, retry ladders and chaos tooling.

Built for the serving stack's failure story (PR 10): every failure mode the
stack can hit — a lane worker thread dying, a solver raising or diverging,
a corrupt or slow disk tile — is *injectable* (``faults``: a process-wide
``FaultPlan`` with named sites compiled into the production paths, zero
cost when disarmed), *observable* (``serve_lane_restarts_total``,
``serve_lane_health``, ``solver_retries_total``,
``store_tile_corruption_total``) and *survivable* (supervised lane
restarts with a serial-fallback circuit breaker, the engine's
retry/degradation ladder, and CRC-verified crash-safe store tiles).

The consumers live where the failures live — ``repro.serve.lanes``,
``repro.serve.engine``, ``repro.store.store`` — this package holds the
harness (``faults``) and the ladder policy (``ladder``).
"""
from repro.resilience.faults import (FaultInjected, FaultPlan, FaultRule,
                                     SITES, active, clear, hit, install,
                                     installed, maybe_delay, maybe_raise)
from repro.resilience.ladder import backoff_s, next_rung, rungs

__all__ = [
    "FaultInjected",
    "FaultPlan",
    "FaultRule",
    "SITES",
    "active",
    "backoff_s",
    "clear",
    "hit",
    "install",
    "installed",
    "maybe_delay",
    "maybe_raise",
    "next_rung",
    "rungs",
]
