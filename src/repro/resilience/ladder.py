"""The retry/degradation ladder — capability-aware fallback spec chains.

When a solve fails (raises) or diverges, the serving engine retries it
down a ladder of progressively cheaper/safer configurations instead of
erroring the request outright:

  1. **cold restart** — if the failed solve warm-started, the warm state is
     implicated first: retry the *same* rung with ``a0=None`` (a poisoned
     warm coefficient is the most common divergence cause);
  2. **precision** — a reduced-precision X stream (``bf16`` /
     ``bf16_fp32acc``) falls back to ``"fp32"`` on the same method;
  3. **method** — each registry entry names its own fallback
     (``MethodEntry.fallback``): fused megakernels fall back to their
     per-sweep XLA family, the block-Jacobi family to the streaming
     out-of-core path, and everything bottoms out at the direct ``"lstsq"``
     baseline, which cannot diverge.

``next_rung`` yields one step of 2–3; the engine layers the cold restart,
jittered backoff (``backoff_s``) and the request-deadline bound on top
(``SolverServeEngine._attempt_solve``).
"""
from __future__ import annotations

import random
from typing import List, Optional

from repro.core.spec import SolverSpec, solver_method


def next_rung(spec: SolverSpec) -> Optional[SolverSpec]:
    """The next (strictly cheaper/safer) spec down the ladder, or None.

    Precision degrades before method: a bf16 failure retries at fp32 on
    the same kernel first, so a numerically marginal solve is not punished
    with a slower method when full precision fixes it.
    """
    if spec.precision != "fp32":
        return spec.replace(precision="fp32")
    fb = solver_method(spec.method).fallback
    if fb is None or fb == spec.method:
        return None
    return spec.replace(method=fb)


def rungs(spec: SolverSpec) -> List[SolverSpec]:
    """The full ladder from ``spec`` (exclusive) to its floor, in order."""
    out: List[SolverSpec] = []
    cur = next_rung(spec)
    while cur is not None:
        out.append(cur)
        cur = next_rung(cur)
    return out


def backoff_s(attempt: int, base: float, cap: float = 0.05) -> float:
    """Jittered exponential backoff before retry ``attempt`` (0-based).

    ``base * 2**attempt``, capped, with ±50% uniform jitter so a burst of
    co-failing requests doesn't retry in lockstep.
    """
    if base <= 0:
        return 0.0
    delay = min(cap, base * (2 ** attempt))
    return delay * (0.5 + random.random())
