"""repro — the BAK coordinate-descent linear solver (Bakas 2021) as a
production-grade multi-pod JAX framework.  See README.md / DESIGN.md."""
__version__ = "1.0.0"
