"""Pallas TPU kernels with *streamed* obs — no VMEM-residency requirement.

These cover the regimes where the residual itself is too large for VMEM
(obs beyond ~10⁶ per device): the residual tiles through VMEM one block per
grid step, so obs is unbounded.

``block_update`` — paper Algorithm 2 line 9, the rank-``thr`` residual
correction ``e ← e − x_blkᵀ·da``: one MXU (CB×obs_tile) pass per grid step.

``score_features`` — SolveBakF line 3 scoring for *all* features in a single
pass over x: partial ⟨x_j, e⟩ accumulate in a VMEM scratch across the inner
(obs) grid dimension; the finished scores ⟨x_j,e⟩²/⟨x_j,x_j⟩ are written once
per column block.  Fuses the matvec, square and scale the paper does with
three BLAS calls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _block_update_kernel(x_ref, da_ref, e_ref, out_ref):
    """Grid: (n_obs_tiles,).  x_ref: (CB, OT); da_ref: (CB, k);
    e_ref/out_ref: (k, OT) — k right-hand sides share the x stream."""
    xb = x_ref[...].astype(jnp.float32)
    da = da_ref[...]
    corr = jax.lax.dot_general(da, xb, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    out_ref[...] = e_ref[...].astype(jnp.float32) - corr


def block_update(x_t_blk, e, da, *, obs_tile=4096, interpret=None):
    """e' = e − x_blkᵀ·da with obs streamed in ``obs_tile`` chunks.

    Args:
      x_t_blk: (CB, obs) transposed column block.
      e: (obs,) residual or (k, obs) multi-RHS residuals.
      da: (CB,) or (CB, k) block coefficient increments.
    Returns:
      Updated residual, same rank as ``e``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cb, obs = x_t_blk.shape
    single = e.ndim == 1
    e2 = e.reshape(1, obs) if single else e
    nrhs = e2.shape[0]
    obs_tile = min(obs_tile, obs)
    assert obs % obs_tile == 0, (obs, obs_tile)
    grid = (obs // obs_tile,)
    out = pl.pallas_call(
        _block_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, obs_tile), lambda k: (0, k)),
            pl.BlockSpec((cb, nrhs), lambda k: (0, 0)),
            pl.BlockSpec((nrhs, obs_tile), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((nrhs, obs_tile), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((nrhs, obs), jnp.float32),
        interpret=interpret,
    )(x_t_blk, da.reshape(cb, nrhs).astype(jnp.float32),
      e2.astype(jnp.float32))
    return out[0] if single else out


def _score_kernel(x_ref, e_ref, invcn_ref, out_ref, g_scr):
    """Grid: (n_col_blocks, n_obs_tiles) — obs is the inner (fastest) dim.
    x_ref: (CB, OT); e_ref: (1, OT); invcn_ref/out_ref: (CB, 1);
    g_scr: (CB, 1) fp32 partial-dot accumulator."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        g_scr[...] = jnp.zeros_like(g_scr)

    xb = x_ref[...].astype(jnp.float32)
    eb = e_ref[...].astype(jnp.float32)
    g_scr[...] += jax.lax.dot_general(xb, eb, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(1) - 1)
    def _finish():
        g = g_scr[...]
        out_ref[...] = g * g * invcn_ref[...]


def score_features(x_t, e, inv_cn, *, col_block=512, obs_tile=4096,
                   interpret=None):
    """SolveBakF scores for all features: ⟨x_j,e⟩²/⟨x_j,x_j⟩, one x pass.

    Args:
      x_t: (vars, obs); e: (obs,); inv_cn: (vars,).
    Returns: (vars,) fp32 scores.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nvars, obs = x_t.shape
    col_block = min(col_block, nvars)
    obs_tile = min(obs_tile, obs)
    assert nvars % col_block == 0 and obs % obs_tile == 0
    grid = (nvars // col_block, obs // obs_tile)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((col_block, obs_tile), lambda i, k: (i, k)),
            pl.BlockSpec((1, obs_tile), lambda i, k: (0, k)),
            pl.BlockSpec((col_block, 1), lambda i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((col_block, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nvars, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((col_block, 1), jnp.float32)],
        interpret=interpret,
    )(x_t, e.reshape(1, obs).astype(jnp.float32),
      inv_cn.reshape(nvars, 1).astype(jnp.float32))
    return out[:, 0]
