"""repro.kernels — Pallas TPU kernels for the solver's compute hot-spots.

  fused_solve.py   whole-solve megakernel: ONE pallas_call runs the entire
                   SolveBak/SolveBakP iteration — x/residual/coefficients
                   VMEM-resident across all sweeps, convergence decided
                   on-chip, true early exit (no compute, no DMA after it).
  cd_sweep.py      per-sweep VMEM-resident CD sweep (Alg. 1) + block-Jacobi
                   sweep (Alg. 2) — x streamed HBM->VMEM once per sweep,
                   residual resident in VMEM scratch across the grid.
  block_update.py  obs-streamed rank-thr residual correction + fused
                   SolveBakF feature scoring.
  ops.py           solver entries: solvebakp_kernel (fused when the design
                   fits VMEM, per-sweep launch loop otherwise) + wrappers
                   (interpret=True off-TPU, y/a0 buffer donation on
                   accelerators).
  ref.py           pure-jnp oracles, tested via shape/dtype sweeps.
"""
from repro.kernels.block_update import block_update, score_features
from repro.kernels.cd_sweep import bakp_sweep, cd_sweep
from repro.kernels.fused_solve import (
    fused_fits,
    fused_solve,
    fused_vmem_bytes,
)
from repro.kernels.ops import (
    block_update_kernel,
    score_features_kernel,
    solvebakp_kernel,
    solvebakp_persweep_kernel,
)

__all__ = [
    "bakp_sweep",
    "block_update",
    "block_update_kernel",
    "cd_sweep",
    "fused_fits",
    "fused_solve",
    "fused_vmem_bytes",
    "score_features",
    "score_features_kernel",
    "solvebakp_kernel",
    "solvebakp_persweep_kernel",
]
