"""repro.kernels — Pallas TPU kernels for the solver's compute hot-spots.

  cd_sweep.py      VMEM-resident CD sweep (Alg. 1) + block-Jacobi sweep
                   (Alg. 2) — x streamed HBM->VMEM once per sweep, residual
                   resident in VMEM scratch across the grid.
  block_update.py  obs-streamed rank-thr residual correction + fused
                   SolveBakF feature scoring.
  ops.py           jit'd wrappers (interpret=True off-TPU).
  ref.py           pure-jnp oracles, tested via shape/dtype sweeps.
"""
from repro.kernels.block_update import block_update, score_features
from repro.kernels.cd_sweep import bakp_sweep, cd_sweep
from repro.kernels.ops import (
    block_update_kernel,
    score_features_kernel,
    solvebakp_kernel,
)

__all__ = [
    "bakp_sweep",
    "block_update",
    "block_update_kernel",
    "cd_sweep",
    "score_features",
    "score_features_kernel",
    "solvebakp_kernel",
]
