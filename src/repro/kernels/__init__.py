"""repro.kernels — Pallas TPU kernels for the solver's compute hot-spots.

  fused_solve.py   whole-solve megakernel: ONE pallas_call runs the entire
                   SolveBak/SolveBakP iteration — x/residual/coefficients
                   VMEM-resident across all sweeps, convergence decided
                   on-chip, true early exit (no compute, no DMA after it).
  cd_sweep.py      per-sweep VMEM-resident CD sweep (Alg. 1) + block-Jacobi
                   sweep (Alg. 2) — x streamed HBM->VMEM once per sweep,
                   residual resident in VMEM scratch across the grid.
  block_update.py  obs-streamed rank-thr residual correction + fused
                   SolveBakF feature scoring.
  stream_solve.py  streaming out-of-core megakernel: x tiles stay in HBM
                   (pltpu.ANY) and double-buffer through a two-slot VMEM
                   scratch per block — the VMEM working set is independent
                   of vars, so over-budget designs keep the single-launch
                   early-exit execution model.  Plus a host block loop
                   (stream_solve_blocks) for store-backed non-resident
                   designs whose tiles fetch from host RAM or disk.
  ops.py           solver entries: solvebakp_kernel (fused when the design
                   fits VMEM, per-sweep launch loop otherwise),
                   solvebakp_stream_kernel (HBM-resident x, streamed) +
                   wrappers (interpret=True off-TPU, y/a0 buffer donation
                   on accelerators).
  ref.py           pure-jnp oracles, tested via shape/dtype sweeps.
"""
from repro.kernels.block_update import block_update, score_features
from repro.kernels.cd_sweep import bakp_sweep, cd_sweep
from repro.kernels.fused_solve import (
    fused_fits,
    fused_solve,
    fused_vmem_bytes,
)
from repro.kernels.ops import (
    block_update_kernel,
    score_features_kernel,
    solvebakp_kernel,
    solvebakp_persweep_kernel,
    solvebakp_stream_kernel,
)
from repro.kernels.stream_solve import (
    stream_fits,
    stream_solve,
    stream_solve_blocks,
    stream_vmem_bytes,
    stream_x_resident_bytes,
)

__all__ = [
    "bakp_sweep",
    "block_update",
    "block_update_kernel",
    "cd_sweep",
    "fused_fits",
    "fused_solve",
    "fused_vmem_bytes",
    "score_features",
    "score_features_kernel",
    "solvebakp_kernel",
    "solvebakp_persweep_kernel",
    "solvebakp_stream_kernel",
    "stream_fits",
    "stream_solve",
    "stream_solve_blocks",
    "stream_vmem_bytes",
    "stream_x_resident_bytes",
]
