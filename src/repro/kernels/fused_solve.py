"""Fused whole-solve Pallas megakernel — the entire SolveBak/SolveBakP
iteration in ONE ``pallas_call``.

The per-sweep kernel path (``repro.kernels.ops.solvebakp_persweep_kernel``)
drives each sweep as a separate ``pallas_call`` from a host-level
``lax.while_loop``: the residual round-trips HBM at every sweep boundary
(2·k·obs·4 bytes each way), convergence is decided off-chip, and every sweep
re-stages its VMEM working set.  This module fuses the whole solve instead:

  * **multi-sweep VMEM residency** — the design ``x_t`` (vars, obs), the
    residual(s) ``e`` (k, obs) and the coefficient accumulator (vars, k) are
    VMEM-resident for the *entire* solve.  ``x`` crosses HBM once per solve,
    not once per sweep — against the per-sweep stream that is an up-to-
    ``n_sweeps``× HBM-traffic reduction, which is everything for a kernel
    whose arithmetic intensity (≈4 flops/byte, see ``cd_sweep``) is far
    below the TPU ridge.
  * **on-chip convergence** — the per-sweep SSE is reduced on-chip and the
    ``sweep_stop_flags`` criterion (``repro.core.types``) is evaluated
    in-kernel; the scalar state (sse/n_sweeps/converged) lives in SMEM
    outputs.  No device→host sync per sweep.
  * **true early exit** — the logical (max_iter, n_col_blocks) grid runs
    *inside* the kernel as a ``while_loop`` over sweeps × ``fori_loop`` over
    column blocks, so post-convergence grid steps are genuinely skipped: no
    compute AND no DMA.  (A hardware 2-D grid cannot abort mid-flight —
    ``pl.when`` guards would still stream every remaining x block — which is
    why the iteration space is in-kernel.)  An early-converging solve costs
    only the sweeps it uses plus the one x load it actually reads.

The kernel accepts precomputed ``inv_cn`` (inverse squared column norms,
computed on the transposed layout — ``PreparedDesign`` caches them) and a
warm-start ``a0``, supports k ≥ 1 right-hand sides sharing the resident x,
and runs both block bodies:

  * ``variant="bakp"`` — Algorithm 2: per-block MXU matvec + rank-block
    residual correction (Jacobi within the block), ``omega`` relaxation.
  * ``variant="bak"``  — Algorithm 1: strictly sequential per-column scalar
    loop inside each block (bit-faithful ordering).

Fit check: whole-x residency needs ``fused_vmem_bytes`` of VMEM — callers
dispatch on ``fused_fits`` and fall back to the per-sweep stream or the XLA
solvers when the design is too large (``repro.core.methods`` wires exactly
that for the ``"bakp_fused"``/``"bak_fused"`` registry entries).

Off TPU the kernel runs in interpret mode — numerically identical, used by
the test suite and the CI benchmarks.
"""
from __future__ import annotations

import functools
import importlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import (SolveResult, column_norms_sq_t, donate_default,
                              safe_inv, sweep_stop_flags)

# The VMEM budget is shared with the per-sweep path.  Import the module via
# importlib: the package re-exports a *function* named cd_sweep, which
# shadows the submodule as a package attribute.
_cd = importlib.import_module("repro.kernels.cd_sweep")


def fused_vmem_bytes(nvars: int, obs: int, nrhs: int, itemsize: int,
                     *, max_iter: int = 1) -> int:
    """VMEM working set of one fused solve (bytes).

    x resident (nvars·obs·itemsize) + residual in/out (2·k·obs·4) +
    a0/coef (2·nvars·k·4) + inv_cn (nvars·4) + history (max_iter·4).
    """
    return (nvars * obs * itemsize
            + 2 * nrhs * obs * 4
            + 2 * nvars * nrhs * 4
            + nvars * 4
            + max_iter * 4)


def fused_fits(nvars: int, obs: int, nrhs: int, itemsize: int,
               *, max_iter: int = 1) -> bool:
    """Whether a fused whole-solve fits the VMEM budget.

    Reads ``repro.kernels.cd_sweep.VMEM_BUDGET_BYTES`` at call time (the
    same budget the per-sweep path enforces), so tests and deployments that
    adjust the budget adjust fused dispatch with it.
    """
    return fused_vmem_bytes(nvars, obs, nrhs, itemsize,
                            max_iter=max_iter) <= _cd.VMEM_BUDGET_BYTES


def _fused_kernel(scal_ref, x_ref, invcn_ref, e0_ref, a0_ref,
                  coef_ref, e_ref, hist_ref, sse_ref, n_ref, conv_ref,
                  *, block, max_iter, variant):
    """Whole-solve kernel body.  Refs:

    scal_ref: (3,) SMEM — [atol_sse, rtol, omega] (traced solver knobs,
        scalar-memory so tolerance changes never recompile).
    x_ref: (nvars, obs) VMEM — the resident design, transposed layout.
    invcn_ref: (nvars, 1) VMEM — inverse squared column norms (0 for
        zero/padded columns, so their updates are pinned to 0).
    e0_ref: (k, obs) / a0_ref: (nvars, k) VMEM — initial residual(s) and
        warm-start coefficients.
    coef_ref/e_ref/hist_ref: VMEM outputs, written in place as the solve's
        resident accumulators.  sse/n/conv: (1, 1) SMEM scalar outputs.

    The iteration space is the logical (max_iter, n_col_blocks) grid, run
    as while(sweeps) × fori(blocks) so convergence aborts it outright.
    """
    atol_sse, rtol, omega = scal_ref[0], scal_ref[1], scal_ref[2]
    nvars = x_ref.shape[0]
    nblocks = nvars // block

    e_ref[...] = e0_ref[...].astype(jnp.float32)
    coef_ref[...] = a0_ref[...]
    hist_ref[...] = jnp.full((max_iter, 1), jnp.nan, jnp.float32)

    def _sse():
        # dot-product reduction: matches the host solvers' jnp.vdot(e, e)
        # bit-for-bit in interpret mode, so fused/unfused stopping decisions
        # agree even at the rtol stall point (n_sweeps parity tests).
        e = e_ref[...]
        ef = e.reshape(1, e.shape[0] * e.shape[1])
        return lax.dot_general(ef, ef, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)[0, 0]

    sse0 = _sse()

    def block_step(b, _):
        xb = pl.load(x_ref, (pl.dslice(b * block, block),
                             slice(None))).astype(jnp.float32)   # (CB, obs)
        inv = pl.load(invcn_ref, (pl.dslice(b * block, block),
                                  slice(None)))                  # (CB, 1)
        # Block math shared with the per-sweep kernels (cd_sweep.py) — one
        # definition keeps the two execution models numerically in lockstep
        # (the n_sweeps/history parity tests depend on it).
        if variant == "bak":
            # Algorithm 1: strictly sequential per column within the block.
            def row(t, _):
                xj = lax.dynamic_slice_in_dim(xb, t, 1, axis=0)  # (1, obs)
                inv_j = lax.dynamic_slice_in_dim(inv, t, 1, 0)[0, 0]
                da, e = _cd.bak_row_update(xj, inv_j, e_ref[...])
                e_ref[...] = e
                old = pl.load(coef_ref, (pl.dslice(b * block + t, 1),
                                         slice(None)))
                pl.store(coef_ref, (pl.dslice(b * block + t, 1),
                                    slice(None)), old + da)
                return 0

            lax.fori_loop(0, block, row, 0)
        else:
            # Algorithm 2: Jacobi within the block, both matvecs on the MXU.
            da, e = _cd.bakp_block_update(xb, inv, e_ref[...], omega)
            e_ref[...] = e
            old = pl.load(coef_ref, (pl.dslice(b * block, block),
                                     slice(None)))
            pl.store(coef_ref, (pl.dslice(b * block, block),
                                slice(None)), old + da)
        return 0

    def sweep_body(state):
        i, sse_prev, converged, stop = state
        lax.fori_loop(0, nblocks, block_step, 0)
        sse = _sse()
        pl.store(hist_ref, (pl.dslice(i, 1), pl.dslice(0, 1)),
                 sse.reshape(1, 1))
        # The shared stopping criterion, evaluated on-chip — scalar jnp ops
        # trace fine inside the kernel, so the fused path can never drift
        # from the host solvers' semantics.
        converged, stop = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                           rtol)
        return i + 1, sse, converged, stop

    def cond(state):
        i, _, _, stop = state
        return (i < max_iter) & ~stop

    n, sse, converged, _ = lax.while_loop(
        cond, sweep_body,
        (jnp.int32(0), sse0, jnp.bool_(False), jnp.bool_(False)))
    sse_ref[0, 0] = sse
    n_ref[0, 0] = n
    conv_ref[0, 0] = converged.astype(jnp.int32)


def _fused_call(x_t, inv_cn, e0, a0m, scal, *, block, max_iter, variant,
                interpret):
    nvars, obs = x_t.shape
    nrhs = e0.shape[0]
    kern = functools.partial(_fused_kernel, block=block, max_iter=max_iter,
                             variant=variant)
    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nvars, nrhs), jnp.float32),   # coef
            jax.ShapeDtypeStruct((nrhs, obs), jnp.float32),     # residual
            jax.ShapeDtypeStruct((max_iter, 1), jnp.float32),   # history
            jax.ShapeDtypeStruct((1, 1), jnp.float32),          # sse
            jax.ShapeDtypeStruct((1, 1), jnp.int32),            # n_sweeps
            jax.ShapeDtypeStruct((1, 1), jnp.int32),            # converged
        ],
        cost_estimate=pl.CostEstimate(
            flops=4.0 * max_iter * nvars * obs * nrhs,
            bytes_accessed=nvars * obs * x_t.dtype.itemsize
            + 2 * nrhs * obs * 4 + 2 * nvars * nrhs * 4,
            transcendentals=0,
        ),
        interpret=interpret,
    )(scal, x_t, inv_cn, e0, a0m)


def validate_solver_args(x_t, y, cn, inv_cn, a0):
    """Shared shape validation + norm resolution for the kernel solver
    entries (this wrapper AND ops.py's per-sweep/shim wrappers — one
    definition, one set of error messages).  Returns (multi, nrhs, inv_cn),
    with ``cn`` folded into ``inv_cn`` when only the raw norms were given.
    """
    nvars, obs = x_t.shape
    if y.ndim not in (1, 2):
        raise ValueError(f"y must be (obs,) or (obs, k), got {y.shape}")
    multi = y.ndim == 2
    nrhs = y.shape[1] if multi else 1
    if a0 is not None and a0.shape not in ((nvars,), (nvars, nrhs)):
        raise ValueError(
            f"a0 must be ({nvars},) or ({nvars}, {nrhs}) matching x_t rows "
            f"and y RHS count, got {a0.shape}")
    if inv_cn is None and cn is not None:
        inv_cn = safe_inv(cn)
    return multi, nrhs, inv_cn


def solve_init(x_t, y, inv_cn, a0, multi):
    """Shared kernel-solver initialisation (fused AND per-sweep paths):
    resolve the inverse norms, cast ``y`` to the (k, obs) kernel layout and
    build the initial coefficients/residual — ``e0 = y - x @ a0`` computed
    on the transposed layout ((vars,) ``a0`` broadcasts across all RHS,
    paper line 2).  One definition so a future change (dtype handling, the
    broadcast rule) cannot split the two execution models' numerics.

    Returns ``(inv_cn, a0m, e0)`` with a0m (vars, k) and e0 (k, obs), fp32.
    """
    nvars, obs = x_t.shape
    nrhs = y.shape[1] if multi else 1
    if inv_cn is None:
        inv_cn = safe_inv(column_norms_sq_t(x_t))
    y2 = y.reshape(obs, nrhs).astype(jnp.float32)
    if a0 is None:
        a0m = jnp.zeros((nvars, nrhs), jnp.float32)
        e0 = y2.T
    else:
        a0m = jnp.broadcast_to(
            a0.astype(jnp.float32).reshape(nvars, -1), (nvars, nrhs))
        e0 = y2.T - lax.dot_general(a0m, x_t.astype(jnp.float32),
                                    (((0,), (0,)), ((), ())))
    return inv_cn, a0m, e0


def _fused_impl(x_t, y, inv_cn, a0, atol, rtol, omega, *, block, max_iter,
                variant, multi, interpret):
    nvars, obs = x_t.shape
    nrhs = y.shape[1] if multi else 1
    inv_cn, a0m, e0 = solve_init(x_t, y, inv_cn, a0, multi)
    atol_sse = jnp.float32(obs * nrhs) * jnp.float32(atol) ** 2
    scal = jnp.stack([atol_sse, jnp.float32(rtol), jnp.float32(omega)])
    coef, e, hist, sse, n, conv = _fused_call(
        x_t, inv_cn.reshape(nvars, 1).astype(jnp.float32), e0, a0m, scal,
        block=block, max_iter=max_iter, variant=variant, interpret=interpret)
    converged = conv[0, 0] != 0
    if not multi:
        return SolveResult(coef[:, 0], e[0], sse[0, 0], n[0, 0], converged,
                           hist[:, 0])
    return SolveResult(coef, e.T, sse[0, 0], n[0, 0], converged, hist[:, 0])


@functools.lru_cache(maxsize=None)
def _jitted(block, max_iter, variant, multi, interpret, donate):
    return jax.jit(
        functools.partial(_fused_impl, block=block, max_iter=max_iter,
                          variant=variant, multi=multi, interpret=interpret),
        donate_argnums=(1, 3) if donate else (),   # y, a0
    )


def fused_solve(
    x_t: jax.Array,
    y: jax.Array,
    *,
    inv_cn: Optional[jax.Array] = None,
    cn: Optional[jax.Array] = None,
    a0: Optional[jax.Array] = None,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    variant: str = "bakp",
    interpret: Optional[bool] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Whole-solve fused SolveBak/SolveBakP megakernel (see module doc).

    Args:
      x_t: (vars, obs) TRANSPOSED design (kernel layout); vars must be a
        multiple of ``block``.  Resident in VMEM for the whole solve — use
        ``fused_fits`` to check, or call through ``solvebakp_kernel`` /
        method ``"bakp_fused"`` which fall back automatically.
      y: (obs,) right-hand side, or (obs, k) for k systems sharing the
        resident x (multi-RHS serving path).
      inv_cn / cn: optional precomputed inverse / raw squared column norms
        (vars,) — ``PreparedDesign`` caches these so repeated solves skip
        the norms pass.  ``inv_cn`` wins when both are given; neither →
        computed on the transposed layout (no ``x_t.T`` materialisation).
      a0: optional (vars,) / (vars, k) warm-start coefficients.
      block / max_iter / atol / rtol / omega: as ``solvebakp_kernel``.
      variant: "bakp" (Algorithm 2, MXU) or "bak" (Algorithm 1,
        bit-faithful sequential order).
      interpret: force interpret mode (defaults to True off-TPU).
      donate: donate the ``y``/``a0`` buffers to the solve (cuts
        steady-state HBM allocation on the serving flush path).  Default:
        auto-donate only host (numpy) operands, on accelerator backends at
        top level — a ``jax.Array`` you pass is never auto-donated (reuse
        stays safe); force with ``donate=True``.

    Returns:
      ``SolveResult`` exactly as ``solvebakp_kernel`` — multi-RHS gives
      (vars, k) coef / (obs, k) residual with total-SSE accounting.
    """
    nvars, obs = x_t.shape
    if variant not in ("bak", "bakp"):
        raise ValueError(f"unknown variant {variant!r}")
    if nvars % block != 0:
        raise ValueError(
            f"vars ({nvars}) must be a multiple of block ({block}); pad "
            f"columns (PreparedDesign.x_t_for does this)")
    if max_iter < 1:
        raise ValueError(f"max_iter must be >= 1, got {max_iter}")
    multi, nrhs, inv_cn = validate_solver_args(x_t, y, cn, inv_cn, a0)
    vmem = fused_vmem_bytes(nvars, obs, nrhs, x_t.dtype.itemsize,
                            max_iter=max_iter)
    if vmem > _cd.VMEM_BUDGET_BYTES:
        raise ValueError(
            f"fused_solve working set {vmem / 2**20:.1f} MiB exceeds VMEM "
            f"budget ({_cd.VMEM_BUDGET_BYTES / 2**20:.0f} MiB); use the "
            f"per-sweep stream (solvebakp_persweep_kernel), shard obs "
            f"across devices (repro.core.distributed), or reduce "
            f"obs ({obs}) / vars ({nvars}) / nrhs ({nrhs}).")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fn = _jitted(block, max_iter, variant, multi, bool(interpret),
                 donate_default(donate, y, a0))
    return fn(x_t, y, inv_cn, a0, atol, rtol, omega)
