"""Streaming out-of-core SolveBakP Pallas kernel — x tiles in ``pltpu.ANY``
memory, double-buffered into VMEM scratch.

The fused megakernel (``repro.kernels.fused_solve``) keeps the whole design
``x_t`` VMEM-resident for the solve, which caps the design size at the VMEM
budget.  This module generalises the same in-kernel (sweeps × col-blocks)
iteration space to designs that only fit **HBM** (or, via the design
store's host fallback below, not even that):

  * ``x_t`` stays in ``pltpu.ANY`` memory (the compiler leaves it in HBM);
    only a ``(2, block, obs)`` double buffer of it lives in VMEM scratch.
  * Each column block is DMA'd in with ``pltpu.make_async_copy`` one block
    ahead of the compute (slot ``b % 2`` computes while slot ``(b+1) % 2``
    fills), so the paper's "one dimension of X per iteration" memory claim
    is literal: x-bytes resident = ``2·block·obs·itemsize``, independent of
    ``vars``.
  * Everything else matches the fused kernel exactly — the residual(s) and
    coefficient accumulator are VMEM-resident across sweeps, the per-sweep
    SSE reduces on-chip, and the shared ``sweep_stop_flags`` criterion
    aborts the in-kernel loop on convergence (no DMA for sweeps that never
    run).  Warm-start ``a0`` and k ≥ 1 right-hand sides ride along
    unchanged.

x crosses HBM once per *sweep* here (vs once per *solve* fused) — the
price of unbounded design size; the block math itself is the shared
``cd_sweep.bakp_block_update``, so the two execution models cannot drift
numerically (the ``bakp_stream`` parity tests pin this).

``stream_solve_blocks`` is the out-of-core endpoint: a host-side
per-block sweep loop over any object exposing the ``StoreBlockSource``
interface (``shape``, ``num_blocks(thr)``, ``block_t(thr, j)``), used for
designs whose bytes live on the host/disk tiers of ``repro.store`` — and,
off-TPU, as the interpret-friendly reference the parity suite runs
everywhere.  It uses the same shared block update and stopping criterion.

Off TPU the Pallas kernel runs in interpret mode (DMA semantics included),
numerically identical to the compiled path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import SolveResult, donate_default, sweep_stop_flags
from repro.kernels.fused_solve import solve_init, validate_solver_args

# Shared block math + VMEM budget (see fused_solve on the importlib note).
import importlib
_cd = importlib.import_module("repro.kernels.cd_sweep")


def stream_x_resident_bytes(block: int, obs: int, itemsize: int) -> int:
    """x bytes resident on-chip during a streaming solve: the two scratch
    buffers.  Independent of ``vars`` — the whole point."""
    return 2 * block * obs * itemsize


def stream_vmem_bytes(nvars: int, obs: int, nrhs: int, itemsize: int, *,
                      block: int, max_iter: int = 1) -> int:
    """VMEM working set of one streaming solve (bytes): the x double
    buffer + residual in/out (2·k·obs·4) + a0/coef (2·nvars·k·4) + inv_cn
    (nvars·4) + history."""
    return (stream_x_resident_bytes(block, obs, itemsize)
            + 2 * nrhs * obs * 4
            + 2 * nvars * nrhs * 4
            + nvars * 4
            + max_iter * 4)


def stream_fits(nvars: int, obs: int, nrhs: int, itemsize: int, *,
                block: int, max_iter: int = 1) -> bool:
    """Whether a streaming solve's scratch + accumulators fit the shared
    VMEM budget (``repro.kernels.cd_sweep.VMEM_BUDGET_BYTES``, read at call
    time).  Note ``vars`` only enters through the O(vars·k) accumulators —
    designs far past the fused kernel's cap stream fine."""
    return stream_vmem_bytes(nvars, obs, nrhs, itemsize, block=block,
                             max_iter=max_iter) <= _cd.VMEM_BUDGET_BYTES


def _stream_kernel(scal_ref, x_hbm_ref, invcn_ref, e0_ref, a0_ref,
                   coef_ref, e_ref, hist_ref, sse_ref, n_ref, conv_ref,
                   *, block, max_iter):
    """Streaming whole-solve kernel body.  Refs as ``_fused_kernel`` except
    ``x_hbm_ref`` lives in ``pltpu.ANY`` (HBM) — the kernel DMAs one
    (block, obs) tile ahead of the compute into VMEM scratch."""
    atol_sse, rtol, omega = scal_ref[0], scal_ref[1], scal_ref[2]
    nvars, obs_p = x_hbm_ref.shape
    nblocks = nvars // block

    e_ref[...] = e0_ref[...].astype(jnp.float32)
    coef_ref[...] = a0_ref[...]
    hist_ref[...] = jnp.full((max_iter, 1), jnp.nan, jnp.float32)

    def _sse():
        # Same flattened dot reduction as the fused kernel — bit-for-bit
        # stopping parity with the host solvers in interpret mode.
        e = e_ref[...]
        ef = e.reshape(1, e.shape[0] * e.shape[1])
        return lax.dot_general(ef, ef, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)[0, 0]

    def solve_body(xscr_ref, sem_ref):
        sse0 = _sse()

        def dma(slot, b):
            return pltpu.make_async_copy(
                x_hbm_ref.at[pl.ds(b * block, block)],
                xscr_ref.at[slot], sem_ref.at[slot])

        def block_step(b, _):
            @pl.when(b + 1 < nblocks)
            def _prefetch():
                # Slot (b+1)%2 last served block b-1, whose wait+compute
                # finished in the previous (sequential) iteration — safe
                # to overwrite while block b computes out of slot b%2.
                dma((b + 1) % 2, b + 1).start()

            dma(b % 2, b).wait()
            xb = xscr_ref[b % 2].astype(jnp.float32)       # (block, obs)
            inv = pl.load(invcn_ref, (pl.dslice(b * block, block),
                                      slice(None)))        # (block, 1)
            da, e = _cd.bakp_block_update(xb, inv, e_ref[...], omega)
            e_ref[...] = e
            old = pl.load(coef_ref, (pl.dslice(b * block, block),
                                     slice(None)))
            pl.store(coef_ref, (pl.dslice(b * block, block),
                                slice(None)), old + da)
            return 0

        def sweep_body(state):
            i, sse_prev, converged, stop = state
            dma(0, 0).start()                              # warm-up fetch
            lax.fori_loop(0, nblocks, block_step, 0)
            sse = _sse()
            pl.store(hist_ref, (pl.dslice(i, 1), pl.dslice(0, 1)),
                     sse.reshape(1, 1))
            converged, stop = sweep_stop_flags(sse, sse_prev, sse0,
                                               atol_sse, rtol)
            return i + 1, sse, converged, stop

        def cond(state):
            i, _, _, stop = state
            return (i < max_iter) & ~stop

        n, sse, converged, _ = lax.while_loop(
            cond, sweep_body,
            (jnp.int32(0), sse0, jnp.bool_(False), jnp.bool_(False)))
        sse_ref[0, 0] = sse
        n_ref[0, 0] = n
        conv_ref[0, 0] = converged.astype(jnp.int32)

    pl.run_scoped(solve_body,
                  xscr_ref=pltpu.VMEM((2, block, obs_p), x_hbm_ref.dtype),
                  sem_ref=pltpu.SemaphoreType.DMA((2,)))


def _stream_call(x_t, inv_cn, e0, a0m, scal, *, block, max_iter, interpret):
    nvars, obs_p = x_t.shape
    nrhs = e0.shape[0]
    kern = functools.partial(_stream_kernel, block=block, max_iter=max_iter)
    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),     # x stays in HBM
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nvars, nrhs), jnp.float32),   # coef
            jax.ShapeDtypeStruct((nrhs, obs_p), jnp.float32),   # residual
            jax.ShapeDtypeStruct((max_iter, 1), jnp.float32),   # history
            jax.ShapeDtypeStruct((1, 1), jnp.float32),          # sse
            jax.ShapeDtypeStruct((1, 1), jnp.int32),            # n_sweeps
            jax.ShapeDtypeStruct((1, 1), jnp.int32),            # converged
        ],
        cost_estimate=pl.CostEstimate(
            # x crosses HBM once per sweep here (vs once per solve fused).
            flops=4.0 * max_iter * nvars * obs_p * nrhs,
            bytes_accessed=max_iter * nvars * obs_p * x_t.dtype.itemsize
            + 2 * nrhs * obs_p * 4 + 2 * nvars * nrhs * 4,
            transcendentals=0,
        ),
        interpret=interpret,
    )(scal, x_t, inv_cn, e0, a0m)


def _stream_impl(x_t, y, inv_cn, a0, atol, rtol, omega, *, block, max_iter,
                 multi, interpret):
    nvars, obs_p = x_t.shape
    nrhs = y.shape[1] if multi else 1
    inv_cn, a0m, e0 = solve_init(x_t, y, inv_cn, a0, multi)
    atol_sse = jnp.float32(obs_p * nrhs) * jnp.float32(atol) ** 2
    scal = jnp.stack([atol_sse, jnp.float32(rtol), jnp.float32(omega)])
    coef, e, hist, sse, n, conv = _stream_call(
        x_t, inv_cn.reshape(nvars, 1).astype(jnp.float32), e0, a0m, scal,
        block=block, max_iter=max_iter, interpret=interpret)
    converged = conv[0, 0] != 0
    if not multi:
        return SolveResult(coef[:, 0], e[0], sse[0, 0], n[0, 0], converged,
                           hist[:, 0])
    return SolveResult(coef, e.T, sse[0, 0], n[0, 0], converged, hist[:, 0])


@functools.lru_cache(maxsize=None)
def _jitted(block, max_iter, multi, interpret, donate):
    return jax.jit(
        functools.partial(_stream_impl, block=block, max_iter=max_iter,
                          multi=multi, interpret=interpret),
        donate_argnums=(1, 3) if donate else (),   # y, a0
    )


def stream_solve(
    x_t: jax.Array,
    y: jax.Array,
    *,
    inv_cn: Optional[jax.Array] = None,
    cn: Optional[jax.Array] = None,
    a0: Optional[jax.Array] = None,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    interpret: Optional[bool] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Streaming whole-solve SolveBakP kernel (see module doc).

    Arguments exactly as ``fused_solve`` minus ``variant`` (Algorithm 2
    only — the sequential Algorithm 1 order gains nothing from tile
    prefetch).  ``x_t`` may be any size that fits HBM; only the scratch +
    accumulators (``stream_vmem_bytes``) must fit the VMEM budget.
    """
    nvars, obs_p = x_t.shape
    if nvars % block != 0:
        raise ValueError(
            f"vars ({nvars}) must be a multiple of block ({block}); pad "
            f"columns (PreparedDesign.x_t_for does this)")
    if max_iter < 1:
        raise ValueError(f"max_iter must be >= 1, got {max_iter}")
    multi, nrhs, inv_cn = validate_solver_args(x_t, y, cn, inv_cn, a0)
    vmem = stream_vmem_bytes(nvars, obs_p, nrhs, x_t.dtype.itemsize,
                             block=block, max_iter=max_iter)
    if vmem > _cd.VMEM_BUDGET_BYTES:
        raise ValueError(
            f"stream_solve scratch+accumulators {vmem / 2**20:.1f} MiB "
            f"exceed the VMEM budget "
            f"({_cd.VMEM_BUDGET_BYTES / 2**20:.0f} MiB); reduce block "
            f"({block}) / nrhs ({nrhs}), or use the per-sweep stream")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fn = _jitted(block, max_iter, multi, bool(interpret),
                 donate_default(donate, y, a0))
    return fn(x_t, y, inv_cn, a0, atol, rtol, omega)


def stream_solve_blocks(
    blocks,
    y,
    *,
    inv_cn,
    a0=None,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
) -> SolveResult:
    """Out-of-core SolveBakP over a block source (host/disk-tier designs).

    ``blocks`` is any object with the ``StoreBlockSource`` interface:
    ``shape`` (obs, vars), ``block_t(thr, j)`` returning the (thr, obs)
    fp32 tile of the transposed layout.  One tile is fetched per block
    step — x never materialises in full anywhere, matching the paper's
    per-iteration memory claim even for designs bigger than host RAM
    (disk-tier tiles are memmapped).

    The block update (``cd_sweep.bakp_block_update``) and stopping
    criterion (``sweep_stop_flags``) are the exact functions the Pallas
    kernels run, so results track the resident paths to float-accumulation
    noise.  ``inv_cn`` must already be in the thr-padded layout
    (``PreparedDesign.inv_cn_for(block)``).
    """
    obs_p, vars_p = blocks.shape
    nblocks = -(-vars_p // block)
    vars_pb = nblocks * block
    y = jnp.asarray(y, jnp.float32)
    if y.ndim not in (1, 2):
        raise ValueError(f"y must be (obs,) or (obs, k), got {y.shape}")
    multi = y.ndim == 2
    nrhs = y.shape[1] if multi else 1
    if a0 is not None and a0.shape not in ((vars_pb,), (vars_pb, nrhs)):
        raise ValueError(
            f"a0 must be ({vars_pb},) or ({vars_pb}, {nrhs}), "
            f"got {tuple(a0.shape)}")
    inv = jnp.asarray(inv_cn, jnp.float32).reshape(vars_pb, 1)
    y2 = y.reshape(obs_p, nrhs)

    def fetch(j):
        return jnp.asarray(blocks.block_t(block, j), jnp.float32)

    if a0 is None:
        a = jnp.zeros((vars_pb, nrhs), jnp.float32)
        e = y2.T
    else:
        a = jnp.broadcast_to(
            jnp.asarray(a0, jnp.float32).reshape(vars_pb, -1),
            (vars_pb, nrhs))
        e = y2.T
        for j in range(nblocks):   # e0 = y.T - a0.T @ x_t, one tile at a time
            e = e - lax.dot_general(a[j * block:(j + 1) * block], fetch(j),
                                    (((0,), (0,)), ((), ())))
    sse0 = jnp.vdot(e, e)
    atol_sse = jnp.float32(obs_p * nrhs) * jnp.float32(atol) ** 2
    hist = np.full((max(max_iter, 0),), np.nan, np.float32)
    sse = sse_prev = sse0
    n = 0
    converged = False
    for i in range(max_iter):
        for j in range(nblocks):
            da, e = _cd.bakp_block_update(
                fetch(j), inv[j * block:(j + 1) * block], e, omega)
            a = a.at[j * block:(j + 1) * block].add(da)
        sse = jnp.vdot(e, e)
        hist[i] = float(sse)
        conv_f, stop_f = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                          jnp.float32(rtol))
        n = i + 1
        converged = bool(conv_f)
        sse_prev = sse
        if bool(stop_f):
            break
    if not multi:
        return SolveResult(a[:, 0], e[0], sse, jnp.int32(n),
                           jnp.bool_(converged), jnp.asarray(hist))
    return SolveResult(a, e.T, sse, jnp.int32(n), jnp.bool_(converged),
                       jnp.asarray(hist))
