"""Pallas TPU kernel: VMEM-resident coordinate-descent sweep (Algorithm 1).

The paper's inner loop is strictly sequential per column:

    da = ⟨x_j, e⟩/⟨x_j, x_j⟩ ;  e ← e − x_j·da ;  a_j += da

A mechanical port would round-trip the residual ``e`` through HBM per column
(2·obs·4 bytes each way) and be memory-latency-bound.  This kernel instead:

  * keeps ``e`` resident in a VMEM scratch buffer for the whole sweep —
    TPU grid steps execute sequentially on a core, so the scratch carries
    across the grid;
  * streams ``x`` through VMEM one (block × obs) tile per grid step — each
    element of ``x`` is read from HBM exactly once per sweep (the optimal
    traffic for this algorithm);
  * consumes the transposed layout (vars, obs) so a paper-"column" is a
    contiguous row: the sequential-update axis lands on sublanes (cheap
    dynamic indexing) and the obs axis lands on the 128-wide lanes (full
    VPU utilisation for the dot/update).

HBM traffic per sweep:  vars·obs·dtype_bytes (reads) + O(vars+obs) —
byte-optimal; arithmetic intensity ≈ 4 flops / dtype_bytes bytes, i.e. the
algorithm is HBM-bandwidth-bound on TPU (819 GB/s v5e ⇒ roofline
~1.6 Tflop/s effective in bf16).  See EXPERIMENTS.md §Roofline(solver).

The dual kernel ``bakp_sweep_kernel`` is the SolveBakP (Algorithm 2) variant:
identical memory schedule but MXU matvecs instead of the scalar loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# TPU VMEM working-set budget the wrapper enforces (conservative v5e figure;
# the compiler owns the real limit).
VMEM_BUDGET_BYTES = 64 * 1024 * 1024


def bak_row_update(xj, inv_j, e):
    """One Algorithm-1 column update on loaded values (shared by the
    per-sweep kernel below AND the fused megakernel — one definition so the
    two execution models cannot drift numerically).

    Args: xj (1, obs) column; inv_j scalar 1/⟨x_j,x_j⟩; e (k, obs).
    Returns (da, e'): (1, k) increment and the corrected residual(s).
    """
    da = lax.dot_general(xj, e, (((1,), (1,)), ((), ())),
                         preferred_element_type=jnp.float32)      # ⟨x_j, e⟩
    da = da * inv_j
    e = e - lax.dot_general(da, xj, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return da, e


def bakp_block_update(xb, inv, e, omega):
    """One Algorithm-2 block update on loaded values (shared as above).

    Args: xb (CB, obs) block; inv (CB, 1); e (k, obs); omega relaxation.
    Returns (da, e'): (CB, k) increments and the rank-CB-corrected
    residual(s); both matvecs hit the MXU.
    """
    g = lax.dot_general(xb, e, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32)       # (CB, k)
    da = omega * g * inv
    e = e - lax.dot_general(da, xb, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return da, e


def _cd_sweep_kernel(x_ref, invcn_ref, e_in_ref, da_ref, e_out_ref, e_scr):
    """Grid: (nblocks,).  Refs:
    x_ref: (CB, obs) tile of x_t        invcn_ref: (CB, 1)
    e_in_ref/e_out_ref: (k, obs)        da_ref: (CB, k)
    e_scr: VMEM scratch (k, obs) fp32 — the resident residual(s); k ≥ 1
    right-hand sides ride the same stream of x (multi-RHS serving).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        e_scr[...] = e_in_ref[...].astype(jnp.float32)

    xb = x_ref[...].astype(jnp.float32)      # (CB, obs)
    inv = invcn_ref[...]                     # (CB, 1)
    cb = xb.shape[0]
    nrhs = da_ref.shape[1]

    def body(t, _):
        xj = lax.dynamic_slice_in_dim(xb, t, 1, axis=0)       # (1, obs)
        inv_j = lax.dynamic_slice_in_dim(inv, t, 1, 0)[0, 0]
        da, e = bak_row_update(xj, inv_j, e_scr[...])
        e_scr[...] = e
        pl.store(da_ref, (pl.dslice(t, 1), pl.dslice(0, nrhs)), da)
        return 0

    lax.fori_loop(0, cb, body, 0)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        e_out_ref[...] = e_scr[...]


def _bakp_sweep_kernel(omega, x_ref, invcn_ref, e_in_ref, da_ref, e_out_ref,
                       e_scr):
    """SolveBakP sweep: Jacobi within the (CB, obs) tile, sequential across
    tiles.  Same refs as ``_cd_sweep_kernel``; the two matvecs hit the MXU.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        e_scr[...] = e_in_ref[...].astype(jnp.float32)

    xb = x_ref[...].astype(jnp.float32)          # (CB, obs)
    inv = invcn_ref[...]                         # (CB, 1)
    da, e = bakp_block_update(xb, inv, e_scr[...], omega)
    e_scr[...] = e
    da_ref[...] = da

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        e_out_ref[...] = e_scr[...]


def _sweep_call(kernel_fn, x_t, e, inv_cn, *, block, interpret):
    nvars, obs = x_t.shape
    assert nvars % block == 0, (nvars, block)
    single = e.ndim == 1
    e2 = e.reshape(1, obs) if single else e          # (k, obs) kernel layout
    nrhs = e2.shape[0]
    nblocks = nvars // block
    vmem = nrhs * obs * 4 + block * obs * x_t.dtype.itemsize
    if vmem > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"cd_sweep working set {vmem/2**20:.1f} MiB exceeds VMEM budget; "
            f"shard obs across devices (repro.core.distributed) or reduce "
            f"block ({block}) / obs ({obs}) / nrhs ({nrhs}).")

    grid = (nblocks,)
    da, e_out = pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, obs), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((nrhs, obs), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, nrhs), lambda i: (i, 0)),
            pl.BlockSpec((nrhs, obs), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nvars, nrhs), jnp.float32),
            jax.ShapeDtypeStruct((nrhs, obs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nrhs, obs), jnp.float32)],
        interpret=interpret,
    )(x_t, inv_cn.reshape(nvars, 1).astype(jnp.float32),
      e2.astype(jnp.float32))
    if single:
        return da[:, 0], e_out[0]
    return da, e_out


def cd_sweep(x_t, e, inv_cn, *, block=256, interpret=None):
    """One paper-faithful sequential CD sweep (all columns).  See module doc.

    Args:
      x_t: (vars, obs) transposed input; vars must divide ``block``.
      e: (obs,) residual, or (k, obs) for k right-hand sides sharing the
        single HBM stream of x (multi-RHS serving path).
      inv_cn: (vars,) inverse squared column norms.
      block: rows of x_t staged to VMEM per grid step (multiple of 8).
      interpret: force interpret mode (defaults to True off-TPU).
    Returns:
      (da, e'): increments and post-sweep residual — (vars,)/(obs,) for 1D
      input, (vars, k)/(k, obs) for multi-RHS.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _sweep_call(_cd_sweep_kernel, x_t, e, inv_cn, block=block,
                       interpret=interpret)


def bakp_sweep(x_t, e, inv_cn, *, block=256, omega=1.0, interpret=None):
    """One SolveBakP (block-Jacobi) sweep; multi-RHS as ``cd_sweep``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _sweep_call(functools.partial(_bakp_sweep_kernel, omega),
                       x_t, e, inv_cn, block=block, interpret=interpret)
