"""jit'd public wrappers around the Pallas kernels.

``solvebakp_kernel`` runs the full SolveBakP iteration built from the
``bakp_sweep``/``cd_sweep`` kernels — the TPU production path of the paper's
solver for problems whose residual fits VMEM (the distributed layer in
``repro.core.distributed`` shards obs so each device lands in this regime).

Off TPU all kernels run in interpret mode (Python execution of the kernel
body) — numerically identical, used by the test suite.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import (SolveResult, column_norms_sq, safe_inv,
                              sweep_stop_flags)
from repro.kernels.block_update import block_update, score_features
from repro.kernels.cd_sweep import bakp_sweep, cd_sweep


@functools.partial(jax.jit, static_argnames=("block", "max_iter", "variant",
                                             "interpret"))
def solvebakp_kernel(
    x_t: jax.Array,
    y: jax.Array,
    *,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    variant: str = "bakp",
    interpret: Optional[bool] = None,
) -> SolveResult:
    """Kernel-accelerated SolveBak/SolveBakP.

    Args:
      x_t: (vars, obs) TRANSPOSED input matrix (kernel layout; see
        repro.kernels.ref docstring).  vars must be a multiple of ``block``.
      y: (obs,) right-hand side, or (obs, k) for k right-hand sides sharing
        one HBM stream of x per sweep (multi-RHS serving path).
      variant: "bakp" (Algorithm 2 sweeps, MXU) or "bak" (Algorithm 1
        sequential sweeps, bit-faithful).

    Returns:
      SolveResult; multi-RHS input gives (vars, k) coef and (obs, k)
      residual with total-SSE convergence accounting.
    """
    nvars, obs = x_t.shape
    multi = y.ndim == 2
    nrhs = y.shape[1] if multi else 1
    inv_cn = safe_inv(column_norms_sq(x_t.T))
    sweep = cd_sweep if variant == "bak" else functools.partial(
        bakp_sweep, omega=omega)

    a0 = jnp.zeros((nvars, nrhs), jnp.float32)
    e0 = y.reshape(obs, nrhs).T.astype(jnp.float32)   # kernel layout (k, obs)
    sse0 = jnp.vdot(e0, e0)
    history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)
    atol_sse = jnp.float32(obs * nrhs) * jnp.float32(atol) ** 2

    def body(state):
        a, e, i, sse_prev, history, converged, stop = state
        da, e = sweep(x_t, e, inv_cn, block=block, interpret=interpret)
        a = a + da
        sse = jnp.vdot(e, e)
        history = history.at[i].set(sse)
        converged, stop = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                           rtol)
        return a, e, i + 1, sse, history, converged, stop

    def cond(state):
        _, _, i, _, _, _, stop = state
        return (i < max_iter) & ~stop

    a, e, n, sse, history, converged, _ = lax.while_loop(
        cond, body, (a0, e0, jnp.int32(0), sse0, history0, jnp.bool_(False),
                     jnp.bool_(False)))
    if not multi:
        return SolveResult(a[:, 0], e[0], sse, n, converged, history)
    return SolveResult(a, e.T, sse, n, converged, history)


@functools.partial(jax.jit, static_argnames=("col_block", "obs_tile",
                                             "interpret"))
def score_features_kernel(x_t, e, *, col_block=512, obs_tile=4096,
                          interpret=None):
    """Fused SolveBakF feature scoring (see block_update.score_features)."""
    inv_cn = safe_inv(column_norms_sq(x_t.T))
    return score_features(x_t, e, inv_cn, col_block=col_block,
                          obs_tile=obs_tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("obs_tile", "interpret"))
def block_update_kernel(x_t_blk, e, da, *, obs_tile=4096, interpret=None):
    """Fused rank-CB residual correction (paper Alg. 2 line 9)."""
    return block_update(x_t_blk, e, da, obs_tile=obs_tile,
                        interpret=interpret)
