"""jit'd public wrappers around the Pallas kernels.

``solvebakp_kernel`` is the TPU production entry for the paper's solver: it
dispatches the whole solve to the fused megakernel
(``repro.kernels.fused_solve`` — x/residual/coefficients VMEM-resident
across all sweeps, convergence decided on-chip, true early exit) whenever
the design fits the VMEM budget, and falls back to the original per-sweep
launch loop (``solvebakp_persweep_kernel`` — residual streamed back to HBM
at each sweep boundary, convergence decided off-chip) when it does not.
The per-sweep loop also remains the benchmark baseline
(``benchmarks.solver_roofline``).

Buffer donation: the jitted solver entries donate their ``y``/``a0``
operands on accelerator backends when those operands are HOST (numpy)
buffers — their in-jit device transfer is fresh, so donation is safe by
construction, and the serving flush path (which hands in host buffers
every batch) gets its steady-state HBM allocation cut.  ``jax.Array``
operands are never auto-donated (callers may reuse them); ``donate=True``
forces it, ``donate=False`` disables it.

Off TPU all kernels run in interpret mode (Python execution of the kernel
body) — numerically identical, used by the test suite.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import (SolveResult, column_norms_sq_t, donate_default,
                              safe_inv, sweep_stop_flags)
from repro.obs import record_dispatch
from repro.kernels.block_update import block_update, score_features
from repro.kernels.cd_sweep import bakp_sweep, cd_sweep
from repro.kernels.fused_solve import (fused_fits, fused_solve, solve_init,
                                       validate_solver_args)
from repro.kernels.stream_solve import stream_fits, stream_solve


def _persweep_impl(x_t, y, inv_cn, a0, atol, rtol, *, block, max_iter,
                   variant, multi, interpret, omega):
    # omega is compile-time here: the sweep kernels close over it (a traced
    # scalar cannot be captured by a pallas kernel body); the fused path
    # keeps it traced via its SMEM scalar input.
    nvars, obs = x_t.shape
    nrhs = y.shape[1] if multi else 1
    sweep = cd_sweep if variant == "bak" else functools.partial(
        bakp_sweep, omega=omega)
    inv_cn, a, e0 = solve_init(x_t, y, inv_cn, a0, multi)
    sse0 = jnp.vdot(e0, e0)
    history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)
    atol_sse = jnp.float32(obs * nrhs) * jnp.float32(atol) ** 2

    def body(state):
        a, e, i, sse_prev, history, converged, stop = state
        da, e = sweep(x_t, e, inv_cn, block=block, interpret=interpret)
        a = a + da
        sse = jnp.vdot(e, e)
        history = history.at[i].set(sse)
        converged, stop = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                           rtol)
        return a, e, i + 1, sse, history, converged, stop

    def cond(state):
        _, _, i, _, _, _, stop = state
        return (i < max_iter) & ~stop

    a, e, n, sse, history, converged, _ = lax.while_loop(
        cond, body, (a, e0, jnp.int32(0), sse0, history0, jnp.bool_(False),
                     jnp.bool_(False)))
    if not multi:
        return SolveResult(a[:, 0], e[0], sse, n, converged, history)
    return SolveResult(a, e.T, sse, n, converged, history)


@functools.lru_cache(maxsize=None)
def _jitted_persweep(block, max_iter, variant, multi, interpret, donate,
                     omega):
    return jax.jit(
        functools.partial(_persweep_impl, block=block, max_iter=max_iter,
                          variant=variant, multi=multi, interpret=interpret,
                          omega=omega),
        donate_argnums=(1, 3) if donate else (),   # y, a0
    )


def solvebakp_persweep_kernel(
    x_t: jax.Array,
    y: jax.Array,
    *,
    cn: Optional[jax.Array] = None,
    inv_cn: Optional[jax.Array] = None,
    a0: Optional[jax.Array] = None,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    variant: str = "bakp",
    interpret: Optional[bool] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Per-sweep-launch SolveBak/SolveBakP: one ``pallas_call`` per sweep
    driven by a host-level ``lax.while_loop`` (the pre-fusion execution
    model — kept as the large-design fallback and benchmark baseline; see
    module doc).  Arguments as ``solvebakp_kernel``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    multi, _, inv_cn = validate_solver_args(x_t, y, cn, inv_cn, a0)
    fn = _jitted_persweep(block, max_iter, variant, multi, bool(interpret),
                          donate_default(donate, y, a0), float(omega))
    return fn(x_t, y, inv_cn, a0, atol, rtol)


def solvebakp_kernel(
    x_t: jax.Array,
    y: jax.Array,
    *,
    cn: Optional[jax.Array] = None,
    inv_cn: Optional[jax.Array] = None,
    a0: Optional[jax.Array] = None,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    variant: str = "bakp",
    interpret: Optional[bool] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Kernel-accelerated SolveBak/SolveBakP.

    Dispatch: the fused whole-solve megakernel when the design fits VMEM
    (``fused_fits``), else the per-sweep launch loop — same results either
    way, different execution models (see module doc).

    Args:
      x_t: (vars, obs) TRANSPOSED input matrix (kernel layout; see
        repro.kernels.ref docstring).  vars must be a multiple of ``block``.
      y: (obs,) right-hand side, or (obs, k) for k right-hand sides sharing
        one stream of x per sweep (multi-RHS serving path).
      cn / inv_cn: optional precomputed (inverse) squared column norms
        (vars,) — lets ``PreparedDesign`` reuse its cached norms instead of
        recomputing them every solve.  Neither given → computed on the
        transposed layout directly (no ``x_t.T`` materialisation).
      a0: optional (vars,) / (vars, k) warm-start coefficients.
      variant: "bakp" (Algorithm 2 sweeps, MXU) or "bak" (Algorithm 1
        sequential sweeps, bit-faithful).
      donate: buffer donation for ``y``/``a0`` (see module doc).

    Returns:
      SolveResult; multi-RHS input gives (vars, k) coef and (obs, k)
      residual with total-SSE convergence accounting.
    """
    nvars, obs = x_t.shape
    _, nrhs, inv_cn = validate_solver_args(x_t, y, cn, inv_cn, a0)
    if (max_iter >= 1
            and fused_fits(nvars, obs, nrhs, x_t.dtype.itemsize,
                           max_iter=max_iter)):
        # This dispatch decision runs eagerly on every call (jit lives
        # inside fused_solve), so the relay reports the path each solve
        # actually took — the engine pops it via obs.consume_dispatch().
        record_dispatch("fused", method=variant)
        return fused_solve(x_t, y, cn=cn, inv_cn=inv_cn, a0=a0, block=block,
                           max_iter=max_iter, atol=atol, rtol=rtol,
                           omega=omega, variant=variant, interpret=interpret,
                           donate=donate)
    reason = "max_iter" if max_iter < 1 else "vmem"
    record_dispatch("persweep", method=variant, reason=reason)
    return solvebakp_persweep_kernel(
        x_t, y, cn=cn, inv_cn=inv_cn, a0=a0, block=block, max_iter=max_iter,
        atol=atol, rtol=rtol, omega=omega, variant=variant,
        interpret=interpret, donate=donate)


def solvebakp_stream_kernel(
    x_t: jax.Array,
    y: jax.Array,
    *,
    cn: Optional[jax.Array] = None,
    inv_cn: Optional[jax.Array] = None,
    a0: Optional[jax.Array] = None,
    block: int = 256,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    interpret: Optional[bool] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Streaming out-of-core SolveBakP: x stays in HBM (``pltpu.ANY``) and
    tiles double-buffer through a two-slot VMEM scratch while the residual,
    coefficients and convergence state stay on-chip for every sweep
    (``repro.kernels.stream_solve``).  The VMEM working set is two
    (block, obs) x tiles plus the accumulators — independent of vars — so
    designs far over the whole-solve budget keep the fused kernel's
    single-launch, early-exit execution model.  Arguments as
    ``solvebakp_kernel``; falls back to the per-sweep launch loop when even
    the two-tile scratch exceeds the VMEM budget or ``max_iter < 1``.
    """
    nvars, obs = x_t.shape
    _, nrhs, inv_cn = validate_solver_args(x_t, y, cn, inv_cn, a0)
    if (max_iter >= 1
            and stream_fits(nvars, obs, nrhs, x_t.dtype.itemsize,
                            block=block, max_iter=max_iter)):
        record_dispatch("stream", method="bakp")
        return stream_solve(x_t, y, inv_cn=inv_cn, a0=a0, block=block,
                            max_iter=max_iter, atol=atol, rtol=rtol,
                            omega=omega, interpret=interpret, donate=donate)
    reason = "max_iter" if max_iter < 1 else "vmem"
    record_dispatch("persweep", method="bakp", reason=reason)
    return solvebakp_persweep_kernel(
        x_t, y, inv_cn=inv_cn, a0=a0, block=block, max_iter=max_iter,
        atol=atol, rtol=rtol, omega=omega, variant="bakp",
        interpret=interpret, donate=donate)


@functools.partial(jax.jit, static_argnames=("col_block", "obs_tile",
                                             "interpret"))
def score_features_kernel(x_t, e, *, col_block=512, obs_tile=4096,
                          interpret=None):
    """Fused SolveBakF feature scoring (see block_update.score_features)."""
    inv_cn = safe_inv(column_norms_sq_t(x_t))
    return score_features(x_t, e, inv_cn, col_block=col_block,
                          obs_tile=obs_tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("obs_tile", "interpret"))
def block_update_kernel(x_t_blk, e, da, *, obs_tile=4096, interpret=None):
    """Fused rank-CB residual correction (paper Alg. 2 line 9)."""
    return block_update(x_t_blk, e, da, obs_tile=obs_tile,
                        interpret=interpret)
