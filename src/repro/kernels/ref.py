"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here with identical semantics
(same update order, same fp32 accumulation).  Tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle.

Layout convention: the kernels consume ``x_t`` — the TRANSPOSED input matrix
with shape (vars, obs) — so that each of the paper's "columns" is a
contiguous row, which (a) makes the HBM→VMEM stream of a column block
contiguous and (b) puts the sequential-update axis on TPU sublanes where
dynamic indexing is cheap (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ref_cd_sweep(x_t: jax.Array, e: jax.Array, inv_cn: jax.Array):
    """Sequential (Gauss–Seidel) CD sweep over all rows of x_t.

    Args:
      x_t: (vars, obs) transposed input matrix.
      e:   (obs,) residual (fp32), or (k, obs) multi-RHS residuals.
      inv_cn: (vars,) 1/⟨x_j,x_j⟩ (0 for zero columns).
    Returns:
      (da, e'): per-column coefficient increments and updated residual —
      (vars,)/(obs,) for 1D ``e``, (vars, k)/(k, obs) for multi-RHS.
    """
    nvars, obs = x_t.shape
    single = e.ndim == 1
    e2 = e.reshape(1, obs) if single else e
    nrhs = e2.shape[0]

    def step(j, carry):
        da_acc, e = carry
        xj = lax.dynamic_slice_in_dim(x_t, j, 1, axis=0)[0].astype(jnp.float32)
        da = (e @ xj) * inv_cn[j]                     # (k,)
        e = e - da[:, None] * xj[None, :]
        return da_acc.at[j].set(da), e

    da0 = jnp.zeros((nvars, nrhs), jnp.float32)
    da, e_out = lax.fori_loop(0, nvars, step, (da0, e2.astype(jnp.float32)))
    if single:
        return da[:, 0], e_out[0]
    return da, e_out


def ref_bakp_sweep(x_t: jax.Array, e: jax.Array, inv_cn: jax.Array, *,
                   block: int, omega: float = 1.0):
    """Block-Jacobi (SolveBakP) sweep: Gauss–Seidel across blocks of rows of
    x_t, Jacobi within a block.

    Args / returns as ``ref_cd_sweep``; ``vars`` must be a multiple of
    ``block``.
    """
    nvars, obs = x_t.shape
    assert nvars % block == 0, (nvars, block)
    single = e.ndim == 1
    e2 = (e.reshape(1, obs) if single else e).astype(jnp.float32)
    nblocks = nvars // block
    xb = x_t.reshape(nblocks, block, obs)
    invb = inv_cn.reshape(nblocks, block)

    def step(carry, b):
        e = carry                                     # (k, obs)
        xblk = lax.dynamic_index_in_dim(xb, b, 0, keepdims=False)
        xblk = xblk.astype(jnp.float32)
        g = e @ xblk.T                                # (k, block)
        da = omega * g * lax.dynamic_index_in_dim(invb, b, 0,
                                                  keepdims=False)[None, :]
        e = e - da @ xblk
        return e, da

    e_out, da = lax.scan(step, e2, jnp.arange(nblocks))
    da = jnp.moveaxis(da, 2, 1).reshape(nvars, -1)    # (vars, k)
    if single:
        return da[:, 0], e_out[0]
    return da, e_out


def ref_block_update(x_t: jax.Array, e: jax.Array, da: jax.Array):
    """Residual correction e' = e - x_blkᵀ·da  (paper Alg. 2 line 9).

    x_t: (block, obs); e: (obs,) or (k, obs); da: (block,) or (block, k).
    """
    ef = e.astype(jnp.float32)
    daf = da.astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    if ef.ndim == 1:
        return ef - daf @ xf
    return ef - daf.T @ xf


def ref_score_features(x_t: jax.Array, e: jax.Array, inv_cn: jax.Array):
    """SolveBakF scoring: SSE reduction of a single CD step per feature.

    score_j = ⟨x_j, e⟩² / ⟨x_j, x_j⟩   (vars,)
    """
    g = x_t.astype(jnp.float32) @ e.astype(jnp.float32)
    return g * g * inv_cn
