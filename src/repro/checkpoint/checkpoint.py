"""Sharded checkpointing with atomic commit, keep-k GC and elastic restore.

Layout per step:
  <dir>/step_<N>.tmp/       — staging (crash-safe: never half-visible)
  <dir>/step_<N>/
    manifest.json           — pytree structure, shapes, dtypes, specs, extras
    arrays.npz              — one entry per leaf (host-gathered)

Elastic restore: the manifest stores *global* shapes; ``restore`` re-shards
onto whatever mesh/shardings the caller passes, so a checkpoint written on a
16×16 mesh restores onto 2×16×16 (or a debug CPU mesh) unchanged — the
fault-tolerance path for resizing after node loss.

On a real multi-host pod each host writes only its addressable shards and
the manifest lists shard files; the single-process implementation here
host-gathers (this container has one process) but keeps the same API.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extras: Optional[Dict[str, Any]] = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # npz has no bfloat16 support: store a uint16 view, record the logical
    # dtype in the manifest and re-view on restore.
    stored = {k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
              for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)

    # keep-k GC
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, old))
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for elastic re-mesh placement.

    Returns (tree, extras, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = []
    for p, _ in flat_t:
        keys.append("/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                             for q in p))
    if shardings is not None:
        flat_s = jax.tree_util.tree_leaves(shardings)
    leaves = []
    for i, k in enumerate(keys):
        arr = data[k]
        if manifest["leaves"][k]["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want = flat_t[i][1]
        assert tuple(arr.shape) == tuple(want.shape), (k, arr.shape, want.shape)
        if shardings is not None:
            leaves.append(jax.device_put(arr, flat_s[i]))
        else:
            leaves.append(jnp.asarray(arr, dtype=want.dtype))
    tree = treedef.unflatten(leaves)
    return tree, manifest["extras"], step
