import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count at first init).

"""Multi-pod dry-run: lower + compile every (arch × shape-cell) on the
production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --cell train_4k --multi-pod --out results/qwen3_train_mp.json

No full-size tensor is ever allocated: parameters, optimizer state, batch
and cache are ShapeDtypeStructs with NamedShardings attached; the proof of
coherence is that ``jit(step).lower(...).compile()`` succeeds under SPMD
partitioning for 256/512 devices, and ``memory_analysis`` bounds the
per-device HBM.

Outputs JSON: memory analysis, cost analysis, per-collective byte totals
(parsed from the partitioned HLO), derived roofline terms (v5e constants),
MODEL_FLOPS and the useful-flops ratio.
"""
import argparse
import json
import re
import time
from typing import Dict

# v5e constants (per spec)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link (ICI)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s32|u32|s16|u16|"
                       r"s8|u8|pred|s64|u64|c64|c128)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
          "f8e5m2": 1, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8, "c128": 16}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the partitioned HLO.

    Matches lines like ``%all-reduce.5 = f32[256]{0} all-reduce(f32[256]{0}
    %x) ...`` and sums the operand shapes inside the call parens.  Async
    pairs (-start/-done) are counted once via the -start op.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s.startswith("%") and not s.startswith("ROOT"):
            continue
        for kind in _COLLECTIVES:
            # opcode appears right after the "=" result shape
            m = re.search(rf"= [^=]*?\b{kind}(-start)?\(", s)
            if m is None:
                continue
            if f"{kind}-done" in s:
                break
            args = s[m.end():]
            depth = 1
            end = 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            ops = args[:end]
            b = sum(_shape_bytes(mm) for mm in _SHAPE_RE.finditer(ops))
            out[kind] += b
            out["count"] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def run_cell(arch: str, cell_name: str, *, multi_pod: bool,
             overrides: Dict = None, fsdp: bool = True,
             serve_rules: bool = False) -> Dict:
    import jax
    from repro.configs.base import SHAPE_CELLS
    from repro.configs.registry import get
    from repro.distributed.sharding import make_rules
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import abstract_cell_args

    cfg = get(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPE_CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, fsdp=fsdp, serve=serve_rules)
    chips = mesh.devices.size

    fn, args = abstract_cell_args(cfg, cell, mesh, rules)
    # production donation: train re-uses params/opt buffers, decode re-uses
    # the KV cache (halves the apparent cache memory in memory_analysis).
    donate = {"train": (0, 1), "prefill": (2,), "decode": (2,)}[cell.kind]
    t0 = time.time()
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze
    t0 = time.time()
    hc = analyze(hlo)   # trip-count-aware per-device flops/bytes/collectives
    t_analyze = time.time() - t0

    flops = hc["flops"]
    bytes_acc = hc["hbm_bytes"]
    coll = {k.replace("coll_", ""): v for k, v in hc.items()
            if k.startswith("coll_")}
    coll["total"] = hc["coll_total"]
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll["total"] / LINK_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])

    # MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D_new for decode/prefill
    n_active = cfg.n_active_params()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    model_flops = (6.0 if cell.kind == "train" else 2.0) * n_active * tokens
    model_flops_per_chip = model_flops / chips

    out = {
        "arch": arch, "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
            "fits_16gb": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes) < 16e9,
        },
        "hlo_cost": {"flops_per_device": flops,
                     "hbm_bytes_per_device": bytes_acc},
        "xla_cost_analysis_raw": {     # body-once; kept for reference only
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "roofline": terms,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "params_total": cfg.n_params(),
        "params_active": n_active,
        "_hlo_text": hlo,   # persisted as .hlo.gz by main(); not in stdout
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True,
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--serve-rules", action="store_true",
                    help="weight-stationary sharding (serving; §Perf H1)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf levers)")
    args = ap.parse_args()

    overrides = json.loads(args.override) if args.override else None
    res = run_cell(args.arch, args.cell, multi_pod=args.multi_pod,
                   overrides=overrides, fsdp=not args.no_fsdp,
                   serve_rules=args.serve_rules)
    hlo_text = res.pop("_hlo_text", None)
    js = json.dumps(res, indent=1)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
        if hlo_text is not None:
            import gzip
            with gzip.open(args.out.replace(".json", ".hlo.gz"), "wt") as f:
                f.write(hlo_text)


if __name__ == "__main__":
    main()
