"""Batched serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs.registry import get
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models.kvcache import init_cache
    from repro.models.model import init_model, make_smoke_batch

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = make_smoke_batch(cfg, key, batch=args.batch, seq=args.prompt_len)
    batch.pop("labels", None)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    cache = init_cache(cfg, args.batch, max(cfg.max_cache_len,
                                            args.prompt_len + args.gen))
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        toks.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, tok, cache)
        if args.temperature > 0:
            k2 = jax.random.fold_in(key, i)
            tok = jax.random.categorical(
                k2, logits / args.temperature, axis=-1)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        tok = tok.astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    out = np.stack(toks, axis=1)
    print(f"prefill {args.prompt_len} tok x{args.batch}: {t_prefill:.3f}s")
    print(f"decode {args.gen} steps: {t_decode:.3f}s "
          f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("generated ids:\n", out)
    return out


if __name__ == "__main__":
    main()
