"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

Production path: builds the mesh, shards params/optimizer with the logical
rules, runs the jitted train_step with checkpointing, straggler monitoring,
preemption handling and resumable data.  ``--smoke`` runs the reduced config
on the local devices (the CPU e2e path used by the examples/tests);
otherwise the full config is used (requires a real TPU slice).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs.registry import get
    from repro.data import SyntheticLM
    from repro.distributed.fault_tolerance import (CheckpointManager,
                                                   StragglerMonitor)
    from repro.launch.steps import make_train_step
    from repro.models.model import init_model
    from repro.optim import make_optimizer

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    import dataclasses
    cfg = dataclasses.replace(cfg, microbatch=1)

    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    step0 = 0

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    mgr = StragglerMonitor()
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir,
                                 interval_steps=args.ckpt_every)
        ckpt.install_preemption_handler()
        if args.resume and ckpt.latest_step() is not None:
            (state, extras, step0) = ckpt.restore_latest(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            data.skip_to(extras.get("data_step", step0))
            print(f"resumed from step {step0}")

    train_step = jax.jit(make_train_step(
        cfg, peak_lr=args.lr, warmup=20, total_steps=args.steps),
        donate_argnums=(0, 1))

    losses = []
    for step in range(step0, args.steps):
        raw = data.next_batch()
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.family == "vlm":
            s = batch["tokens"].shape[1]
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s)[None, None],
                (3, batch["tokens"].shape[0], s)).astype(jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(key, step),
                (batch["tokens"].shape[0], batch["tokens"].shape[1],
                 cfg.d_model), jnp.float32)
        mgr.step_start()
        params, opt_state, metrics = train_step(
            params, opt_state, batch, jnp.int32(step))
        jax.block_until_ready(metrics["loss"])
        straggler = mgr.step_end()
        losses.append(float(metrics["ce_loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} ce={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}"
                  + (" [straggler]" if straggler else ""), flush=True)
        if ckpt and ckpt.should_save(step):
            ckpt.save(step, {"params": params, "opt": opt_state},
                      extras={"data_step": data.state.step})

    print(f"final: first10={np.mean(losses[:10]):.3f} "
          f"last10={np.mean(losses[-10:]):.3f} "
          f"straggler_summary={mgr.summary()}")
    return losses


if __name__ == "__main__":
    main()
