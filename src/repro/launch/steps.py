"""jit-able train / prefill / decode steps + abstract-state builders.

``make_train_step`` closes over config and sharding context and returns the
pure (params, opt_state, batch, step) → (params', opt_state', metrics)
function; the dry-run lowers it against ShapeDtypeStruct trees so no memory
is ever allocated for the full-size models.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.common import NULL_CTX, ShardCtx
from repro.models.model import (abstract_model, forward_decode,
                                forward_prefill, forward_train, input_specs,
                                model_defs)
from repro.models.kvcache import abstract_cache
from repro.models.params import ParamDef, param_shardings, spec_for
from repro.optim import make_optimizer
from repro.optim.schedule import clip_by_global_norm, cosine_schedule


def _split_microbatches(batch, k: int, ctx):
    """(B, ...) leaves → (k, B/k, ...); M-RoPE positions carry batch on
    axis 1.  Re-constrain so the microbatch axis stays unsharded (the batch
    shards over the data axes within each microbatch)."""
    def split(name, x):
        if name == "positions":          # (3, B, S)
            y = x.reshape((x.shape[0], k, x.shape[1] // k) + x.shape[2:])
            y = jnp.moveaxis(y, 1, 0)    # (k, 3, B/k, S)
            return ctx.constrain(y, None, None, "batch",
                                 *([None] * (y.ndim - 3)))
        y = x.reshape((k, x.shape[0] // k) + x.shape[1:])
        return ctx.constrain(y, None, "batch", *([None] * (y.ndim - 2)))
    return {name: split(name, x) for name, x in batch.items()}


def make_train_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None,
                    *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, clip_norm: float = 1.0):
    ctx = ctx or NULL_CTX
    _, opt_update = make_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch, step):
        if cfg.microbatch > 1:
            # gradient accumulation: scan over k microbatches; activations
            # live for one microbatch at a time (memory lever, §Perf).
            k = cfg.microbatch
            mbs = _split_microbatches(batch, k, ctx)

            def loss_fn(p):
                def body(carry, mb):
                    l, m = forward_train(cfg, p, mb, ctx)
                    return carry + l / k, m
                loss, ms = jax.lax.scan(
                    jax.checkpoint(body), jnp.float32(0.0), mbs)
                return loss, jax.tree_util.tree_map(
                    lambda x: jnp.mean(x, axis=0), ms)
        else:
            def loss_fn(p):
                return forward_train(cfg, p, batch, ctx)

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if ctx.mesh is not None:
            # pin gradient shardings to the parameter shardings so SPMD
            # lowers the data-axis reduction as reduce-scatter into the
            # FSDP shard instead of a full all-reduce (§Perf H7).
            shardings = param_shardings(model_defs(cfg), ctx.mesh, ctx.rules)
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, shardings)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = cosine_schedule(step, peak_lr=peak_lr, warmup_steps=warmup,
                             total_steps=total_steps)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
    ctx = ctx or NULL_CTX

    def prefill_step(params, batch, cache):
        return forward_prefill(cfg, params, batch, cache, ctx)

    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
    ctx = ctx or NULL_CTX

    def decode_step(params, tokens, cache):
        return forward_decode(cfg, params, tokens, cache, ctx)

    return decode_step


# ---------------------------------------------------------------------------
# Abstract optimizer state (for lowering train_step without allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    if mesh is None:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return jax.ShapeDtypeStruct(tuple(shape), dtype,
                                sharding=NamedSharding(mesh, spec))


def abstract_opt_state(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                       rules=None):
    """ShapeDtypeStruct tree matching {adamw,adafactor}_init output, with
    optimizer-state shardings inherited from the parameter logical axes."""
    defs = model_defs(cfg)
    rules = rules or {}
    is_def = lambda x: isinstance(x, ParamDef)

    def full(d: ParamDef):
        return _sds(d.shape, jnp.float32, mesh, spec_for(d, rules))

    count = _sds((), jnp.int32, mesh, P())
    if cfg.optimizer == "adamw":
        t = lambda: jax.tree_util.tree_map(full, defs, is_leaf=is_def)
        return {"m": t(), "v": t(), "master": t(), "count": count}

    def stat(d: ParamDef):
        if len(d.shape) >= 2:
            vr = ParamDef(d.shape[:-1], d.axes[:-1], d.init)
            vc = ParamDef(d.shape[:-2] + d.shape[-1:],
                          d.axes[:-2] + d.axes[-1:], d.init)
            return {"vr": full(vr), "vc": full(vc)}
        return {"v": full(d)}

    return {
        "stats": jax.tree_util.tree_map(stat, defs, is_leaf=is_def),
        "master": jax.tree_util.tree_map(full, defs, is_leaf=is_def),
        "count": count,
    }


def abstract_cell_args(cfg: ModelConfig, cell: ShapeCell,
                       mesh: Optional[Mesh] = None, rules=None):
    """(fn, args) ready for jit(fn).lower(*args) for this cell."""
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX
    params = abstract_model(cfg, mesh, rules)
    batch = input_specs(cfg, cell, mesh, rules)
    if cell.kind == "train":
        fn = make_train_step(cfg, ctx)
        opt = abstract_opt_state(cfg, mesh, rules)
        step = _sds((), jnp.int32, mesh, P())
        return fn, (params, opt, batch, step)
    if cell.kind == "prefill":
        fn = make_prefill_step(cfg, ctx)
        cache = abstract_cache(cfg, cell.global_batch, cell.seq_len, mesh,
                               rules)
        return fn, (params, batch, cache)
    fn = make_decode_step(cfg, ctx)
    cache = abstract_cache(cfg, cell.global_batch, cell.seq_len, mesh, rules)
    return fn, (params, batch["tokens"], cache)
