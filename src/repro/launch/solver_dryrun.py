import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count at first init).

"""Dry-run of the paper's own workload at pod scale: the distributed
SolveBakP on the production mesh, lowered against ShapeDtypeStructs.

    PYTHONPATH=src python -m repro.launch.solver_dryrun \
        --obs 16777216 --vars 16384 --thr 512 --mode gram [--multi-pod] \
        [--sharding obs|2d] [--dtype bfloat16] --out results/solver.json

The system is obs×vars bf16 (default 16M×16k = 512 GiB, 2 GiB/chip on one
pod).  Roofline terms come from the same trip-count-aware HLO analyzer as
the LM cells; sweeps are bounded by --sweeps (the while-loop trip).
"""
import argparse
import functools
import json
import time

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--obs", type=int, default=16_777_216)
    ap.add_argument("--vars", type=int, default=16_384)
    ap.add_argument("--thr", type=int, default=512)
    ap.add_argument("--sweeps", type=int, default=8)
    ap.add_argument("--mode", default="gram", choices=["gram", "jacobi"])
    ap.add_argument("--sharding", default="obs", choices=["obs", "2d"])
    ap.add_argument("--dtype", default="bfloat16",
                    choices=["bfloat16", "float32"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import (solvebakp_2d, solvebakp_obs_sharded)
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    chips = mesh.devices.size
    dt = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32

    if args.sharding == "obs":
        fn = functools.partial(
            solvebakp_obs_sharded, mesh=mesh, data_axes=data_axes + ("model",),
            thr=args.thr, max_iter=args.sweeps, mode=args.mode)
        x_spec = P(data_axes + ("model",), None)
        y_spec = P(data_axes + ("model",))
    else:
        fn = functools.partial(
            solvebakp_2d, mesh=mesh, data_axes=data_axes,
            model_axis="model", thr=args.thr, max_iter=args.sweeps,
            mode=args.mode, omega=0.5)
        x_spec = P(data_axes, "model")
        y_spec = P(data_axes)

    x = jax.ShapeDtypeStruct((args.obs, args.vars), dt,
                             sharding=NamedSharding(mesh, x_spec))
    y = jax.ShapeDtypeStruct((args.obs,), jnp.float32,
                             sharding=NamedSharding(mesh, y_spec))

    t0 = time.time()
    lowered = jax.jit(lambda xx, yy: fn(xx, yy)).lower(x, y)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    hc = analyze(hlo)

    per_sweep = 1.0 / args.sweeps
    # analytic per-sweep terms (the solver's own roofline, DESIGN.md §3)
    bytes_ideal = args.obs * args.vars * (2 if dt == jnp.bfloat16 else 4)
    flops_ideal = 4.0 * args.obs * args.vars
    res = {
        "workload": {"obs": args.obs, "vars": args.vars, "thr": args.thr,
                     "mode": args.mode, "sharding": args.sharding,
                     "dtype": args.dtype, "sweeps": args.sweeps},
        "mesh": "2x16x16" if args.multi_pod else "16x16", "chips": chips,
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "per_sweep": {
            "compute_s": hc["flops"] * per_sweep / PEAK_FLOPS,
            "memory_s": hc["hbm_bytes"] * per_sweep / HBM_BW,
            "collective_s": hc["coll_total"] * per_sweep / LINK_BW,
        },
        "collectives": {k.replace("coll_", ""): v * per_sweep
                        for k, v in hc.items() if k.startswith("coll_")},
        "ideal_per_sweep": {
            "memory_s_per_chip": bytes_ideal / chips / HBM_BW,
            "compute_s_per_chip": flops_ideal / chips / PEAK_FLOPS,
        },
    }
    ps = res["per_sweep"]
    ps["bottleneck"] = max(("compute_s", "memory_s", "collective_s"),
                           key=lambda k: ps[k])
    res["roofline_fraction"] = (res["ideal_per_sweep"]["memory_s_per_chip"]
                                / max(ps["memory_s"], ps["compute_s"],
                                      ps["collective_s"]))
    js = json.dumps(res, indent=1)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)


if __name__ == "__main__":
    main()
