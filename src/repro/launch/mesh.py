"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax device
state.  Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
composes with data for batch/FSDP sharding (repro.distributed.sharding).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for host-device tests (requires matching device count)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
