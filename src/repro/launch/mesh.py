"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax device
state.  Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
composes with data for batch/FSDP sharding (repro.distributed.sharding).

``AxisType`` (explicit-sharding axis modes) only exists on newer jax; on
jax 0.4.x the plain ``jax.make_mesh`` call is equivalent for everything this
repo does (shard_map with explicit specs), so the builders degrade
gracefully instead of Importing-Error the whole distributed test suite.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    try:
        from jax.sharding import AxisType
    except ImportError:  # jax < 0.5: no axis_types kwarg
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for host-device tests (requires matching device count)."""
    return _make_mesh(shape, axes)
