"""Run the full dry-run matrix (every arch × applicable cell × mesh) as
subprocesses (fresh XLA device state per cell) and tabulate the results.

    PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun \
        [--archs a,b] [--cells c1,c2] [--meshes pod,multipod] [-j 2]
"""
from __future__ import annotations

import argparse
import concurrent.futures as cf
import json
import os
import subprocess
import sys
import time


def run_one(arch, cell, multi_pod, outdir, override=None, tag=""):
    suffix = ("_mp" if multi_pod else "_sp") + (f"_{tag}" if tag else "")
    out = os.path.join(outdir, f"{arch}__{cell}{suffix}.json")
    if os.path.exists(out):
        return arch, cell, multi_pod, "cached", 0.0
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--cell", cell, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    if override:
        cmd += ["--override", json.dumps(override)]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd="/root/repo")
    dt = time.time() - t0
    if p.returncode != 0:
        err_path = out.replace(".json", ".err")
        with open(err_path, "w") as f:
            f.write(p.stdout[-4000:] + "\n---\n" + p.stderr[-8000:])
        return arch, cell, multi_pod, f"FAIL({err_path})", dt
    return arch, cell, multi_pod, "ok", dt


def main():
    from repro.configs.registry import all_cells

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--archs", default=None)
    ap.add_argument("--cells", default=None)
    ap.add_argument("--meshes", default="pod,multipod")
    ap.add_argument("-j", type=int, default=2)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    wanted_archs = set(args.archs.split(",")) if args.archs else None
    wanted_cells = set(args.cells.split(",")) if args.cells else None
    meshes = [m == "multipod" for m in args.meshes.split(",")]

    jobs = []
    for arch, cell in all_cells():
        if wanted_archs and arch not in wanted_archs:
            continue
        if wanted_cells and cell.name not in wanted_cells:
            continue
        for mp in meshes:
            jobs.append((arch, cell.name, mp))

    print(f"{len(jobs)} dry-run cells -> {args.out}", flush=True)
    results = []
    with cf.ThreadPoolExecutor(max_workers=args.j) as ex:
        futs = [ex.submit(run_one, a, c, m, args.out) for a, c, m in jobs]
        for f in cf.as_completed(futs):
            a, c, m, status, dt = f.result()
            print(f"[{len(results)+1}/{len(jobs)}] {a:24s} {c:12s} "
                  f"{'mp' if m else 'sp'}  {status:8s} {dt:6.0f}s",
                  flush=True)
            results.append((a, c, m, status))
    bad = [r for r in results if r[3].startswith("FAIL")]
    print(f"done: {len(results) - len(bad)} ok, {len(bad)} failed")
    for r in bad:
        print("  FAILED:", r)


if __name__ == "__main__":
    main()
