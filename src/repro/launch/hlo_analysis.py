"""Trip-count-aware cost extraction from partitioned HLO text.

Why: XLA's ``compiled.cost_analysis()`` visits every ``while`` body ONCE —
a 48-layer ``lax.scan`` reports 1/48th of its real FLOPs (verified in
EXPERIMENTS.md §Dry-run methodology).  Since the whole zoo scans over layers,
roofline terms derived from raw cost_analysis would be off by 30-80×.

This module parses ``compiled.as_text()`` (the partitioned, per-device
module) into computations, walks the callgraph, multiplies ``while`` bodies
by their static trip count (parsed from the loop-condition comparison
constant — every scan emits one), and produces:

  flops      — 2·M·N·K for dots (+1/elem for non-dot instructions as a
               floor estimate of VPU work),
  hbm_bytes  — operand+result bytes at fusion/op boundaries (fusion
               internals live in registers/VMEM),
  collectives— result-shape bytes per collective opcode × trips (with
               group-size scaling for reduce-scatter).

All quantities are PER DEVICE (the module is the SPMD program of one chip).
This is an analytic model, not a profile: precise for dot/collective volume,
a floor for elementwise — exactly what the three-term roofline needs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)$")
_OP_RE = re.compile(r"\s([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "iota", "reshape", "broadcast", "copy-done",
         "partition-id", "replica-id", "opt-barrier", "custom-call"}


def _shape_info(text: str) -> Tuple[int, List[int]]:
    """(total bytes, dims of the first array shape) from a shape string."""
    total = 0
    first_dims: Optional[List[int]] = None
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) \
            else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[m.group(1)]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_dims: List[int]
    operands: List[str]
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.hbm_bytes * t,
                    {k: v * t for k, v in self.coll.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self._parse(hlo_text)
        self.entry = self._entry_name
        self._memo: Dict[str, Cost] = {}
        self._trip_memo: Dict[str, int] = {}

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[str] = None
        self._entry_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if not s:
                continue
            if s.endswith("{") and ("(" in s) and ("->" in s or "ENTRY" in s):
                # computation header: `%name (args) -> type {` or `ENTRY ...`
                m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if s.startswith("ENTRY"):
                        self._entry_name = cur
                continue
            if s == "}" or s.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            rest = mi.group("rest").strip()
            # split off the result shape: either a tuple `( ... )` or a
            # single `dtype[dims]{layout}` token.
            if rest.startswith("("):
                depth = 0
                shape_end = -1
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            shape_end = i + 1
                            break
                if shape_end < 0:
                    continue
                shape_str, remainder = rest[:shape_end], rest[shape_end:]
            else:
                sp = rest.find(" ")
                if sp < 0:
                    continue
                shape_str, remainder = rest[:sp], rest[sp:]
            mop = re.match(r"\s*([a-z][\w\-]*)\(", remainder)
            if not mop:
                continue
            opcode = mop.group(1)
            rbytes, rdims = _shape_info(shape_str)
            args = remainder[mop.end():]
            # cut operand list at closing paren of the call
            depth, end = 1, len(args)
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(args[:end])
            self.computations[cur].append(
                Instr(mi.group("name"), opcode, rbytes, rdims, operands,
                      line))

    # -- helpers -------------------------------------------------------------
    def _symtab(self, comp: str) -> Dict[str, Instr]:
        return {i.name: i for i in self.computations.get(comp, [])}

    def _called(self, instr: Instr) -> List[str]:
        names = []
        for m in _CALL_ATTR_RE.finditer(instr.line):
            names.append(m.group(1))
        # branch_computations={%a, %b}
        mb = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
        if mb:
            names = [n.strip().lstrip("%") for n in mb.group(1).split(",")]
            names = [n for n in names if n]
        return [n for n in names if n in self.computations]

    def _trip_count(self, cond_comp: str) -> int:
        """Static trip count from the scan/while condition.

        lax.scan conditions compare the induction variable against a
        constant (`compare(gte, constant(L)), direction=LT`), but XLA often
        wraps the compare in a kLoop fusion, so we take the max integer
        constant reachable from the condition computation (including its
        fused calls).  Dynamic-bound while loops (tolerance-based solver
        loops) have no such constant and conservatively count as 1 trip.
        """
        if cond_comp in self._trip_memo:
            return self._trip_memo[cond_comp]
        trip = 1

        def scan_comp(name):
            nonlocal trip
            for i in self.computations.get(name, []):
                if i.opcode == "constant":
                    m = re.search(r"constant\((\d+)\)", i.line)
                    if m:
                        trip = max(trip, int(m.group(1)))
                elif i.opcode == "fusion":
                    for c in self._called(i):
                        scan_comp(c)

        scan_comp(cond_comp)
        self._trip_memo[cond_comp] = max(trip, 1)
        return self._trip_memo[cond_comp]

    def _dot_flops(self, instr: Instr, symtab) -> float:
        out = 1
        for d in instr.result_dims:
            out *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
        k = 1
        if m and instr.operands:
            lhs = symtab.get(instr.operands[0])
            if lhs is not None:
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(lhs.result_dims):
                        k *= lhs.result_dims[idx]
        return 2.0 * out * k

    def _operand_bytes(self, instr: Instr, symtab) -> int:
        b = 0
        for op in instr.operands:
            src = symtab.get(op)
            if src is not None:
                b += src.result_bytes
        return b

    def _fusion_footprint(self, instr: Instr, symtab) -> float:
        """HBM bytes touched by a fusion: operands count at their *access
        footprint* — a parameter consumed only through dynamic-slice ops
        contributes the slice bytes (scan reading one layer of a stacked
        buffer), and a dynamic-update-slice root writes only the update
        (in-place stack append), not the whole buffer."""
        called = self._called(instr)
        comp = next((c for c in called if c in self.computations), None)
        if comp is None:
            return instr.result_bytes + self._operand_bytes(instr, symtab)
        instrs = self.computations[comp]
        fsym = {i.name: i for i in instrs}
        outer_bytes = []
        for op in instr.operands:
            src = symtab.get(op)
            outer_bytes.append(src.result_bytes if src else 0)
        root = next((j for j in instrs if "ROOT" in j.line),
                    instrs[-1] if instrs else None)

        passthrough = {"bitcast", "reshape", "copy", "transpose"}

        def effective_consumers(name, seen=None):
            """Transitive consumers through passthrough ops."""
            seen = seen or set()
            out = []
            for j in instrs:
                if name in j.operands and j.name not in seen:
                    seen.add(j.name)
                    if j.opcode in passthrough:
                        out.extend(effective_consumers(j.name, seen))
                    else:
                        out.append(j)
            return out

        def feeds_inplace_dest(param_name, j):
            """True if j is the root DUS/scatter and the param reaches its
            operand 0 (the aliased destination buffer)."""
            if j is not root or j.opcode not in ("dynamic-update-slice",
                                                 "scatter"):
                return False
            dest = j.operands[0] if j.operands else None
            cur = dest
            while cur is not None:
                if cur == param_name:
                    return True
                src = fsym.get(cur)
                if src is None or src.opcode not in passthrough:
                    return False
                cur = src.operands[0] if src.operands else None
            return False

        total = 0.0
        for i in instrs:
            if i.opcode != "parameter":
                continue
            m = re.search(r"parameter\((\d+)\)", i.line)
            idx = int(m.group(1)) if m else 0
            cons = effective_consumers(i.name)
            if cons and all(
                    c.opcode in ("dynamic-slice", "gather")
                    or feeds_inplace_dest(i.name, c) for c in cons):
                total += sum(c.result_bytes for c in cons
                             if c.opcode in ("dynamic-slice", "gather"))
            else:
                total += outer_bytes[idx] if idx < len(outer_bytes) else 0

        if root is not None and root.opcode == "dynamic-update-slice":
            upd = fsym.get(root.operands[1]) if len(root.operands) > 1 \
                else None
            total += 2 * (upd.result_bytes if upd else 0)
        elif root is not None and root.opcode == "scatter":
            upd = fsym.get(root.operands[-1]) if root.operands else None
            total += 2 * (upd.result_bytes if upd else instr.result_bytes)
        else:
            total += instr.result_bytes if root is None else \
                root.result_bytes if root.opcode not in passthrough else \
                instr.result_bytes
        return total

    # -- cost walk -------------------------------------------------------------
    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # cycle guard
        symtab = self._symtab(comp)
        total = Cost()
        for i in self.computations.get(comp, []):
            op = i.opcode
            if op in _SKIP:
                continue
            if op == "while":
                body = cond = None
                mc = re.search(r"condition=%?([\w.\-]+)", i.line)
                mb = re.search(r"body=%?([\w.\-]+)", i.line)
                cond = mc.group(1) if mc else None
                body = mb.group(1) if mb else None
                trip = self._trip_count(cond) if cond else 1
                inner = Cost()
                if body in self.computations:
                    inner += self.cost_of(body)
                if cond in self.computations:
                    inner += self.cost_of(cond)
                total += inner.scaled(trip)
            elif op == "fusion":
                sub = Cost()
                for c in self._called(i):
                    sub += self.cost_of(c)
                total.flops += sub.flops
                for k in total.coll:
                    total.coll[k] += sub.coll[k]
                total.hbm_bytes += self._fusion_footprint(i, symtab)
            elif op in ("call", "async-start"):
                for c in self._called(i):
                    total += self.cost_of(c)
            elif op == "conditional":
                branches = [self.cost_of(c) for c in self._called(i)]
                if branches:
                    best = max(branches, key=lambda c: c.flops + c.hbm_bytes)
                    total += best
            elif op == "dot":
                total.flops += self._dot_flops(i, symtab)
                total.hbm_bytes += i.result_bytes + \
                    self._operand_bytes(i, symtab)
            elif op == "convolution":
                # rough: 2 * out_elems * (kernel elems / out channels)
                out = 1
                for d in i.result_dims:
                    out *= d
                total.flops += 2.0 * out
                total.hbm_bytes += i.result_bytes + \
                    self._operand_bytes(i, symtab)
            elif op in ("dynamic-slice", "gather"):
                total.flops += 0.0
                total.hbm_bytes += 2 * i.result_bytes  # slice read + write
            elif op == "dynamic-update-slice":
                upd = symtab.get(i.operands[1]) if len(i.operands) > 1 \
                    else None
                total.hbm_bytes += 2 * (upd.result_bytes if upd
                                        else i.result_bytes)
            elif op == "scatter":
                upd = symtab.get(i.operands[-1]) if i.operands else None
                total.hbm_bytes += 2 * (upd.result_bytes if upd
                                        else i.result_bytes)
            else:
                base = op.replace("-start", "")
                if base in _COLLECTIVES:
                    if op.endswith("-done"):
                        continue
                    b = float(i.result_bytes)
                    if base == "reduce-scatter":
                        mg = re.search(r"replica_groups=\[(\d+),(\d+)\]",
                                       i.line)
                        g = int(mg.group(2)) if mg else 1
                        b *= g
                    total.coll[base] += b
                    total.hbm_bytes += i.result_bytes
                    continue
                out = 1
                for d in i.result_dims:
                    out *= d
                total.flops += out  # 1 flop/elem floor
                total.hbm_bytes += i.result_bytes + \
                    self._operand_bytes(i, symtab)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Dict[str, float]:
    cm = HloCostModel(hlo_text)
    c = cm.entry_cost()
    out = {"flops": c.flops, "hbm_bytes": c.hbm_bytes}
    out.update({f"coll_{k}": v for k, v in c.coll.items()})
    out["coll_total"] = sum(c.coll.values())
    return out
