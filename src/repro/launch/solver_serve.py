"""Solver-serving driver: pump a synthetic multi-tenant request stream
through ``repro.serve.SolverServeEngine`` and report throughput.

    PYTHONPATH=src python -m repro.launch.solver_serve \
        --requests 256 --obs 2048 --vars 256 --designs 8 \
        --method bakp_gram --flush-every 32

``--designs D`` controls design-matrix reuse: requests cycle over D distinct
matrices, so every flush window sees same-design groups (coalesced into
multi-RHS solves) and, across windows, warm design-cache hits.  ``--designs``
equal to ``--requests`` gives a worst-case all-unique stream (pure vmap
batching); ``--designs 1`` gives the best case (everything rides one
multi-RHS solve).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def build_requests(rng, xs, n, method, max_iter, rtol, thr, noise=0.0):
    """Requests cycling over the shared design matrices ``xs``.

    ``design_key`` is trusted identity — it must only be reused for the SAME
    matrix, which is why the designs are drawn once and shared between the
    warmup and the timed stream.
    """
    from repro.serve import SolveRequest

    designs = len(xs)
    nvars = xs[0].shape[1]
    reqs = []
    for i in range(n):
        d = i % designs
        a = rng.normal(size=(nvars,)).astype(np.float32)
        y = xs[d] @ a
        if noise:
            y = y + noise * rng.normal(size=y.shape[0]).astype(np.float32)
        reqs.append(SolveRequest(
            x=xs[d], y=y, method=method, max_iter=max_iter, rtol=rtol,
            thr=thr, design_key=f"design-{d}", request_id=f"req-{i}"))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--obs", type=int, default=2048)
    ap.add_argument("--vars", type=int, default=256)
    ap.add_argument("--designs", type=int, default=8)
    ap.add_argument("--method", default="bakp_gram",
                    choices=["bak", "bakp", "bakp_gram", "lstsq", "normal"])
    ap.add_argument("--max-iter", type=int, default=40)
    ap.add_argument("--rtol", type=float, default=1e-10)
    ap.add_argument("--thr", type=int, default=128)
    ap.add_argument("--flush-every", type=int, default=32,
                    help="requests per flush window (batching horizon)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify every request vs numpy lstsq (slow)")
    args = ap.parse_args()

    from repro.serve import ServeConfig, SolverServeEngine

    rng = np.random.default_rng(args.seed)
    engine = SolverServeEngine(ServeConfig())
    xs = [rng.normal(size=(args.obs, args.vars)).astype(np.float32)
          for _ in range(args.designs)]
    reqs = build_requests(rng, xs, args.requests, args.method, args.max_iter,
                          args.rtol, args.thr)

    # Warmup: compile every (bucket, k, B) program this stream will need.
    warm = build_requests(rng, xs, min(args.flush_every, args.requests),
                          args.method, args.max_iter, args.rtol, args.thr)
    engine.serve(warm)

    results = []
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), args.flush_every):
        for r in reqs[lo:lo + args.flush_every]:
            engine.submit(r)
        results.extend(engine.flush())
    wall = time.perf_counter() - t0

    lat = np.array([r.latency_s for r in results])
    kinds = {k: sum(r.batch_kind == k for r in results)
             for k in ("multi_rhs", "vmap", "single")}
    print(f"served {len(results)} requests in {wall:.3f}s "
          f"-> {len(results)/wall:.1f} solves/s")
    print(f"latency p50={np.percentile(lat, 50)*1e3:.2f}ms "
          f"p95={np.percentile(lat, 95)*1e3:.2f}ms "
          f"max={lat.max()*1e3:.2f}ms (batch wall time per request)")
    print(f"batch mix: {kinds}")
    s = engine.stats
    print(f"solver calls: {s.solver_calls} "
          f"(multi_rhs groups={s.multi_rhs_groups} "
          f"covering {s.multi_rhs_requests} reqs; "
          f"vmap batches={s.vmap_batches} covering {s.vmap_requests} reqs; "
          f"singles={s.single_solves})")
    c = engine.cache.stats
    print(f"design cache: {c.hits} hits / {c.misses} misses "
          f"(hit rate {c.hit_rate:.1%}), {len(engine.cache)} resident")

    if args.check:
        mapes = []
        for r, q in zip(results, reqs):
            ref = np.linalg.lstsq(np.asarray(q.x, np.float64),
                                  np.asarray(q.y, np.float64), rcond=None)[0]
            denom = np.maximum(np.abs(ref), 1e-12)
            mapes.append(float(np.mean(np.abs(r.coef - ref) / denom)))
        print(f"MAPE vs lstsq: mean={np.mean(mapes):.2e} "
              f"worst={np.max(mapes):.2e}")


if __name__ == "__main__":
    main()
