"""Solver-serving driver: pump a synthetic multi-tenant request stream
through ``repro.serve`` and report throughput.

Synchronous windows (the original engine-level driver):

    PYTHONPATH=src python -m repro.launch.solver_serve \
        --requests 256 --obs 2048 --vars 256 --designs 8 \
        --method bakp_gram --flush-every 32

Async deadline-aware dispatch (Poisson arrivals through AsyncDispatcher):

    PYTHONPATH=src python -m repro.launch.solver_serve --mode async \
        --requests 256 --rate 200 --deadline-ms 500 --max-batch 16 \
        --tenants 32

Fused-megakernel serving (whole solves on one Pallas launch; oversized
designs fall back to the XLA path automatically):

    PYTHONPATH=src python -m repro.launch.solver_serve \
        --method bakp_fused --requests 256 --designs 8
    # or upgrade eligible 'bakp' requests in place:
    PYTHONPATH=src python -m repro.launch.solver_serve \
        --method bakp --prefer-fused

Mesh-sharded placement (route big buckets / giant same-design groups onto
the sharded SolveBakP backends; on CPU this forces virtual host devices
before jax loads, so it must be a fresh process):

    PYTHONPATH=src python -m repro.launch.solver_serve --mesh 4x2 \
        --requests 256 --obs 2048 --vars 256 --designs 4 \
        --shard-min-cells 65536 --rhs-shard-min-k 32

``--designs D`` controls design-matrix reuse: requests cycle over D distinct
matrices, so every flush window sees same-design groups (coalesced into
multi-RHS solves) and, across windows, warm design-cache hits.  ``--designs``
equal to ``--requests`` gives a worst-case all-unique stream (pure vmap
batching); ``--designs 1`` gives the best case (everything rides one
multi-RHS solve).  ``--tenants T`` tags requests with recurring tenant ids,
so repeated (design, tenant) pairs warm-start from their previous
coefficients; in async mode each request also carries a deadline and the
driver reports the deadline hit rate.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro import obs


def ensure_mesh_devices(spec: str) -> None:
    """Force enough virtual CPU devices for ``spec`` BEFORE jax imports.

    XLA reads ``--xla_force_host_platform_device_count`` at backend init, so
    this only works from a fresh process that has not touched jax yet — which
    is why the driver defers every ``repro.serve`` import into ``main``.  On
    a real accelerator platform (JAX_PLATFORMS set to tpu/gpu) the flag is
    left alone: the mesh uses the physical devices.
    """
    platforms = os.environ.get("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "cpu" in platforms and "xla_force_host_platform_device_count" not in flags:
        n = 1  # inline product: importing repro.serve here would pull in jax
        for part in spec.lower().split("x"):
            n *= int(part)
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def build_requests(rng, xs, n, method, max_iter, rtol, thr, noise=0.0,
                   tenants=0, deadline_s=None, precision="fp32",
                   refine_sweeps=None):
    """Requests cycling over the shared design matrices ``xs``.

    ``design_key`` is trusted identity — it must only be reused for the SAME
    matrix, which is why the designs are drawn once and shared between the
    warmup and the timed stream.
    """
    from repro.serve import SolveRequest, SolverSpec

    kw = {} if refine_sweeps is None else {"refine_sweeps": refine_sweeps}
    spec = SolverSpec(method=method, max_iter=max_iter, rtol=rtol, thr=thr,
                      precision=precision, **kw)
    designs = len(xs)
    nvars = xs[0].shape[1]
    reqs = []
    for i in range(n):
        d = i % designs
        a = rng.normal(size=(nvars,)).astype(np.float32)
        y = xs[d] @ a
        if noise:
            y = y + noise * rng.normal(size=y.shape[0]).astype(np.float32)
        reqs.append(SolveRequest(
            x=xs[d], y=y, spec=spec,
            design_key=f"design-{d}", request_id=f"req-{i}",
            tenant_id=f"tenant-{i % tenants}" if tenants else None,
            deadline_s=deadline_s))
    return reqs


def report_engine(engine):
    s = engine.stats
    print(f"solver calls: {s.solver_calls} "
          f"(multi_rhs groups={s.multi_rhs_groups} "
          f"covering {s.multi_rhs_requests} reqs; "
          f"vmap batches={s.vmap_batches} covering {s.vmap_requests} reqs; "
          f"singles={s.single_solves}; warm starts={s.warm_starts}; "
          f"failures={s.failures}; sharded={s.sharded_solves})")
    c = engine.cache.stats
    print(f"design cache: {c.hits} hits / {c.misses} misses "
          f"(hit rate {c.hit_rate:.1%}), {len(engine.cache)} resident")
    lanes = engine.lanes.stats()
    if lanes:
        mix = "; ".join(
            f"{label}: {ls['batches']} batches/{ls['requests']} reqs "
            f"busy {ls['busy_s']*1e3:.0f}ms"
            for label, ls in sorted(lanes.items()))
        print(f"execution lanes: {mix}")
    if engine.mesh is not None:
        print(f"mesh: {engine.mesh.describe()}")


def run_sync(args, engine, reqs):
    results = []
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), args.flush_every):
        for r in reqs[lo:lo + args.flush_every]:
            engine.submit(r)
        results.extend(engine.flush())
    wall = time.perf_counter() - t0

    lat = np.array([r.latency_s for r in results])
    kinds = {k: sum(r.batch_kind == k for r in results)
             for k in ("multi_rhs", "vmap", "single", "error")}
    placements = {}
    for r in results:
        placements[r.placement] = placements.get(r.placement, 0) + 1
    print(f"served {len(results)} requests in {wall:.3f}s "
          f"-> {len(results)/wall:.1f} solves/s")
    print(f"latency p50={np.percentile(lat, 50)*1e3:.2f}ms "
          f"p95={np.percentile(lat, 95)*1e3:.2f}ms "
          f"max={lat.max()*1e3:.2f}ms (batch wall time per request)")
    print(f"batch mix: {kinds}")
    print(f"placement mix: {placements}")
    report_engine(engine)
    return reqs, results


def run_async(args, engine, reqs):
    """Poisson arrival stream through the deadline-aware dispatcher."""
    from repro.serve import AsyncDispatcher, DispatchConfig

    rng = np.random.default_rng(args.seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=len(reqs)))
    deadline_s = args.deadline_ms / 1e3
    cfg = DispatchConfig(
        max_queue=args.max_queue,
        backpressure=args.backpressure,
        max_batch=args.max_batch,
        deadline_margin_s=args.deadline_margin_ms / 1e3,
        idle_timeout_s=args.idle_timeout_ms / 1e3,
        default_deadline_s=deadline_s,
    )
    tickets = []
    rejected = 0
    with AsyncDispatcher(engine, cfg) as disp:
        t0 = time.perf_counter()
        base = obs.now()  # same clock as every SolveTicket timestamp
        for i, req in enumerate(reqs):
            now = time.perf_counter() - t0
            if arrivals[i] > now:
                time.sleep(arrivals[i] - now)
            try:
                tickets.append((i, disp.submit(req)))
            except Exception:  # QueueFullError under "reject"
                rejected += 1
        disp.drain()
        wall = time.perf_counter() - t0
        results = [t.result(timeout=60.0) for _, t in tickets]
        stats = disp.stats

    lat = np.array([t.completed_at - base - arrivals[i]
                    for i, t in tickets])
    misses = sum(t.deadline_met is False for _, t in tickets)
    served = len(tickets)
    print(f"served {served}/{len(reqs)} requests in {wall:.3f}s "
          f"-> {served/wall:.1f} solves/s "
          f"(arrival rate {args.rate:.0f}/s, {rejected} rejected)")
    print(f"request latency p50={np.percentile(lat, 50)*1e3:.2f}ms "
          f"p95={np.percentile(lat, 95)*1e3:.2f}ms "
          f"max={lat.max()*1e3:.2f}ms (arrival -> completion)")
    print(f"deadlines: {misses} missed / {served} "
          f"(hit rate {1 - misses/served:.1%} at "
          f"{args.deadline_ms:.0f}ms)")
    print(f"batches fired: full={stats.fired_full} "
          f"deadline={stats.fired_deadline} idle={stats.fired_idle} "
          f"drain={stats.fired_drain}; max inflight={stats.max_inflight}")
    report_engine(engine)
    # Pair results with the requests actually accepted: under "reject"
    # backpressure some submissions never got a ticket, and --check must
    # not verify a solve against a shifted request's system.
    return [reqs[i] for i, _ in tickets], results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["sync", "async"], default="sync")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--obs", type=int, default=2048)
    ap.add_argument("--vars", type=int, default=256)
    ap.add_argument("--designs", type=int, default=8)
    ap.add_argument("--method", default="bakp_gram",
                    help="solver method; any name in the core method "
                         "registry (repro.core.method_names()) — validated "
                         "after jax loads so --mesh device forcing works")
    ap.add_argument("--max-iter", type=int, default=40)
    ap.add_argument("--rtol", type=float, default=1e-10)
    ap.add_argument("--thr", type=int, default=128)
    ap.add_argument("--flush-every", type=int, default=32,
                    help="sync mode: requests per flush window")
    ap.add_argument("--tenants", type=int, default=0,
                    help="recurring tenant ids (0 = off; enables warm starts)")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "bf16_fp32acc"],
                    help="X-stream storage precision (SolverSpec.precision): "
                         "bf16 halves HBM traffic with fp32 accumulators; "
                         "bf16_fp32acc adds fp32 polish sweeps recovering "
                         "full precision.  Methods without bf16 support are "
                         "downgraded to fp32 by the engine (counted in "
                         "solver_fallback_total{reason='precision'})")
    ap.add_argument("--refine-sweeps", type=int, default=None,
                    help="fp32 polish-sweep cap for --precision "
                         "bf16_fp32acc (default: SolverSpec's)")
    ap.add_argument("--prefer-fused", action="store_true",
                    help="upgrade 'bakp' requests to the fused whole-solve "
                         "Pallas megakernel (method 'bakp_fused') when the "
                         "bucket fits VMEM; request --method bakp_fused "
                         "directly to force it for all sizes (oversized "
                         "designs fall back to the XLA path)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="route big buckets onto a device mesh, e.g. '8' or "
                         "'4x2' (data[xmodel]); on CPU forces that many "
                         "virtual host devices")
    ap.add_argument("--shard-min-cells", type=int, default=None,
                    help="bucket obs_p*vars_p at which solves go obs-sharded "
                         "(default: PlacementPolicy's 2^21)")
    ap.add_argument("--rhs-shard-min-k", type=int, default=32,
                    help="same-design group size at which the k axis shards "
                         "across data devices")
    ap.add_argument("--no-lanes", action="store_true",
                    help="disable per-placement execution lanes: run every "
                         "batch on one serial executor thread (the pre-lane "
                         "architecture; results are bit-identical)")
    ap.add_argument("--store-device-bytes", type=int, default=None,
                    help="device-tier byte budget for the tiered design "
                         "store (repro.store): eviction demotes designs to "
                         "host RAM/disk instead of deleting them, and "
                         "over-budget designs serve via the streaming "
                         "'bakp_stream' method.  Unset (with the other "
                         "--store-* flags) = plain LRU cache, bit-identical "
                         "behaviour")
    ap.add_argument("--store-host-bytes", type=int, default=None,
                    help="host-tier byte budget; overflow spills LRU host "
                         "snapshots to --store-dir (or drops X bytes, "
                         "keeping warm/Cholesky state, when unset)")
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="disk-tier directory for memmapped design tile "
                         "files (unset = no disk tier)")
    ap.add_argument("--fault-plan", default=None, metavar="JSON",
                    help="chaos harness (repro.resilience): inline JSON or "
                         "a path to a JSON file mapping fault sites to "
                         "rules, e.g. '{\"solver.raise\": {\"count\": 3}}'. "
                         "Sites: lane.worker, lane.delay, solver.raise, "
                         "solver.diverge, store.tile_corrupt, "
                         "store.read_delay.  Unset = injection disarmed "
                         "(zero-cost, bit-identical)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify every request vs numpy lstsq (slow)")
    # async-mode knobs
    ap.add_argument("--rate", type=float, default=200.0,
                    help="async: Poisson arrival rate (requests/s)")
    ap.add_argument("--deadline-ms", type=float, default=500.0)
    ap.add_argument("--deadline-margin-ms", type=float, default=100.0)
    ap.add_argument("--idle-timeout-ms", type=float, default=20.0)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-queue", type=int, default=1024)
    ap.add_argument("--backpressure", choices=["reject", "block"],
                    default="block")
    # observability (repro.obs)
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the final metrics-registry snapshot (solve "
                         "counts, per-kernel-path latency histograms, cache "
                         "hit/miss, deadline hit rate, ...) to PATH as JSON")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus /metrics (+ /metrics.json, "
                         "/healthz) on this port for the run's duration "
                         "(0 = ephemeral; the resolved port is printed)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="capture a jax profiler trace of the run into DIR "
                         "(view in TensorBoard/Perfetto; flushes and solver "
                         "calls appear as named obs.profile_region blocks)")
    args = ap.parse_args()

    if args.mesh:
        ensure_mesh_devices(args.mesh)  # must precede any jax import

    from repro.core import method_names
    from repro.serve import (PlacementPolicy, ServeConfig, SolverServeEngine,
                             build_serve_mesh)

    if args.method not in method_names():
        raise SystemExit(
            f"--method must be one of {method_names()}, got {args.method!r}")
    rng = np.random.default_rng(args.seed)
    smesh = build_serve_mesh(args.mesh) if args.mesh else None
    policy = None
    if args.mesh:
        defaults = PlacementPolicy()
        policy = PlacementPolicy(
            obs_shard_min_cells=(args.shard_min_cells
                                 if args.shard_min_cells is not None
                                 else defaults.obs_shard_min_cells),
            rhs_shard_min_k=args.rhs_shard_min_k)
    engine = SolverServeEngine(
        ServeConfig(placement_policy=policy,
                    prefer_fused=args.prefer_fused,
                    lane_execution=not args.no_lanes,
                    precision=(args.precision if args.precision != "fp32"
                               else None),
                    store_device_bytes=args.store_device_bytes,
                    store_host_bytes=args.store_host_bytes,
                    store_dir=args.store_dir,
                    fault_plan=args.fault_plan),
        mesh=smesh)
    xs = [rng.normal(size=(args.obs, args.vars)).astype(np.float32)
          for _ in range(args.designs)]
    req_kw = dict(tenants=args.tenants, precision=args.precision,
                  refine_sweeps=args.refine_sweeps)
    reqs = build_requests(rng, xs, args.requests, args.method, args.max_iter,
                          args.rtol, args.thr,
                          deadline_s=(args.deadline_ms / 1e3
                                      if args.mode == "async" else None),
                          **req_kw)

    # Warmup: compile every (bucket, k, B) program this stream will need.
    # Async batch compositions vary with arrival timing, so warm a range of
    # window sizes (1, 2, 4, ... max_batch), not just one; with tenants the
    # warm-start (a0) program variants are separate jit signatures, so each
    # size runs twice — the second pass warm-starts off the first.
    if args.mode == "sync":
        warm_sizes = [min(args.flush_every, args.requests)]
    else:
        warm_sizes = sorted({1, 2, 4, args.max_batch, args.designs,
                             2 * args.designs})
    for n in warm_sizes:
        for _ in range(2 if args.tenants else 1):
            engine.serve(build_requests(
                rng, xs, min(n, args.requests), args.method, args.max_iter,
                args.rtol, args.thr, **req_kw))

    server = None
    if args.metrics_port is not None:
        server = obs.start_metrics_server(args.metrics_port,
                                          registry=engine.registry)
        print(f"metrics: http://localhost:{server.port}/metrics")
    if args.trace_dir:
        obs.start_profiling(args.trace_dir)

    try:
        if args.mode == "sync":
            served_reqs, results = run_sync(args, engine, reqs)
        else:
            served_reqs, results = run_async(args, engine, reqs)
    finally:
        if args.trace_dir:
            obs.stop_profiling()
            print(f"profiler trace written to {args.trace_dir}")
        if args.metrics_json:
            obs.write_metrics_json(
                args.metrics_json, registry=engine.registry,
                extra={"mode": args.mode, "method": args.method,
                       "requests": args.requests, "obs": args.obs,
                       "vars": args.vars, "designs": args.designs,
                       "mesh": args.mesh})
            print(f"metrics snapshot written to {args.metrics_json}")
        if server is not None:
            server.close()

    lat_h = engine.registry.get("serve_solve_latency_seconds")
    if lat_h is not None and lat_h.count():
        print("solver-call latency (registry): "
              f"p50={lat_h.percentile(50)*1e3:.2f}ms "
              f"p95={lat_h.percentile(95)*1e3:.2f}ms "
              f"p99={lat_h.percentile(99)*1e3:.2f}ms "
              f"over {lat_h.count()} calls")

    if args.check:
        mapes = []
        for r, q in zip(results, served_reqs):
            ref = np.linalg.lstsq(np.asarray(q.x, np.float64),
                                  np.asarray(q.y, np.float64), rcond=None)[0]
            denom = np.maximum(np.abs(ref), 1e-12)
            mapes.append(float(np.mean(np.abs(r.coef - ref) / denom)))
        print(f"MAPE vs lstsq: mean={np.mean(mapes):.2e} "
              f"worst={np.max(mapes):.2e}")


if __name__ == "__main__":
    main()
