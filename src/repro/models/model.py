"""Top-level model API: parameter tree, train/prefill/decode forwards, and
the ShapeDtypeStruct ``input_specs`` consumed by the multi-pod dry-run.

Batch layouts per family:
  dense/moe/ssm/hybrid : {"tokens": (B,S) int32, "labels": (B,S) int32}
  vlm                  : + {"positions": (3,B,S) int32} (M-RoPE streams);
                         tokens are text ids, the patch frontend is stubbed
                         as extra embedded positions — the backbone is real.
  encdec               : {"frames": (B,S_src,d) float} (stub frontend)
                         + {"tokens"/"labels": (B,S_tgt)}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec as encdec_lib
from repro.models.common import (NULL_CTX, embed_tokens, embedding_defs,
                                 rmsnorm, rmsnorm_def, softmax_xent, unembed)
from repro.models.kvcache import abstract_cache, cache_spec_tree
from repro.models.params import abstract_params, init_params
from repro.models.transformer import backbone_defs, run_backbone


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "embed": embedding_defs(cfg.padded_vocab, cfg.d_model,
                                cfg.tie_embeddings),
        "final_ln": rmsnorm_def(cfg.d_model),
    }
    if cfg.family == "encdec":
        defs["backbone"] = encdec_lib.encdec_defs(cfg)
    else:
        defs["backbone"] = backbone_defs(cfg)
    return defs


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _positions(cfg, batch, start, s):
    pos = jnp.broadcast_to(start[:, None] + jnp.arange(s)[None], (batch, s))
    return pos


def _embed_inputs(cfg, params, batch_inputs, ctx):
    dt = _dtype(cfg)
    x = embed_tokens(params["embed"], batch_inputs["tokens"], dt)
    return ctx.constrain(x, "batch", None, None)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward_train(cfg, params, batch, ctx=NULL_CTX):
    """Returns (loss, metrics)."""
    if cfg.family == "encdec":
        enc_out = encdec_lib.run_encoder(cfg, params["backbone"],
                                         batch["frames"].astype(_dtype(cfg)),
                                         ctx)
        b, s = batch["tokens"].shape
        x = _embed_inputs(cfg, params, batch, ctx)
        pos = _positions(cfg, b, jnp.zeros((b,), jnp.int32), s)
        x, _ = encdec_lib.run_decoder(cfg, params["backbone"], x, enc_out,
                                      mode="train", positions=pos, ctx=ctx)
        aux = {}
    else:
        b, s = batch["tokens"].shape
        x = _embed_inputs(cfg, params, batch, ctx)
        if cfg.family == "vlm":
            pos = batch["positions"]          # (3, B, S) M-RoPE streams
        else:
            pos = _positions(cfg, b, jnp.zeros((b,), jnp.int32), s)
        x, _, aux = run_backbone(cfg, params["backbone"], x, mode="train",
                                 positions=pos, ctx=ctx)

    x = rmsnorm(x, params["final_ln"])
    logits = unembed(params["embed"], x, tie=cfg.tie_embeddings,
                     final_softcap=cfg.final_softcap)
    logits = ctx.constrain(logits, "batch", None, "act_vocab")
    loss = softmax_xent(logits, batch["labels"])
    metrics = {"ce_loss": loss}
    for k, v in (aux or {}).items():
        loss = loss + v
        metrics[k] = v
    metrics["loss"] = loss
    return loss, metrics


def _pad_cache_seq(entry, target_shape):
    """Pad a produced prefill cache entry to the cache buffer shape."""
    if entry.shape == tuple(target_shape):
        return entry
    pads = [(0, t - s) for s, t in zip(entry.shape, target_shape)]
    return jnp.pad(entry, pads)


def forward_prefill(cfg, params, batch, cache, ctx=NULL_CTX):
    """Fill the cache from a full prompt.  Returns (last_logits, cache')."""
    spec = cache_spec_tree(cfg, cache["lengths"].shape[0],
                           _max_len_of(cfg, cache))
    if cfg.family == "encdec":
        enc_out = encdec_lib.run_encoder(cfg, params["backbone"],
                                         batch["frames"].astype(_dtype(cfg)),
                                         ctx)
        b, s = batch["tokens"].shape
        x = _embed_inputs(cfg, params, batch, ctx)
        pos = _positions(cfg, b, jnp.zeros((b,), jnp.int32), s)
        x, new_entries = encdec_lib.run_decoder(
            cfg, params["backbone"], x, enc_out, mode="prefill",
            positions=pos, ctx=ctx)
    else:
        b, s = batch["tokens"].shape
        x = _embed_inputs(cfg, params, batch, ctx)
        if cfg.family == "vlm":
            pos = batch["positions"]
        else:
            pos = _positions(cfg, b, jnp.zeros((b,), jnp.int32), s)
        x, new_entries, _ = run_backbone(cfg, params["backbone"], x,
                                         mode="prefill", positions=pos,
                                         cache=cache, ctx=ctx)

    new_cache = dict(cache)
    for k, v in new_entries.items():
        new_cache[k] = _pad_cache_seq(v, spec[k][0]).astype(spec[k][1])
    new_cache["lengths"] = jnp.full_like(cache["lengths"], s)

    x_last = x[:, -1:]
    x_last = rmsnorm(x_last, params["final_ln"])
    logits = unembed(params["embed"], x_last, tie=cfg.tie_embeddings,
                     final_softcap=cfg.final_softcap)
    return logits[:, 0], new_cache


def forward_decode(cfg, params, tokens, cache, ctx=NULL_CTX,
                   positions=None):
    """One decode step.  tokens: (B, 1).  Returns (logits (B,V), cache')."""
    b = tokens.shape[0]
    lengths = cache["lengths"] + 1
    pos_scalar = cache["lengths"]                      # 0-based new position
    if cfg.family == "vlm":
        pos = positions if positions is not None else \
            jnp.broadcast_to(pos_scalar[None, :, None], (3, b, 1))
    else:
        pos = pos_scalar[:, None]

    x = embed_tokens(params["embed"], tokens, _dtype(cfg))
    if cfg.family == "encdec":
        x, new_entries = encdec_lib.run_decoder(
            cfg, params["backbone"], x, None, mode="decode", positions=pos,
            cache=cache, lengths=lengths, ctx=ctx)
    else:
        x, new_entries, _ = run_backbone(
            cfg, params["backbone"], x, mode="decode", positions=pos,
            cache=cache, lengths=lengths, ctx=ctx)

    new_cache = dict(cache)
    new_cache.update(new_entries)
    new_cache["lengths"] = lengths

    x = rmsnorm(x, params["final_ln"])
    logits = unembed(params["embed"], x, tie=cfg.tie_embeddings,
                     final_softcap=cfg.final_softcap)
    return logits[:, 0], new_cache


def _max_len_of(cfg, cache) -> int:
    for k in ("k", "k_global", "c_kv"):
        if k in cache:
            return cache[k].shape[2]
    return cfg.max_cache_len


# ---------------------------------------------------------------------------
# Input specs (dry-run) and concrete batch builders (smoke tests)
# ---------------------------------------------------------------------------

def _tok_sds(shape, mesh, rules, dtype=jnp.int32):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    from repro.distributed.sharding import divisible_spec
    spec = divisible_spec(mesh, shape,
                          [rules["batch"]] + [None] * (len(shape) - 1))
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                mesh: Optional[Mesh] = None, rules=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    rules = rules or {}
    b, s = cell.global_batch, cell.seq_len
    dt = _dtype(cfg)
    if cell.kind == "train" or cell.kind == "prefill":
        batch = {"tokens": _tok_sds((b, s), mesh, rules),
                 "labels": _tok_sds((b, s), mesh, rules)}
        if cfg.family == "vlm":
            pos = jax.ShapeDtypeStruct((3, b, s), jnp.int32) if mesh is None \
                else jax.ShapeDtypeStruct(
                    (3, b, s), jnp.int32,
                    sharding=NamedSharding(mesh, P(None, rules["batch"], None)))
            batch["positions"] = pos
        if cfg.family == "encdec":
            # encoder (stub frontend) length: the shape cell's seq_len is
            # the decoder context; the encoder side uses the configured
            # source length except in training where both run at seq_len.
            src = s if cell.kind == "train" else cfg.src_len_for_decode
            batch["frames"] = _tok_sds((b, src, cfg.d_model), mesh, rules,
                                       dt)
        if cell.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one token + cache of seq_len
    batch = {"tokens": _tok_sds((b, 1), mesh, rules)}
    return batch


def abstract_decode_cache(cfg, cell, mesh=None, rules=None):
    return abstract_cache(cfg, cell.global_batch, cell.seq_len, mesh, rules)


def make_smoke_batch(cfg, key, batch=2, seq=32) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                        cfg.vocab_size),
           "labels": jax.random.randint(ks[1], (batch, seq), 0,
                                        cfg.vocab_size)}
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(seq)[None, None], (3, batch, seq))
        out["positions"] = pos.astype(jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(ks[2], (batch, seq, cfg.d_model),
                                          jnp.float32)
    return out


def init_model(cfg, key, dtype=None):
    return init_params(model_defs(cfg), key, dtype or _dtype(cfg))


def abstract_model(cfg, mesh=None, rules=None, dtype=None):
    return abstract_params(model_defs(cfg), dtype or _dtype(cfg), mesh, rules)
