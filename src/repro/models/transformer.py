"""Decoder-only transformer assembly for the dense / moe / vlm / ssm / hybrid
families: scan-over-layers (stacked params — keeps HLO size O(1) in depth),
optional remat, KV-cache read/write per mode.

Every family funnels through ``run_backbone(cfg, params, x, ...)`` which
returns final hidden states + updated cache + aux losses; embedding/unembed
and the loss live in model.py.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn_lib
from repro.models.common import (NULL_CTX, mlp_defs, apply_mlp, rmsnorm,
                                 rmsnorm_def, stacked)
from repro.models.moe import apply_moe, moe_defs
from repro.models.params import ParamDef
from repro.models.ssm import apply_ssm, ssm_defs

ZERO_AUX = {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


def _remat(fn, cfg):
    """Wrap a scan body with remat + an activation barrier.

    The optimization_barrier on the carried activations stops XLA from
    hoisting downstream fp32 converts into the saved residual stack (which
    would store an f32 copy of every layer's input — 2× activation memory;
    observed on the CPU backend, EXPERIMENTS.md §Dry-run).
    """
    def barriered(carry, xs):
        # barrier on the INPUT side: the residual stack saves body inputs,
        # and an opaque consumer forces XLA to store them in their native
        # dtype (bf16) instead of a pre-converted f32 copy.
        carry = jax.tree_util.tree_map(
            lambda t: lax.optimization_barrier(t) if t.ndim >= 3 else t,
            carry)
        return fn(carry, xs)

    if cfg.remat == "none":
        return barriered
    if cfg.remat == "dots":
        return jax.checkpoint(
            barriered,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(barriered)


# ---------------------------------------------------------------------------
# Dense / MoE / VLM block
# ---------------------------------------------------------------------------

def dense_block_defs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    attn = attn_lib.mla_defs(cfg) if cfg.attn_type == "mla" \
        else attn_lib.gqa_defs(cfg)
    ffn = moe_defs(cfg) if cfg.n_experts else mlp_defs(d, cfg.d_ff)
    defs = {"ln1": rmsnorm_def(d), "attn": attn,
            "ln2": rmsnorm_def(d), "ffn": ffn}
    if cfg.post_norm:
        defs["post1"] = rmsnorm_def(d)
        defs["post2"] = rmsnorm_def(d)
    return defs


def apply_dense_block(cfg, p, x, *, positions, mode, window=0,
                      kv=None, lengths=None, ctx=NULL_CTX, q_offset=0):
    """One pre-norm block.  Returns (x', new_kv, aux).

    ``kv``: decode-mode cache slice — (k_flat, v_flat) for GQA or
    (c_kv, k_rope) for MLA, shapes (B, Smax, ·).
    In prefill mode new_kv holds the produced keys/values (trimmed to the
    ring window if SWA); in train mode new_kv is None.
    """
    b, s, d = x.shape
    h = rmsnorm(x, p["ln1"])
    aux = dict(ZERO_AUX)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    if cfg.attn_type == "mla":
        if mode == "decode":
            o, ckv, krope = attn_lib.mla_decode(
                cfg, p["attn"], h, positions, kv[0], kv[1], lengths)
            new_kv = (ckv, krope)
        else:
            o, (ckv, krope) = attn_lib.mla_attend(
                cfg, p["attn"], h, positions, q_offset=q_offset)
            new_kv = None if mode == "train" else (ckv, krope)
    else:
        if mode == "decode" and cfg.kv_quant == "int8":
            kq8 = kv[0].reshape(b, -1, hkv, hd)
            vq8 = kv[1].reshape(b, -1, hkv, hd)
            o, kq8, vq8, ks, vs = attn_lib.gqa_decode_quant(
                cfg, p["attn"], h, positions, kq8, vq8, kv[2], kv[3],
                lengths, window=window)
            new_kv = (kq8.reshape(b, -1, hkv * hd),
                      vq8.reshape(b, -1, hkv * hd), ks, vs)
        elif mode == "decode":
            k4 = kv[0].reshape(b, -1, hkv, hd)
            v4 = kv[1].reshape(b, -1, hkv, hd)
            o, k4, v4 = attn_lib.gqa_decode(
                cfg, p["attn"], h, positions, k4, v4, lengths, window=window)
            new_kv = (k4.reshape(b, -1, hkv * hd), v4.reshape(b, -1, hkv * hd))
        else:
            o, (k4, v4) = attn_lib.gqa_attend(
                cfg, p["attn"], h, positions, window=window,
                q_offset=q_offset)
            if mode == "train":
                new_kv = None
            else:
                smax = min(window, s) if window else s
                k_keep, v_keep = k4[:, -smax:], v4[:, -smax:]
                if window and s > smax:
                    # ring-buffer semantics: token t lives at slot t % smax,
                    # so the kept tail must be rolled into slot order.
                    shift = s % smax
                    k_keep = jnp.roll(k_keep, shift, axis=1)
                    v_keep = jnp.roll(v_keep, shift, axis=1)
                if cfg.kv_quant == "int8":
                    kq8, ks = attn_lib.quantize_kv(k_keep)
                    vq8, vs = attn_lib.quantize_kv(v_keep)
                    new_kv = (kq8.reshape(b, smax, hkv * hd),
                              vq8.reshape(b, smax, hkv * hd), ks, vs)
                else:
                    new_kv = (k_keep.reshape(b, smax, hkv * hd),
                              v_keep.reshape(b, smax, hkv * hd))

    if cfg.post_norm:
        o = rmsnorm(o, p["post1"])
    x = x + o
    x = ctx.constrain(x, "batch", None, None)

    h = rmsnorm(x, p["ln2"])
    if cfg.n_experts:
        f, aux = apply_moe(cfg, p["ffn"], h, ctx)
    else:
        f = apply_mlp(p["ffn"], h)
    if cfg.post_norm:
        f = rmsnorm(f, p["post2"])
    x = x + f
    x = ctx.constrain(x, "batch", None, None)
    return x, new_kv, aux


# ---------------------------------------------------------------------------
# SSM (mamba2) block
# ---------------------------------------------------------------------------

def ssm_block_defs(cfg) -> Dict[str, Any]:
    return {"ln": rmsnorm_def(cfg.d_model), "ssm": ssm_defs(cfg)}


def apply_ssm_block(cfg, p, x, *, mode, conv_state=None, ssm_state=None,
                    ctx=NULL_CTX):
    h = rmsnorm(x, p["ln"])
    y, (conv_state, ssm_state) = apply_ssm(
        cfg, p["ssm"], h, conv_state=conv_state, ssm_state=ssm_state,
        mode=mode)
    x = ctx.constrain(x + y, "batch", None, None)
    return x, conv_state, ssm_state


# ---------------------------------------------------------------------------
# Backbone stacks
# ---------------------------------------------------------------------------

def backbone_defs(cfg) -> Dict[str, Any]:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.layer_pattern == "alt_local_global":
            pair = {"local": dense_block_defs(cfg),
                    "global": dense_block_defs(cfg)}
            return {"pairs": stacked(pair, cfg.n_layers // 2)}
        return {"layers": stacked(dense_block_defs(cfg), cfg.n_layers)}
    if fam == "ssm":
        return {"layers": stacked(ssm_block_defs(cfg), cfg.n_layers)}
    if fam == "hybrid":
        shared = {"ln1": rmsnorm_def(cfg.d_model),
                  "attn": attn_lib.gqa_defs(cfg),
                  "ln2": rmsnorm_def(cfg.d_model),
                  "ffn": mlp_defs(cfg.d_model, cfg.d_ff)}
        r = cfg.shared_lora_rank
        d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
        lora = {
            "a_q": ParamDef((d, r), ("embed", None), "small"),
            "b_q": ParamDef((r, h * hd), (None, "model"), "zeros"),
            "a_k": ParamDef((d, r), ("embed", None), "small"),
            "b_k": ParamDef((r, cfg.n_kv_heads * hd), (None, "model"), "zeros"),
            "a_v": ParamDef((d, r), ("embed", None), "small"),
            "b_v": ParamDef((r, cfg.n_kv_heads * hd), (None, "model"), "zeros"),
        }
        return {
            "units": stacked(
                {"mamba": stacked(ssm_block_defs(cfg), cfg.mamba_per_unit,
                                  "layers"),
                 "lora": lora}, cfg.hybrid_units, "units"),
            "shared": shared,
            "tail": stacked(ssm_block_defs(cfg), cfg.trailing_mamba),
        }
    raise ValueError(fam)


def _shared_attn_params(shared, lora):
    """Zamba2: shared transformer block + per-invocation LoRA deltas on QKV."""
    p = dict(shared)
    p = {**shared}
    attn = dict(shared["attn"])
    attn["wq"] = attn["wq"] + lora["a_q"] @ lora["b_q"]
    attn["wk"] = attn["wk"] + lora["a_k"] @ lora["b_k"]
    attn["wv"] = attn["wv"] + lora["a_v"] @ lora["b_v"]
    p["attn"] = attn
    return p


def run_backbone(cfg, params, x, *, mode, positions, cache=None,
                 lengths=None, ctx=NULL_CTX, q_offset=0):
    """Run all layers.  x: (B, S, d) embedded inputs.

    Returns (hidden, new_cache_entries, aux) where new_cache_entries is a
    dict matching the kvcache layout (without "lengths").
    """
    fam = cfg.family
    aux_sum = dict(ZERO_AUX)
    new_cache: Dict[str, jax.Array] = {}

    if fam in ("dense", "moe", "vlm") and cfg.layer_pattern != "alt_local_global":
        window = cfg.sliding_window if cfg.layer_pattern == "swa" else 0

        def body(carry, xs):
            x, aux = carry
            if mode == "decode":
                p, *kv_in = xs
                x, kv, a = apply_dense_block(
                    cfg, p, x, positions=positions, mode=mode, window=window,
                    kv=tuple(kv_in), lengths=lengths, ctx=ctx)
            else:
                p = xs
                x, kv, a = apply_dense_block(
                    cfg, p, x, positions=positions, mode=mode, window=window,
                    ctx=ctx, q_offset=q_offset)
            aux = {k2: aux[k2] + a[k2] for k2 in aux}
            return (x, aux), kv

        if cfg.attn_type == "mla":
            names = ("c_kv", "k_rope")
        elif cfg.kv_quant == "int8":
            names = ("k", "v", "k_scale", "v_scale")
        else:
            names = ("k", "v")
        if mode == "decode":
            xs = (params["layers"],) + tuple(cache[n] for n in names)
        else:
            xs = params["layers"]
        (x, aux_sum), kvs = lax.scan(_remat(body, cfg), (x, aux_sum), xs)
        if mode != "train":
            new_cache = {n: kvs[i] for i, n in enumerate(names)}

    elif fam in ("dense", "moe", "vlm"):
        # gemma2-style local/global pairs
        w = cfg.sliding_window

        def body(carry, xs):
            x, aux = carry
            if mode == "decode":
                p, kl, vl, kg, vg = xs
                x, kv_l, a1 = apply_dense_block(
                    cfg, p["local"], x, positions=positions, mode=mode,
                    window=w, kv=(kl, vl), lengths=lengths, ctx=ctx)
                x, kv_g, a2 = apply_dense_block(
                    cfg, p["global"], x, positions=positions, mode=mode,
                    window=0, kv=(kg, vg), lengths=lengths, ctx=ctx)
            else:
                p = xs
                x, kv_l, a1 = apply_dense_block(
                    cfg, p["local"], x, positions=positions, mode=mode,
                    window=w, ctx=ctx, q_offset=q_offset)
                x, kv_g, a2 = apply_dense_block(
                    cfg, p["global"], x, positions=positions, mode=mode,
                    window=0, ctx=ctx, q_offset=q_offset)
            aux = {k2: aux[k2] + a1[k2] + a2[k2] for k2 in aux}
            ys = (kv_l, kv_g) if mode != "train" else None
            return (x, aux), ys

        if mode == "decode":
            xs = (params["pairs"], cache["k_local"], cache["v_local"],
                  cache["k_global"], cache["v_global"])
        else:
            xs = params["pairs"]
        (x, aux_sum), ys = lax.scan(_remat(body, cfg), (x, aux_sum), xs)
        if mode != "train":
            (kl, vl), (kg, vg) = ys
            new_cache = {"k_local": kl, "v_local": vl,
                         "k_global": kg, "v_global": vg}

    elif fam == "ssm":
        def body(carry, xs):
            x = carry
            if mode == "train":
                p = xs
                x, _, _ = apply_ssm_block(cfg, p, x, mode=mode, ctx=ctx)
                return x, None
            p, cs, ss = xs
            x, cs, ss = apply_ssm_block(cfg, p, x, mode=mode, conv_state=cs,
                                        ssm_state=ss, ctx=ctx)
            return x, (cs, ss)

        if mode == "train":
            x, _ = lax.scan(_remat(body, cfg), x, params["layers"])
        else:
            x, ys = lax.scan(_remat(body, cfg), x,
                             (params["layers"], cache["conv"], cache["ssm"]))
            new_cache = {"conv": ys[0], "ssm": ys[1]}

    elif fam == "hybrid":
        shared = params["shared"]

        def unit_body(carry, xs):
            x, aux = carry
            if mode == "train":
                up = xs
            elif mode == "prefill":
                up, cs_u, ss_u = xs
            else:
                up, cs_u, ss_u, k_u, v_u = xs

            def mamba_body(xc, m_xs):
                if mode == "train":
                    mp = m_xs
                    xc, _, _ = apply_ssm_block(cfg, mp, xc, mode=mode, ctx=ctx)
                    return xc, None
                mp, cs, ss = m_xs
                xc, cs, ss = apply_ssm_block(cfg, mp, xc, mode=mode,
                                             conv_state=cs, ssm_state=ss,
                                             ctx=ctx)
                return xc, (cs, ss)

            if mode == "train":
                x, _ = lax.scan(mamba_body, x, up["mamba"])
                m_ys = None
            else:
                x, m_ys = lax.scan(mamba_body, x, (up["mamba"], cs_u, ss_u))

            sp = _shared_attn_params(shared, up["lora"])
            b, s, _ = x.shape
            hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            h = rmsnorm(x, sp["ln1"])
            if mode == "decode":
                k4 = k_u.reshape(b, -1, hkv, hd)
                v4 = v_u.reshape(b, -1, hkv, hd)
                o, k4, v4 = attn_lib.gqa_decode(
                    cfg, sp["attn"], h, positions, k4, v4, lengths)
                kv = (k4.reshape(b, -1, hkv * hd),
                      v4.reshape(b, -1, hkv * hd))
            else:
                o, (k4, v4) = attn_lib.gqa_attend(
                    cfg, sp["attn"], h, positions, q_offset=q_offset)
                kv = None if mode == "train" else (
                    k4.reshape(b, s, hkv * hd), v4.reshape(b, s, hkv * hd))
            x = ctx.constrain(x + o, "batch", None, None)
            x = x + apply_mlp(sp["ffn"], rmsnorm(x, sp["ln2"]))
            x = ctx.constrain(x, "batch", None, None)
            if mode == "train":
                return (x, aux), None
            return (x, aux), (m_ys, kv)

        if mode == "train":
            xs = params["units"]
        elif mode == "prefill":
            xs = (params["units"],
                  jnp.zeros_like(cache["conv"]), jnp.zeros_like(cache["ssm"]))
        else:
            xs = (params["units"], cache["conv"], cache["ssm"],
                  cache["k"], cache["v"])
        (x, aux_sum), ys = lax.scan(_remat(unit_body, cfg), (x, aux_sum), xs)
        if mode != "train":
            m_ys, kv = ys
            new_cache.update({"conv": m_ys[0], "ssm": m_ys[1],
                              "k": kv[0], "v": kv[1]})

        def tail_body(xc, m_xs):
            if mode == "train":
                xc, _, _ = apply_ssm_block(cfg, m_xs, xc, mode=mode, ctx=ctx)
                return xc, None
            mp, cs, ss = m_xs
            xc, cs, ss = apply_ssm_block(cfg, mp, xc, mode=mode,
                                         conv_state=cs, ssm_state=ss, ctx=ctx)
            return xc, (cs, ss)

        if mode == "train":
            x, _ = lax.scan(_remat(tail_body, cfg), x, params["tail"])
        else:
            if mode == "prefill":
                tail_cs = (jnp.zeros_like(cache["conv_tail"]),
                           jnp.zeros_like(cache["ssm_tail"]))
            else:
                tail_cs = (cache["conv_tail"], cache["ssm_tail"])
            x, t_ys = lax.scan(_remat(tail_body, cfg), x,
                               (params["tail"],) + tail_cs)
            new_cache.update({"conv_tail": t_ys[0], "ssm_tail": t_ys[1]})
    else:
        raise ValueError(fam)

    return x, new_cache, aux_sum
