"""Parameter definition machinery.

Models declare their parameters as a pytree of ``ParamDef`` (shape + logical
axes + init law).  From one definition tree we derive:

  * ``init_params``      — materialised arrays (CPU smoke tests, examples);
  * ``abstract_params``  — ShapeDtypeStruct stand-ins with shardings attached
                           (the multi-pod dry-run lowers against these — a
                           480B-param model never allocates);
  * ``param_shardings``  — NamedSharding tree via the logical-axis rules in
                           repro.distributed.sharding.

Logical axis names used by the zoo:
  layers/units  — stacked scan dimension (never sharded)
  embed         — weight input dim → FSDP axes ("pod","data")
  model         — tensor-parallel output dim (heads, mlp, vocab rows…)
  experts       — MoE expert dim → "model" (expert parallelism)
  none          — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis per dim
    init: str = "normal"              # normal | zeros | ones | embed | small
    scale: float = 1.0                # fan-in scaling multiplier

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_array(d: ParamDef, key, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        std = 1.0
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
    if d.init == "small":
        return (jax.random.normal(key, d.shape, jnp.float32) * 0.02 * d.scale
                ).astype(dtype)
    # fan-in scaled normal: fan-in = product of all dims mapped to the
    # "input" side — approximate with the second-to-last dim (weights are
    # (..., d_in, d_out)) or the last dim for 1-D.
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_params(defs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_array(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(defs, dtype, mesh: Optional[Mesh] = None, rules=None):
    """ShapeDtypeStruct tree (with shardings when mesh given) — no allocation."""
    def mk(d: ParamDef):
        if mesh is not None:
            return jax.ShapeDtypeStruct(
                d.shape, dtype, sharding=NamedSharding(mesh, spec_for(d, rules)))
        return jax.ShapeDtypeStruct(d.shape, dtype)
    return jax.tree_util.tree_map(
        mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def spec_for(d: ParamDef, rules: Dict[str, Any]) -> P:
    return P(*(rules.get(a) if a is not None else None for a in d.axes))


def param_shardings(defs, mesh: Mesh, rules) -> Any:
    return jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, spec_for(d, rules)), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(np.prod(d.shape) for d in leaves))
