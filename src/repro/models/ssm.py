"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of ``ssm_chunk``; within a chunk the quadratic "attention-like" form
runs on the MXU, across chunks a linear recurrence carries the
(heads × head_dim × state) SSM state.  Decode is the O(1) recurrent update.

Layer structure (Mamba2):
  in_proj → [z | xBC | dt],  causal depthwise conv over xBC, SiLU,
  SSD(x·dt, exp(dt·A), B, C) + D·x,  gated RMSNorm(·, z), out_proj.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import gated_rmsnorm, rmsnorm_def
from repro.models.params import ParamDef


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def ssm_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    din, nh, conv_dim = ssm_dims(cfg)
    return {
        "in_proj": ParamDef((d, din + conv_dim + nh), ("embed", "model")),
        "conv_w": ParamDef((cfg.ssm_conv, conv_dim), (None, "model")),
        "conv_b": ParamDef((conv_dim,), ("model",), "zeros"),
        "a_log": ParamDef((nh,), ("model",), "zeros"),
        "d_skip": ParamDef((nh,), ("model",), "ones"),
        "dt_bias": ParamDef((nh,), ("model",), "zeros"),
        "norm": rmsnorm_def(din),
        "out_proj": ParamDef((din, d), ("model", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: (B,S,C); w: (K,C); state: (B,K-1,C).

    Returns (y (B,S,C), new_state (B,K-1,C)).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    y = sum(xx[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xx[:, -(k - 1):] if k > 1 else state
    return y + b[None, None], new_state


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum over
    (j, i] of a — the log-domain decay matrix of SSD.  a: (..., Q)."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_neg, bmat, cmat, *, chunk: int,
                h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:    (B, S, H, P)   inputs (already includes dt factor via x*dt)
    dt:   (B, S, H)      discretisation steps (softplus'd)
    a_neg:(H,)           negative continuous-time A (so dA = dt * a_neg ≤ 0)
    bmat: (B, S, G, N)   input mixers (broadcast G→H)
    cmat: (B, S, G, N)   output mixers
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    b, s0, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    # pad sequence to a chunk multiple; padded steps carry dt=0 so the decay
    # is exp(0)=1 and the state contribution dt·B⊗x is 0 — state-neutral.
    s = -(-s0 // chunk) * chunk
    if s != s0:
        pad = ((0, 0), (0, s - s0), (0, 0), (0, 0))
        x = jnp.pad(x, pad)
        bmat = jnp.pad(bmat, pad)
        cmat = jnp.pad(cmat, pad)
        dt = jnp.pad(dt, ((0, 0), (0, s - s0), (0, 0)))
    nc = s // chunk
    rep = h // g

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc = to_chunks(x.astype(jnp.float32))
    dac = to_chunks((dt * a_neg[None, None]).astype(jnp.float32))  # (B,nc,Q,H)
    bc = to_chunks(bmat.astype(jnp.float32))
    cc = to_chunks(cmat.astype(jnp.float32))
    bc = jnp.repeat(bc, rep, axis=3)    # (B,nc,Q,H,N)
    cc = jnp.repeat(cc, rep, axis=3)

    da_h = jnp.moveaxis(dac, -1, 2)      # (B,nc,H,Q)
    seg = jnp.exp(_segsum(da_h))         # (B,nc,H,Q,Q) intra-chunk decay
    cum = jnp.cumsum(da_h, axis=-1)      # (B,nc,H,Q)
    total = cum[..., -1]                 # (B,nc,H)

    # intra-chunk (quadratic, MXU): y_ij = C_i·B_j seg_ij x_j
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc) * seg.transpose(
        0, 1, 2, 3, 4)                   # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # chunk states: S_c = sum_j exp(total - cum_j) B_j ⊗ x_j
    decay_tail = jnp.exp(total[..., None] - cum)          # (B,nc,H,Q)
    states = jnp.einsum("bchq,bcqhn,bcqhp->bchpn",
                        decay_tail, bc, xc)               # (B,nc,H,P,N)

    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def chunk_scan(hprev, inp):
        st, tot = inp                                     # (B,H,P,N),(B,H)
        hnew = hprev * jnp.exp(tot)[..., None, None] + st
        return hnew, hprev

    states_t = jnp.moveaxis(states, 1, 0)                 # (nc,B,H,P,N)
    total_t = jnp.moveaxis(total, 1, 0)                   # (nc,B,H)
    h_final, h_prevs = lax.scan(chunk_scan, h0, (states_t, total_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,P,N)

    # inter-chunk output: y_i += C_i · h_prev * exp(cum_i)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", cc, h_prevs) * \
        jnp.exp(jnp.moveaxis(cum, 2, -1))[..., None]      # (B,nc,Q,H,1)

    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s0]
    return y, h_final


def apply_ssm(cfg, p, x: jax.Array, *,
              conv_state=None, ssm_state=None, mode: str = "train"):
    """Mamba2 block.  x: (B,S,d).

    mode "train"/"prefill": chunked SSD over the full sequence.
    mode "decode": S == 1 recurrent update using (conv_state, ssm_state).
    Returns (y, (conv_state', ssm_state')).
    """
    b, s, d = x.shape
    din, nh, conv_dim = ssm_dims(cfg)
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    hd = cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + conv_dim]
    dt_raw = zxbcdt[..., din + conv_dim:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))        # (H,)

    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :din].reshape(b, s, nh, hd)
    bmat = xbc[..., din:din + g * n].reshape(b, s, g, n)
    cmat = xbc[..., din + g * n:].reshape(b, s, g, n)

    if mode == "decode":
        assert s == 1
        da = jnp.exp(dt[:, 0] * a_neg[None])                # (B,H)
        xdt = xs[:, 0].astype(jnp.float32) * dt[:, 0][..., None]  # (B,H,P)
        bmat1 = jnp.repeat(bmat[:, 0], nh // g, axis=1)     # (B,H,N)
        cmat1 = jnp.repeat(cmat[:, 0], nh // g, axis=1)
        if ssm_state is None:
            ssm_state = jnp.zeros((b, nh, hd, n), jnp.float32)
        ssm_state = ssm_state * da[..., None, None] + \
            jnp.einsum("bhp,bhn->bhpn", xdt, bmat1)
        y = jnp.einsum("bhpn,bhn->bhp", ssm_state, cmat1)
        y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * \
            xs[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, din).astype(x.dtype)
    else:
        xdt = xs.astype(jnp.float32) * dt[..., None]
        y, ssm_state = ssd_chunked(xdt, dt, a_neg, bmat, cmat,
                                   chunk=min(cfg.ssm_chunk, s), h0=ssm_state)
        y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * \
            xs.astype(jnp.float32)
        y = y.reshape(b, s, din).astype(x.dtype)

    y = gated_rmsnorm(y, z, p["norm"])
    return y @ p["out_proj"], (conv_state, ssm_state)
