"""repro.models — architecture zoo (10 assigned archs; DESIGN.md §5)."""
