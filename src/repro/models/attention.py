"""Attention: GQA (+qk-norm, softcap, SWA, M-RoPE) and MLA, with
flash-style chunked computation (pure-JAX online softmax) so no (S×S) score
tensor ever materialises — required for the 32k-prefill and 4k-train cells.

Two causal schedules (perf lever, EXPERIMENTS.md §Perf):
  * ``masked``      — scan over all K/V chunks and mask.  Simple, small HLO,
                      but compiles ~2× the useful attention FLOPs.
  * ``triangular``  — static Python loop over Q chunks; each only visits the
                      K/V chunks its causal/window footprint can reach.
                      Bigger HLO, near-zero wasted FLOPs.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import apply_mrope, apply_rope, rmsnorm, rmsnorm_def
from repro.models.params import ParamDef

_NEG = -2.0e30


# ---------------------------------------------------------------------------
# Flash-style chunked attention core
# ---------------------------------------------------------------------------

def _attn_block(qc, kc, vc, q_pos, k_pos, *, causal, window, softcap, scale,
                kv_valid):
    """One (q_chunk × k_chunk) attention block with online-softmax stats.

    qc: (B, Qc, Hkv, G, D); kc/vc: (B, Kc, Hkv, D).
    Returns (m, l, acc) contributions: s-max (B,Hkv,G,Qc), sumexp, weighted V.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = k_pos[None, :] < kv_valid          # padded KV masked out
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, _NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
    return m, l, acc


def _merge(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    q_offset: int = 0,
    causal_mode: str = "masked",
) -> jax.Array:
    """q: (B,Sq,H,D); k/v: (B,Sk,Hkv,D) → (B,Sq,H,D).

    ``q_offset`` is the absolute position of q[.,0] (prefill continuation).
    """
    b, sq0, h, d = q.shape
    _, sk0, hkv, _ = k.shape
    g = h // hkv
    scale = d ** -0.5
    q_chunk = min(q_chunk, sq0)
    k_chunk = min(k_chunk, sk0)
    # pad both sequence dims to chunk multiples; padded KV positions are
    # masked below, padded Q rows are sliced off at the end.
    sq = -(-sq0 // q_chunk) * q_chunk
    sk = -(-sk0 // k_chunk) * k_chunk
    if sq != sq0:
        q = jnp.pad(q, ((0, 0), (0, sq - sq0), (0, 0), (0, 0)))
    if sk != sk0:
        k = jnp.pad(k, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
    nq, nk = sq // q_chunk, sk // k_chunk
    q5 = q.reshape(b, sq, hkv, g, d)

    def init_stats():
        m = jnp.full((b, hkv, g, q_chunk), _NEG, jnp.float32)
        l = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        acc = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        return m, l, acc

    def q_pos_of(qi):
        return q_offset + qi * q_chunk + jnp.arange(q_chunk)

    def kv_block(ki):
        kc = lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, axis=1)
        vc = lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, axis=1)
        return kc, vc, ki * k_chunk + jnp.arange(k_chunk)

    def run_q_chunk(qi, kv_range):
        qc = lax.dynamic_slice_in_dim(q5, qi * q_chunk, q_chunk, axis=1)
        qp = q_pos_of(qi)

        def kv_step(carry, ki):
            kc, vc, kp = kv_block(ki)
            blk = _attn_block(qc, kc, vc, qp, kp, causal=causal,
                              window=window, softcap=softcap, scale=scale,
                              kv_valid=sk0)
            return _merge(*carry, *blk), None

        (m, l, acc), _ = lax.scan(kv_step, init_stats(), kv_range)
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,Hkv,G,Qc,D)
        return jnp.moveaxis(out, 3, 1).reshape(b, q_chunk, h, d)

    if causal_mode == "triangular" and causal:
        outs = []
        for qi in range(nq):
            hi = min(nk, (q_offset + (qi + 1) * q_chunk - 1) // k_chunk + 1)
            lo = 0
            if window:
                lo = max(0, (q_offset + qi * q_chunk - window) // k_chunk)
            outs.append(run_q_chunk(qi, jnp.arange(lo, hi)))
        out = jnp.concatenate(outs, axis=1)
    else:
        out = lax.map(lambda qi: run_q_chunk(qi, jnp.arange(nk)),
                      jnp.arange(nq))                       # (nq,B,Qc,H,D)
        out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d)
    return out[:, :sq0].astype(q.dtype)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    lengths: jax.Array, *,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) cache.

    q: (B,1,H,D); caches: (B,Smax,Hkv,D); lengths: (B,) tokens already in
    cache INCLUDING the current one.  For ring buffers (window>0, Smax ==
    window) every slot older than ``window`` has been overwritten, so all
    written slots are valid.
    """
    b, _, h, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = h // hkv
    scale = d ** -0.5
    q5 = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", q5.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slot = jnp.arange(smax)
    valid = slot[None, :] < jnp.minimum(lengths, smax)[:, None]   # (B,Smax)
    s = jnp.where(valid[:, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (serving lever, EXPERIMENTS.md §Perf-5)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array):
    """Per-(token, head) symmetric int8.  x: (..., hkv, hd) →
    (q int8, scale fp32 (..., hkv))."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-6) / 127.0
    q = jnp.round(xf / scale[..., None]).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def gqa_decode_quant(cfg, p, x, positions, kq8, vq8, ks, vs, lengths, *,
                     window=0):
    """One-token decode against an int8-quantized ring/linear cache.

    kq8/vq8: (B, Smax, Hkv, hd) int8; ks/vs: (B, Smax, Hkv) fp32.
    Returns (out, kq8', vq8', ks', vs').
    """
    b = x.shape[0]
    q, k, v = gqa_qkv(cfg, p, x, positions)        # k/v: (B,1,Hkv,hd)
    smax = kq8.shape[1]
    if window and smax == window:
        slot = (lengths - 1) % smax
    else:
        slot = jnp.minimum(lengths - 1, smax - 1)
    bidx = jnp.arange(b)
    kq_new, ks_new = quantize_kv(k[:, 0])
    vq_new, vs_new = quantize_kv(v[:, 0])
    kq8 = kq8.at[bidx, slot].set(kq_new)
    vq8 = vq8.at[bidx, slot].set(vq_new)
    ks = ks.at[bidx, slot].set(ks_new)
    vs = vs.at[bidx, slot].set(vs_new)
    k4 = dequantize_kv(kq8, ks, x.dtype)
    v4 = dequantize_kv(vq8, vs, x.dtype)
    o = decode_attention(q, k4, v4, lengths, window=window,
                         softcap=cfg.attn_softcap)
    return o.reshape(b, 1, -1) @ p["wo"], kq8, vq8, ks, vs


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_defs(cfg) -> Dict[str, ParamDef]:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kv_axis = None if cfg.replicate_kv else "model"
    defs = {
        "wq": ParamDef((d, h * hd), ("embed", "model")),
        "wk": ParamDef((d, hkv * hd), ("embed", kv_axis)),
        "wv": ParamDef((d, hkv * hd), ("embed", kv_axis)),
        "wo": ParamDef((h * hd, d), ("model", "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = rmsnorm_def(hd)
        defs["k_norm"] = rmsnorm_def(hd)
    return defs


def gqa_qkv(cfg, p, x, positions, *, rope=True):
    """Project + normalise + rope.  x: (B,S,d) → q (B,S,H,hd), k/v (B,S,Hkv,hd)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if rope:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(cfg, p, x, positions, *, window=0, causal=True, q_offset=0,
               kv_override=None):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    b, s, _ = x.shape
    q, k, v = gqa_qkv(cfg, p, x, positions, rope=kv_override is None)
    if kv_override is not None:        # enc-dec cross attention
        k, v = kv_override
        causal = False
    o = flash_attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
        q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk, q_offset=q_offset,
        causal_mode=cfg.causal_mode)
    return o.reshape(b, s, -1) @ p["wo"], (k, v)


def gqa_decode(cfg, p, x, positions, k_cache, v_cache, lengths, *, window=0):
    """One-token decode.  x: (B,1,d).  Returns (out, k_cache', v_cache')."""
    b = x.shape[0]
    q, k, v = gqa_qkv(cfg, p, x, positions)     # k/v: (B,1,Hkv,hd)
    smax = k_cache.shape[1]
    if window and smax == window:       # ring buffer (SWA)
        slot = (lengths - 1) % smax
    else:
        slot = jnp.minimum(lengths - 1, smax - 1)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    o = decode_attention(q, k_cache, v_cache, lengths,
                         window=window, softcap=cfg.attn_softcap)
    return o.reshape(b, 1, -1) @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek style)
# ---------------------------------------------------------------------------

def mla_defs(cfg) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "q_down": ParamDef((d, qr), ("embed", None)),
        "q_norm": rmsnorm_def(qr),
        "q_up": ParamDef((qr, h * (dn + dr)), (None, "model")),
        "kv_down": ParamDef((d, kvr + dr), ("embed", None)),
        "kv_norm": rmsnorm_def(kvr),
        "k_up": ParamDef((kvr, h * dn), (None, "model")),
        "v_up": ParamDef((kvr, h * dv), (None, "model")),
        "wo": ParamDef((h * dv, d), ("model", "embed")),
    }


def _mla_project_q(cfg, p, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = rmsnorm(x @ p["q_down"], p["q_norm"])
    q = (ql @ p["q_up"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, x, positions):
    """Compressed KV stream: (c_kv (B,S,kvr) normed, k_rope (B,S,dr) roped)."""
    kv = x @ p["kv_down"]
    c_kv = rmsnorm(kv[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attend(cfg, p, x, positions, *, q_offset=0):
    """Train/prefill MLA: materialise per-head K/V from the latent stream and
    run flash attention (Hkv == H).  Returns (out, (c_kv, k_rope)) — the
    latent pair is what the cache stores (the paper-level MLA memory win).
    """
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_project_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_latent(cfg, p, x, positions)
    k_nope = (c_kv @ p["k_up"]).reshape(b, s, h, dn)
    v = (c_kv @ p["v_up"]).reshape(b, s, h, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (b, s, h, cfg.qk_rope_dim))], axis=-1)
    # pad v to qk dim for the shared flash kernel, slice after.
    dq = dn + cfg.qk_rope_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - dv)))
    o = flash_attention(q, k, v_pad, causal=True, q_chunk=cfg.q_chunk,
                        k_chunk=cfg.k_chunk, q_offset=q_offset,
                        causal_mode=cfg.causal_mode)[..., :dv]
    return o.reshape(b, s, -1) @ p["wo"], (c_kv, k_rope)


def mla_decode(cfg, p, x, positions, ckv_cache, krope_cache, lengths):
    """Absorbed-matmul MLA decode: score directly in latent space —
    q_nope' = q_nope @ k_upᵀ (per head) lands in the kv_lora space, so the
    cache is never expanded to per-head K/V (O(S·kvr) instead of O(S·H·hd)).
    """
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv, kvr = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                       cfg.kv_lora_rank)
    q_nope, q_rope = _mla_project_q(cfg, p, x, positions)   # (B,1,H,·)
    c_kv, k_rope = _mla_latent(cfg, p, x, positions)        # (B,1,kvr),(B,1,dr)

    bidx = jnp.arange(b)
    slot = jnp.minimum(lengths - 1, ckv_cache.shape[1] - 1)
    ckv_cache = ckv_cache.at[bidx, slot].set(c_kv[:, 0])
    krope_cache = krope_cache.at[bidx, slot].set(k_rope[:, 0])

    k_up = p["k_up"].reshape(kvr, h, dn)
    # absorb: q' (B,H,kvr)
    q_lat = jnp.einsum("bhd,khd->bhk", q_nope[:, 0].astype(jnp.float32),
                       k_up.astype(jnp.float32))
    s_lat = jnp.einsum("bhk,bsk->bhs", q_lat,
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    s = (s_lat + s_rope) * scale
    valid = jnp.arange(ckv_cache.shape[1])[None] < lengths[:, None]
    s = jnp.where(valid[:, None], s, _NEG)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsk->bhk", pattn,
                       ckv_cache.astype(jnp.float32))       # (B,H,kvr)
    v_up = p["v_up"].reshape(kvr, h, dv)
    o = jnp.einsum("bhk,khd->bhd", o_lat, v_up.astype(jnp.float32))
    o = o.reshape(b, 1, h * dv).astype(x.dtype)
    return o @ p["wo"], ckv_cache, krope_cache
