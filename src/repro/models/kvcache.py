"""KV / state cache structures for every architecture family.

Caches are plain pytrees (dicts of arrays) with a leading stacking dim that
matches the layer scan, plus ``lengths`` (B,) int32.  ``abstract_cache``
returns ShapeDtypeStruct stand-ins (with shardings) for the dry-run.

Sharding note: K/V are stored FLAT on the trailing dim (…, Hkv·hd) and
sharded over the model axis there.  Several assigned archs have Hkv (8, 2)
smaller than the 16-wide model axis; the flat dim (Hkv·hd) is always a
multiple of 16, and GSPMD factors the flat sharding across the (Hkv, hd)
reshape inside the attention layer (hd-partial dots turn into psums).

Cache kinds per family:
  dense/moe/vlm : k/v (L, B, Smax, Hkv·hd); SWA archs use Smax = window
                  (ring buffer).
  gemma2-style  : separate "local" (ring, window) and "global" (full) stacks,
                  one per layer pair.
  mla           : latent c_kv (L, B, Smax, kvr) + k_rope (L, B, Smax, dr) —
                  the MLA cache-compression win (no per-head K/V ever stored).
  ssm           : conv_state (L, B, K-1, conv_dim) + ssm_state
                  (L, B, H, P, N) — O(1) in sequence length.
  hybrid        : mamba states per unit + trailing + attention k/v per shared
                  block invocation.
  encdec        : decoder self-attn k/v + cross-attn k/v (computed once at
                  prefill from the encoder output).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models.ssm import ssm_dims

_KV_AXES = (None, "batch", None, "model")  # (layers, B, S, Hkv·hd)


def _kv_axes(cfg):
    return (None, "batch", None, None if cfg.replicate_kv else "model")


def cache_spec_tree(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    """Returns {name: (shape, dtype, logical_axes)} description of the cache."""
    hd = cfg.resolved_head_dim
    hkv = cfg.n_kv_heads
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out: Dict[str, Any] = {
        "lengths": ((batch,), jnp.int32, ("batch",)),
    }
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.layer_pattern == "alt_local_global":
            npairs = cfg.n_layers // 2
            w = min(cfg.sliding_window, max_len)
            out["k_local"] = ((npairs, batch, w, hkv * hd), dt, _kv_axes(cfg))
            out["v_local"] = out["k_local"]
            out["k_global"] = ((npairs, batch, max_len, hkv * hd), dt, _kv_axes(cfg))
            out["v_global"] = out["k_global"]
        elif cfg.attn_type == "mla":
            l = cfg.n_layers
            out["c_kv"] = ((l, batch, max_len, cfg.kv_lora_rank), dt,
                           (None, "batch", None, "model"))
            out["k_rope"] = ((l, batch, max_len, cfg.qk_rope_dim), dt,
                             (None, "batch", None, None))
        else:
            smax = min(cfg.sliding_window, max_len) if cfg.sliding_window \
                else max_len
            if cfg.kv_quant == "int8":
                out["k"] = ((cfg.n_layers, batch, smax, hkv * hd), jnp.int8,
                            _kv_axes(cfg))
                out["v"] = out["k"]
                out["k_scale"] = ((cfg.n_layers, batch, smax, hkv),
                                  jnp.float32, (None, "batch", None, None))
                out["v_scale"] = out["k_scale"]
            else:
                out["k"] = ((cfg.n_layers, batch, smax, hkv * hd), dt,
                            _kv_axes(cfg))
                out["v"] = out["k"]
    elif fam == "ssm":
        din, nh, conv_dim = ssm_dims(cfg)
        l = cfg.n_layers
        out["conv"] = ((l, batch, cfg.ssm_conv - 1, conv_dim), dt,
                       (None, "batch", None, "act_mlp"))
        out["ssm"] = ((l, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32, (None, "batch", "act_heads", None, None))
    elif fam == "hybrid":
        din, nh, conv_dim = ssm_dims(cfg)
        u, m = cfg.hybrid_units, cfg.mamba_per_unit
        out["conv"] = ((u, m, batch, cfg.ssm_conv - 1, conv_dim), dt,
                       (None, None, "batch", None, "act_mlp"))
        out["ssm"] = ((u, m, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32,
                      (None, None, "batch", "act_heads", None, None))
        t = cfg.trailing_mamba
        out["conv_tail"] = ((t, batch, cfg.ssm_conv - 1, conv_dim), dt,
                            (None, "batch", None, "act_mlp"))
        out["ssm_tail"] = ((t, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32,
                           (None, "batch", "act_heads", None, None))
        out["k"] = ((u, batch, max_len, hkv * hd), dt, _kv_axes(cfg))
        out["v"] = out["k"]
    elif fam == "encdec":
        l = cfg.n_dec_layers
        out["k"] = ((l, batch, max_len, hkv * hd), dt, _kv_axes(cfg))
        out["v"] = out["k"]
        src = cfg.src_len_for_decode
        out["k_cross"] = ((l, batch, src, hkv * hd), dt, _kv_axes(cfg))
        out["v_cross"] = out["k_cross"]
    else:
        raise ValueError(fam)
    return out


def init_cache(cfg, batch: int, max_len: int) -> Dict[str, jax.Array]:
    tree = cache_spec_tree(cfg, batch, max_len)
    return {k: jnp.zeros(shape, dtype) for k, (shape, dtype, _) in tree.items()}


def abstract_cache(cfg, batch: int, max_len: int,
                   mesh: Optional[Mesh] = None, rules=None):
    tree = cache_spec_tree(cfg, batch, max_len)

    def mk(shape, dtype, axes):
        if mesh is None:
            return jax.ShapeDtypeStruct(shape, dtype)
        from repro.distributed.sharding import divisible_spec
        spec = divisible_spec(
            mesh, shape,
            [(rules or {}).get(a) if a is not None else None for a in axes])
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))
    return {k: mk(*v) for k, v in tree.items()}


def cache_shardings(cfg, batch, max_len, mesh: Mesh, rules):
    from repro.distributed.sharding import divisible_spec
    tree = cache_spec_tree(cfg, batch, max_len)
    return {k: NamedSharding(
        mesh, divisible_spec(
            mesh, shape,
            [rules.get(a) if a is not None else None for a in axes]))
        for k, (shape, dtype, axes) in tree.items()}


def cache_bytes(cfg, batch, max_len) -> int:
    tree = cache_spec_tree(cfg, batch, max_len)
    return int(sum(np.prod(shape) * np.dtype(dtype).itemsize
                   for shape, dtype, _ in tree.values()))
