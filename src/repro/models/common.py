"""Shared model components: norms, RoPE/M-RoPE, SwiGLU MLP, embeddings.

All modules follow the defs/apply pattern: ``*_defs`` returns a pytree of
ParamDef, ``apply_*``/functions consume a matching pytree of arrays.
Activations stay in the model dtype; norms/softmax/rope accumulate fp32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.params import ParamDef


def stacked(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for lax.scan over layers) to every ParamDef."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init,
                           d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_def(dim: int) -> ParamDef:
    return ParamDef((dim,), (None,), "ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def gated_rmsnorm(x: jax.Array, z: jax.Array, w: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
    """Mamba2 out-norm: RMSNorm(x) * silu(z)."""
    return rmsnorm(x, w, eps) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]).

    x: (B, S, H, D); positions: (B, S) int32.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                          # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    The D/2 frequency slots are split into ``sections`` = (t, h, w) groups;
    group g uses position stream g.  positions: (3, B, S).
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)                           # (D/2,)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=d // 2)
    pos_per_freq = jnp.take(positions.astype(jnp.float32), sec_id,
                            axis=0)                      # (D/2, B, S)
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv        # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int) -> Dict[str, ParamDef]:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "model")),
        "w_up": ParamDef((d_model, d_ff), ("embed", "model")),
        "w_down": ParamDef((d_ff, d_model), ("model", "embed")),
    }


def apply_mlp(p, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_defs(vocab: int, d_model: int, tie: bool) -> Dict[str, ParamDef]:
    defs = {"tok": ParamDef((vocab, d_model), ("model", "embed"), "small")}
    if not tie:
        defs["out"] = ParamDef((d_model, vocab), ("embed", "model"), "small")
    return defs


def embed_tokens(p, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def unembed(p, x: jax.Array, *, tie: bool,
            final_softcap: float = 0.0) -> jax.Array:
    w = p["tok"].T if tie else p["out"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if final_softcap:
        logits = final_softcap * jnp.tanh(logits / final_softcap)
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy in fp32.  logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        maskf = mask.astype(jnp.float32)
        return jnp.sum(nll * maskf) / jnp.maximum(jnp.sum(maskf), 1.0)
    return jnp.mean(nll)


class ShardCtx:
    """Optional sharding context threaded through the forward pass.

    Holds the logical-axis rules; ``constrain(x, *axes)`` applies a
    with_sharding_constraint when a mesh is active, else no-ops (CPU smoke
    tests run without a mesh).
    """

    def __init__(self, mesh=None, rules=None):
        self.mesh = mesh
        self.rules = rules or {}
        if mesh is not None:
            self.data_shards = 1
            for name in mesh.axis_names:
                if name != "model":
                    self.data_shards *= mesh.shape[name]
        else:
            self.data_shards = 1

    def constrain(self, x, *axes):
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import divisible_spec
        spec = divisible_spec(
            self.mesh, x.shape,
            [self.rules.get(a) if a is not None else None for a in axes])
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


NULL_CTX = ShardCtx()
