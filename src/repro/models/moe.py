"""Mixture-of-Experts block: top-k routing with capacity-bounded scatter
dispatch (MaxText-"dropping"-style, but scatter/gather instead of the
O(N·E·C) dispatch einsum so it scales to 128 experts).

Expert weights carry the ("experts" → model axis) logical sharding = expert
parallelism under pjit: XLA partitions the (E, C, d) dispatch buffer over the
model axis and inserts the token exchange collectives.  The explicit
shard_map all_to_all variant is a §Perf hillclimb of the arctic train cell.

Arctic-style ``dense_residual_d_ff`` adds a small dense SwiGLU MLP in
parallel with the MoE output (Snowflake's dense+MoE hybrid).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_mlp, mlp_defs
from repro.models.params import ParamDef


def moe_defs(cfg) -> Dict[str, ParamDef]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    # experts shard over the model axis (EP); d over the FSDP axes; the ff
    # dim carries the "moe_ff" logical axis — None under training rules
    # ("experts" and "model" both map to the model mesh axis and a mesh axis
    # can appear only once), mapped to the data axes under the
    # weight-stationary serve rules (2-D expert sharding, no per-step
    # gathers; EXPERIMENTS.md §Perf H1).
    defs = {
        "router": ParamDef((d, e), ("embed", None), "small"),
        "w_gate": ParamDef((e, d, f), ("experts", "embed", "moe_ff")),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "moe_ff")),
        "w_down": ParamDef((e, f, d), ("experts", "moe_ff", "embed")),
    }
    if cfg.dense_residual_d_ff:
        defs["dense"] = mlp_defs(d, cfg.dense_residual_d_ff)
    return defs


def _capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * n_tokens
            / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 (sublane alignment)


def apply_moe(cfg, p, x: jax.Array, ctx) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) → (B, S, d), aux-loss dict.

    Grouped per-data-shard dispatch: tokens are reshaped to (G, N/G, d)
    where G = number of data shards, so the routing cumsum, the capacity
    scatter and the combine gather are all *batched per shard* — XLA
    partitions them with zero cross-data-shard communication.  The expert
    dim of the (G, E, C, d) buffer carries the model-axis sharding (expert
    parallelism); the only model-axis collectives are the weight FSDP
    all-gathers and the combine reduction.  (The naive single-buffer global
    scatter is catastrophic under SPMD — it replicates and all-reduces the
    whole dispatch buffer; see EXPERIMENTS.md §Perf for the measured delta.)

    Capacity is per data shard (standard EP semantics); overflow tokens are
    dropped (their residual path still carries them).
    """
    import math
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    g = ctx.data_shards if ctx is not None else 1
    g = math.gcd(b, g)
    n = b * s
    n_loc = n // g
    cap = _capacity(cfg, n_loc)
    xg = x.reshape(g, n_loc, d)

    logits = (xg @ p["router"]).astype(jnp.float32)          # (G, N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (G, N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style) + router z-loss
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = {
        "load_balance": e * jnp.sum(me * ce) * cfg.aux_loss_coef,
        "router_z": jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_loss,
    }

    flat_e = idx.reshape(g, n_loc * k)                       # (G, Nk)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (G, Nk, E)
    ranks = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1), flat_e[..., None], axis=2)[..., 0] - 1
    keep = ranks < cap                                       # (G, Nk)
    dest = jnp.where(keep, flat_e * cap + ranks, e * cap)

    x_rep = jnp.repeat(xg, k, axis=1)                        # (G, Nk, d)
    # vmap'd scatter: G stays a *batch* dim of the HLO scatter, so SPMD
    # partitions it on the data axes (an explicit (g, dest) index pair
    # defeats partitioning and replicates the updates — 100+GB/layer).
    buf = jax.vmap(lambda d_, u: jnp.zeros((e * cap + 1, d), x.dtype)
                   .at[d_].add(u))(dest, x_rep)
    h = buf[:, : e * cap].reshape(g, e, cap, d)
    if ctx is not None and g == ctx.data_shards:
        h = ctx.constrain(h, "batch", "act_experts", None, None)

    # expert FFN (SwiGLU), batched over (shard, expert)
    hg = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
    hu = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    ho = jnp.einsum("gecf,efd->gecd", jax.nn.silu(hg) * hu, p["w_down"])
    if ctx is not None and g == ctx.data_shards:
        ho = ctx.constrain(ho, "batch", "act_experts", None, None)

    out_buf = jnp.concatenate(
        [ho.reshape(g, e * cap, d), jnp.zeros((g, 1, d), ho.dtype)], axis=1)
    y = jax.vmap(lambda ob, d_: jnp.take(ob, d_, axis=0))(out_buf, dest)
    y = y * (gate.reshape(g, -1, 1) * keep[..., None]).astype(y.dtype)
    y = y.reshape(g, n_loc, k, d).sum(axis=2).reshape(b, s, d)

    if cfg.dense_residual_d_ff:
        y = y + apply_mlp(p["dense"], x)
    return y, aux
