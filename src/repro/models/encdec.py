"""Encoder-decoder backbone (seamless-m4t style, audio frontend stubbed).

Encoder: bidirectional transformer over precomputed frame embeddings
(the speech frontend is a stub by contract — ``input_specs`` supplies
(B, S_src, d) frames).  Decoder: causal self-attention + cross-attention to
the encoder output.  Prefill computes the encoder pass once and caches the
cross-attention K/V per decoder layer.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn_lib
from repro.models.common import (NULL_CTX, apply_mlp, mlp_defs, rmsnorm,
                                 rmsnorm_def, stacked)
from repro.models.transformer import _remat


def enc_block_defs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": rmsnorm_def(d), "attn": attn_lib.gqa_defs(cfg),
            "ln2": rmsnorm_def(d), "ffn": mlp_defs(d, cfg.d_ff)}


def dec_block_defs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": rmsnorm_def(d), "self_attn": attn_lib.gqa_defs(cfg),
            "ln_x": rmsnorm_def(d), "cross_attn": attn_lib.gqa_defs(cfg),
            "ln2": rmsnorm_def(d), "ffn": mlp_defs(d, cfg.d_ff)}


def encdec_defs(cfg) -> Dict[str, Any]:
    return {"enc": stacked(enc_block_defs(cfg), cfg.n_enc_layers),
            "enc_ln": rmsnorm_def(cfg.d_model),
            "dec": stacked(dec_block_defs(cfg), cfg.n_dec_layers)}


def run_encoder(cfg, params, frames, ctx=NULL_CTX):
    """frames: (B, S_src, d) stub-frontend embeddings → (B, S_src, d)."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, p):
        h = rmsnorm(x, p["ln1"])
        o, _ = attn_lib.gqa_attend(cfg, p["attn"], h, positions, causal=False)
        x = ctx.constrain(x + o, "batch", None, None)
        x = x + apply_mlp(p["ffn"], rmsnorm(x, p["ln2"]))
        return ctx.constrain(x, "batch", None, None), None

    x, _ = lax.scan(_remat(body, cfg), frames, params["enc"])
    return rmsnorm(x, params["enc_ln"])


def run_decoder(cfg, params, x, enc_out, *, mode, positions, cache=None,
                lengths=None, ctx=NULL_CTX):
    """Decoder stack.  x: (B, S_tgt, d) embedded target tokens.

    Returns (hidden, new_cache_entries).
    mode "train"/"prefill": full teacher forcing, cross K/V from enc_out.
    mode "decode": one token; cross K/V come from the cache.
    """
    b = x.shape[0]
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    new_cache: Dict[str, jax.Array] = {}

    def body(carry, xs):
        x = carry
        if mode == "decode":
            p, k, v, kx, vx = xs
        else:
            p = xs
        h = rmsnorm(x, p["ln1"])
        if mode == "decode":
            k4 = k.reshape(b, -1, hkv, hd)
            v4 = v.reshape(b, -1, hkv, hd)
            o, k4, v4 = attn_lib.gqa_decode(
                cfg, p["self_attn"], h, positions, k4, v4, lengths)
            kv_self = (k4.reshape(b, -1, hkv * hd),
                       v4.reshape(b, -1, hkv * hd))
        else:
            o, (k4, v4) = attn_lib.gqa_attend(
                cfg, p["self_attn"], h, positions)
            s = x.shape[1]
            kv_self = None if mode == "train" else (
                k4.reshape(b, s, hkv * hd), v4.reshape(b, s, hkv * hd))
        x = ctx.constrain(x + o, "batch", None, None)

        h = rmsnorm(x, p["ln_x"])
        if mode == "decode":
            kx4 = kx.reshape(b, -1, hkv, hd)
            vx4 = vx.reshape(b, -1, hkv, hd)
            q, _, _ = attn_lib.gqa_qkv(cfg, p["cross_attn"], h, positions,
                                       rope=False)
            src_len = jnp.full((b,), kx4.shape[1], jnp.int32)
            o = attn_lib.decode_attention(q, kx4, vx4, src_len)
            o = o.reshape(b, 1, -1) @ p["cross_attn"]["wo"]
            kv_cross = (kx, vx)
        else:
            # cross K/V from encoder output (no rope in cross attention)
            kc = (enc_out @ p["cross_attn"]["wk"]).reshape(b, -1, hkv, hd)
            vc = (enc_out @ p["cross_attn"]["wv"]).reshape(b, -1, hkv, hd)
            o, _ = attn_lib.gqa_attend(
                cfg, p["cross_attn"], h, positions, kv_override=(kc, vc))
            s_src_ = kc.shape[1]
            kv_cross = None if mode == "train" else (
                kc.reshape(b, s_src_, hkv * hd),
                vc.reshape(b, s_src_, hkv * hd))
        x = ctx.constrain(x + o, "batch", None, None)
        x = x + apply_mlp(p["ffn"], rmsnorm(x, p["ln2"]))
        x = ctx.constrain(x, "batch", None, None)
        if mode == "train":
            return x, None
        return x, (kv_self, kv_cross)

    if mode == "decode":
        xs = (params["dec"], cache["k"], cache["v"],
              cache["k_cross"], cache["v_cross"])
    else:
        xs = params["dec"]
    x, ys = lax.scan(_remat(body, cfg), x, xs)
    if mode != "train":
        (k, v), (kx, vx) = ys
        new_cache = {"k": k, "v": v, "k_cross": kx, "v_cross": vx}
    return x, new_cache
