"""prepare()/PreparedDesign — the design-handle half of the solver API.

The paper's central structural property is that one sweep streams each
element of ``x`` exactly once, while everything reusable about the design is
computable up front: squared column norms (Algorithm 1 line 3), block Gram
Cholesky factors (``mode="gram"``), and — in a serving system — the
device-resident (possibly mesh-sharded) copies of ``x`` itself.  Related
direct/sketching baselines make the same split (factor once, solve per RHS);
here it is first-class:

    spec = SolverSpec(method="bakp_gram", rtol=1e-8)
    design = prepare(x, spec)            # once per design matrix
    res1 = design.solve(y1)              # cheap per-RHS solves
    res2 = design.solve(y2, a0=res1.coef)  # warm-started re-solve

``PreparedDesign`` owns, per design matrix:

  * the device-resident fp32 copy of ``x`` (``x_pad`` — callers may hand in
    an already shape-padded matrix, as the serving engine does);
  * its content ``fingerprint`` (identity for caches and request coalescing);
  * the squared column norms, plus thr-padded layouts per block width and
    their inverses (``inv_cn_for`` — consumed directly by the fused
    megakernel);
  * the transposed padded device copy per block width (``x_t_for`` — the
    Pallas kernels' (vars, obs) layout, relayouted once and kept resident);
  * the quantized cache tier (``x_bf16_for`` — the same layout cast to
    bf16 once, streamed by mixed-precision solves at half the HBM traffic
    while accumulators stay fp32);
  * block Gram Cholesky factors per ``(thr, ridge)``;
  * per-placement sharded device copies (a mesh backend needs ``x`` laid out
    for its in_specs; the ``device_put`` happens once per placement);
  * an LRU of per-tenant warm-start coefficients (serving re-solves with
    drifting ``y`` start from the tenant's last solution);
  * device ownership for the serving lanes: a ``home`` placement kind
    (``bind_home`` — first-wins) plus the ``resident_lanes()`` summary of
    which per-lane tiers (fused transposed/bf16 copies, sharded mesh
    copies) are currently built; ``warm_lane_state`` warms all of them for
    one (spec, placement) off the lane threads.

All of that state is mutated lazily from multiple threads in the serving
path (the async dispatcher pre-warms entries while the solver thread reads
them), so every accessor takes the per-design ``_lock``; the lock is
per-design so a slow Cholesky build on one design never blocks another.

Compiled programs are cached keyed by (spec static knobs, operand shapes,
placement): the single-device kernels are ``jit``-cached, the mesh backends
``lru_cache`` their ``shard_map`` programs, and ``_solve_protocol`` below
memoises the per-(spec, placement) dispatch so a repeated solve re-enters
its compiled program without re-touching the registry.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import (SolverSpec, UnsupportedSpecError,
                             ensure_precision_supported, solver_method,
                             streaming_methods)
from repro.core.types import (SolveResult, column_norms_sq, safe_inv,
                              warm_retention_ok)


def design_fingerprint(x, *, _prefix: str = "d") -> str:
    """Content fingerprint of a design matrix (shape + dtype + bytes).

    Two matrices that hash equal are the same design: they may share one
    ``PreparedDesign`` (and, in serving, coalesce into one multi-RHS solve).
    """
    a = np.ascontiguousarray(np.asarray(x))
    h = hashlib.blake2b(digest_size=16)
    h.update(str((a.shape, a.dtype.str)).encode())
    h.update(a.view(np.uint8).data)
    return f"{_prefix}:{h.hexdigest()}"


@dataclass
class PreparedDesign:
    """Per-design solver state + the ``solve`` handle (see module doc).

    ``x_pad`` is the device-resident fp32 design exactly as prepared —
    callers that bucket-pad (the serving engine) hand the padded matrix in.
    All mutable members (``chol``, ``_cn``, ``_cn_thr``, ``_warm``,
    ``_sharded``) are read AND written from concurrent threads in the
    serving path, so every accessor takes the per-design ``_lock``.

    Program caching note: the compiled programs behind ``solve`` are cached
    one level down, keyed by exactly (spec static knobs, operand shapes,
    placement) — ``jit`` on the single-device kernels, ``lru_cache``d
    ``shard_map`` programs for the mesh backends — so a repeat solve
    re-enters its compiled executable; the registry lookup itself is a
    plain dict access, never memoised (a re-``register_method`` with
    ``overwrite=True`` takes effect immediately).
    """

    x_pad: Optional[jax.Array]            # (obs, vars) fp32, device-resident;
    # None for a NON-RESIDENT handle (repro.store): the design's X bytes
    # live on the store's host/disk tiers and are fetched per column block
    # through ``blocks`` — only methods registered ``streams=True``
    # ("bakp_stream") can solve it; everything x-resident raises.
    spec: Optional[SolverSpec] = None     # default spec bound by prepare()
    fingerprint: Optional[str] = None
    mesh: Optional[object] = None         # serve.placement.ServeMesh-like
    home: Optional[str] = None            # home placement kind (lane home);
    # bound first-wins by bind_home() — the serving cache stamps it on the
    # first (pre)warm, so a design's primary residency is queryable even
    # after later solves add other lane tiers (see resident_lanes()).
    chol: Dict[Tuple[int, float], jax.Array] = field(default_factory=dict)
    max_tenants: int = 64
    blocks: Optional[object] = None       # StoreBlockSource of a
    # non-resident handle (shape / num_blocks(thr) / block_t(thr, j))
    _cn: Optional[jax.Array] = field(default=None, repr=False)
    _cn_thr: Dict[int, jax.Array] = field(default_factory=dict)
    _inv_cn: Dict[int, jax.Array] = field(default_factory=dict)
    _x_t: Dict[int, jax.Array] = field(default_factory=dict)
    _x_bf16: Dict[int, jax.Array] = field(default_factory=dict)
    _warm: "OrderedDict[str, np.ndarray]" = field(default_factory=OrderedDict)
    _sharded: Dict[object, jax.Array] = field(default_factory=dict)
    _lock: threading.RLock = field(default_factory=threading.RLock,
                                   repr=False, compare=False)

    # ------------------------------------------------------------ identity
    @property
    def shape(self) -> Tuple[int, int]:
        if self.x_pad is not None:
            return tuple(self.x_pad.shape)
        return tuple(self.blocks.shape)

    @property
    def resident(self) -> bool:
        """Whether the design is device-resident (vs a store-backed
        streaming handle)."""
        return self.x_pad is not None

    def _require_x(self, what: str) -> jax.Array:
        """The resident design, or a clear error on a streaming handle."""
        if self.x_pad is None:
            raise UnsupportedSpecError(
                f"{what} needs the device-resident design, but this "
                f"PreparedDesign is non-resident (X blocks stream through "
                f"the design store); solve with a streaming method "
                f"{streaming_methods()}")
        return self.x_pad

    def design_key(self) -> str:
        """This design's identity: the fingerprint handed to ``prepare``
        (serving passes its cache key) or, lazily on first use, the content
        hash of the matrix bytes.  Lazy because hashing is an O(obs·vars)
        host pass the plain ``solve()`` shim should never pay."""
        with self._lock:
            if self.fingerprint is None:
                self.fingerprint = design_fingerprint(
                    np.asarray(self._require_x("design_key")))
            return self.fingerprint

    # --------------------------------------------- per-tenant warm starts
    def warm_coef(self, tenant_id: Optional[str]) -> Optional[np.ndarray]:
        """Last stored coefficients for ``tenant_id`` (None = cold)."""
        if tenant_id is None:
            return None
        with self._lock:
            coef = self._warm.get(tenant_id)
            if coef is not None:
                self._warm.move_to_end(tenant_id)
            return coef

    def store_coef(self, tenant_id: Optional[str], coef: np.ndarray) -> None:
        """Retain a tenant's solved (unpadded) coefficients, LRU-bounded.

        Copies: the same array is handed back to callers, and an in-place
        mutation there must not corrupt the tenant's next warm start.
        """
        if tenant_id is None:
            return
        coef = np.array(coef, np.float32, copy=True)
        with self._lock:
            self._warm[tenant_id] = coef
            self._warm.move_to_end(tenant_id)
            while len(self._warm) > self.max_tenants:
                self._warm.popitem(last=False)

    # ------------------------------------------------- derived design state
    @property
    def cn(self) -> jax.Array:
        """Squared column norms (vars,), computed lazily on first use — the
        O(obs·vars) pass only the iterative methods need; direct methods
        ("lstsq"/"normal") never touch it, so a one-shot direct solve pays
        nothing extra."""
        with self._lock:
            if self._cn is None:
                self._cn = column_norms_sq(self._require_x("column norms"))
            return self._cn

    def cn_for_thr(self, thr: int) -> jax.Array:
        """Column norms extended to SolveBakP's thr-multiple padding."""
        vars_p = self.shape[1]
        nblocks = -(-vars_p // thr)
        pad = nblocks * thr - vars_p
        if pad == 0:
            return self.cn
        with self._lock:
            if thr not in self._cn_thr:
                self._cn_thr[thr] = jnp.concatenate(
                    [self.cn, jnp.zeros((pad,), jnp.float32)])
            return self._cn_thr[thr]

    def inv_cn_for(self, thr: int) -> jax.Array:
        """Inverse squared column norms in SolveBakP's thr-padded layout.

        The fused megakernel (``repro.kernels.fused_solve``) consumes these
        directly; padded (zero-norm) columns come back 0, which pins their
        updates to 0 exactly like the masked XLA path.
        """
        with self._lock:
            if thr not in self._inv_cn:
                self._inv_cn[thr] = safe_inv(self.cn_for_thr(thr))
            return self._inv_cn[thr]

    def x_t_for(self, thr: int) -> jax.Array:
        """Device-resident TRANSPOSED copy of the design, (vars_pad, obs)
        with vars zero-padded to a multiple of ``thr`` — the layout the
        Pallas kernels stream/hold (a paper-"column" is a contiguous row).
        The transpose relayout happens once per (design, thr) and is
        memoised; repeat fused solves reuse the resident copy.
        """
        with self._lock:
            if thr not in self._x_t:
                obs_p, vars_p = self._require_x("x_t_for").shape
                nblocks = -(-vars_p // thr)
                pad = nblocks * thr - vars_p
                x_t = jnp.swapaxes(self.x_pad, 0, 1)
                if pad:
                    x_t = jnp.pad(x_t, ((0, pad), (0, 0)))
                self._x_t[thr] = x_t
            return self._x_t[thr]

    def x_bf16_for(self, thr: int) -> jax.Array:
        """Quantized cache tier: ``x_t_for(thr)`` cast to bf16, memoised.

        The mixed-precision sweep kernels stream this copy instead of the
        fp32 one — half the HBM traffic, half the VMEM footprint — while
        every accumulator (residual, coef, SSE, norms) stays fp32.  The
        cast happens once per (design, thr); both copies stay resident so
        a later ``precision="fp32"`` solve (or the fp32 polish sweeps of
        ``"bf16_fp32acc"``) reuses ``x_t_for`` untouched.
        """
        with self._lock:
            if thr not in self._x_bf16:
                self._x_bf16[thr] = self.x_t_for(thr).astype(jnp.bfloat16)
            return self._x_bf16[thr]

    def chol_for(self, thr: int, ridge: float) -> jax.Array:
        """Block-Gram Cholesky factors for (thr, ridge), computed once."""
        from repro.core.solvebakp import block_gram_cholesky

        key = (int(thr), float(ridge))
        with self._lock:
            if key not in self.chol:
                obs_p, vars_p = self._require_x("chol_for").shape
                nblocks = -(-vars_p // thr)
                pad = nblocks * thr - vars_p
                x = self.x_pad
                if pad:
                    x = jnp.pad(x, ((0, 0), (0, pad)))
                xb = x.reshape(obs_p, nblocks, thr)
                self.chol[key] = block_gram_cholesky(xb, ridge)
            return self.chol[key]

    def x_for_placement(self, placement, smesh) -> jax.Array:
        """``x_pad`` laid out for a sharded placement's in_specs.

        The ``device_put`` (an all-device scatter or broadcast) happens once
        per (design, placement) and is memoised, so repeat solves onto the
        same mesh reuse the resident copy instead of resharding.
        """
        if placement is None or not placement.sharded:
            return self.x_pad
        from jax.sharding import NamedSharding, PartitionSpec as P
        with self._lock:
            if placement not in self._sharded:
                if placement.kind == "obs_sharded":
                    spec = P(smesh.data_axes, None)
                elif placement.kind == "rhs_sharded":
                    spec = P(None, None)  # replicated: devices share x
                elif placement.kind == "mesh_2d":
                    spec = P(smesh.data_axes, smesh.model_axis)
                else:
                    raise ValueError(
                        f"unknown placement kind {placement.kind!r}")
                self._sharded[placement] = jax.device_put(
                    self._require_x("x_for_placement"),
                    NamedSharding(smesh.mesh, spec))
            return self._sharded[placement]

    def warm_method_state(self, spec: SolverSpec) -> None:
        """Run ``spec.method``'s prepare hook (column-norm layouts, Gram
        factors, ...) so later solves find their derived state resident.
        Idempotent and thread-safe; serving pre-warm calls this off the
        solver thread."""
        entry = solver_method(spec.method)
        if entry.prepare is not None:
            entry.prepare(self, spec)

    # ------------------------------------------------- lane residency
    def bind_home(self, placement=None) -> str:
        """Bind (first-wins) and return this design's home placement kind.

        The home is where the design primarily serves — ``"single"`` or a
        sharded placement kind.  First-wins: a design warmed for an
        obs-sharded bucket keeps that home even when later single-device
        leftovers also solve against it, so eviction/streaming policies
        can ask "whose device memory does this design own?" with one read.
        """
        kind = placement.kind if placement is not None else "single"
        with self._lock:
            if self.home is None:
                self.home = kind
            return self.home

    def warm_lane_state(self, spec: SolverSpec, placement=None,
                        mesh=None) -> None:
        """Warm every lane-resident tier a (spec, placement) solve needs:
        the method's prepare hook (thr-padded norms, Gram factors, the
        fused kernel's transposed/bf16 copies) plus the placement's
        sharded device copy — and bind the design's home.  Idempotent;
        the serving cache / dispatcher pre-warm call this off the lane
        threads so first solves find their residents built.
        ``mesh`` defaults to the one bound at ``prepare`` time."""
        self.bind_home(placement)
        self.warm_method_state(spec)
        mesh = mesh if mesh is not None else self.mesh
        if (placement is not None and placement.sharded and mesh is not None
                and self.x_pad is not None):
            self.x_for_placement(placement, mesh)

    def resident_lanes(self) -> Tuple[str, ...]:
        """Which per-lane resident tiers this design currently holds:
        always ``"single"`` (``x_pad``), plus ``"fused"`` (transposed
        Pallas layout), ``"fused_bf16"`` (quantized tier) and each sharded
        placement kind with a resident mesh copy."""
        with self._lock:
            out = ["single"]
            if self._x_t:
                out.append("fused")
            if self._x_bf16:
                out.append("fused_bf16")
            out.extend(sorted({p.kind for p in self._sharded}))
            return tuple(out)

    # ---------------------------------------------------------------- solve
    def solve(
        self,
        y: jax.Array,
        a0: Optional[jax.Array] = None,
        *,
        spec: Optional[SolverSpec] = None,
        key: Optional[jax.Array] = None,
        tenant_id: Optional[str] = None,
        placement=None,
        mesh=None,
    ) -> SolveResult:
        """Solve ``x @ a ≈ y`` against this prepared design.

        Args:
          y: (obs,) right-hand side, or (obs, k) for a multi-RHS solve (one
            stream of ``x`` serves all k systems — methods with
            ``multi_rhs=False`` reject the 2-D form).
          a0: optional (vars,)/(vars, k) warm-start coefficients.  Direct
            methods ignore ``a0`` (see ``SolverSpec``); iterative methods
            start from it instead of zeros.
          spec: overrides the spec bound at ``prepare`` time (the serving
            engine shares one PreparedDesign across specs this way).
          key: PRNG key for ``order="random"``.
          tenant_id: when set and ``a0`` is None, warm-start from this
            tenant's last stored coefficients and store the new solution
            back afterwards (the serving warm-start protocol, available to
            direct users of the handle too).
          placement / mesh: mesh-sharded execution (serving placement layer;
            ``mesh`` defaults to the one bound at ``prepare`` time).

        Returns:
          ``SolveResult`` in this design's (padded) shapes.
        """
        spec = spec if spec is not None else self.spec
        if spec is None:
            raise ValueError(
                "no SolverSpec bound to this PreparedDesign; pass spec=")
        mesh = mesh if mesh is not None else self.mesh
        # Normalise to an ndim-carrying array but keep HOST buffers host:
        # the solver entry points auto-donate fresh in-jit transfers of
        # numpy operands (types.donate_default), which is how the serving
        # flush path sheds its steady-state HBM allocation.
        if not hasattr(y, "ndim"):
            y = np.asarray(y, np.float32)
        entry = ensure_precision_supported(spec)
        if self.x_pad is None and not entry.streams:
            raise UnsupportedSpecError(
                f"method {spec.method!r} cannot solve a non-resident design "
                f"(X blocks live in the design store, not on device); use a "
                f"streaming method {streaming_methods()}")
        if y.ndim == 2 and not entry.multi_rhs:
            raise ValueError(
                f"method {spec.method!r} does not support multi-RHS "
                f"y of shape {y.shape}")
        store_tenant = None
        if a0 is None and tenant_id is not None and entry.iterative:
            store_tenant = tenant_id
            warm = self.warm_coef(tenant_id)
            # A stored coefficient only warm-starts a compatible solve: the
            # kernels take (vars,) — broadcast over RHS — or exactly
            # (vars, k).  A tenant alternating RHS counts (say a (vars, 4)
            # multi-RHS fit followed by a single-RHS solve) falls back to a
            # cold start instead of crashing the kernel's a0 check.
            nvars = self.shape[1]
            nrhs = y.shape[1] if y.ndim == 2 else 1
            if warm is not None and warm.shape in ((nvars,), (nvars, nrhs)):
                a0 = jnp.asarray(warm)
        if a0 is not None and not entry.iterative:
            a0 = None  # direct methods ignore warm starts (SolverSpec doc)
        res = entry.solve(self, y, spec, a0=a0, key=key,
                          placement=placement, mesh=mesh)
        # A diverged solve's coefficients would poison the tenant's next
        # warm start (it would resume from the blown-up point); plain
        # budget exhaustion still retains — see warm_retention_ok.
        if store_tenant is not None and warm_retention_ok(res):
            self.store_coef(store_tenant, np.asarray(res.coef))
        return res


def prepare(
    x: jax.Array,
    spec: Optional[SolverSpec] = None,
    mesh=None,
    *,
    fingerprint: Optional[str] = None,
    max_tenants: int = 64,
) -> PreparedDesign:
    """Build a ``PreparedDesign`` for ``x`` (see module doc).

    Args:
      x: (obs, vars) design matrix; copied to device as fp32.
      spec: default ``SolverSpec`` for ``PreparedDesign.solve``.  When given,
        the method's prepare hook runs eagerly (column norms for its block
        width, Gram Cholesky factors, ...) so the first ``solve`` is as
        cheap as a repeat one.  Without it, pass ``spec=`` per solve.
      mesh: optional ``repro.serve.placement.ServeMesh`` bound as the
        default for placement-routed solves.
      fingerprint: caller-known identity for ``x`` (skips hashing the
        bytes); None defers to a lazy content hash on first
        ``design_key()`` access.
      max_tenants: LRU bound on retained per-tenant warm-start coefficients.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"x must be 2D (obs, vars), got {x.shape}")
    if spec is not None:
        # Fail fast on unknown methods and unsupported precisions
        # (UnsupportedSpecError) before paying the device transfer.
        ensure_precision_supported(spec)
    prepared = PreparedDesign(
        x_pad=x,
        spec=spec,
        fingerprint=fingerprint,
        mesh=mesh,
        max_tenants=max_tenants,
    )
    if spec is not None:
        prepared.warm_method_state(spec)
    return prepared
