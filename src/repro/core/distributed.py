"""Distributed SolveBakP — the paper's §6 parallelisation mapped onto a TPU mesh.

Four shardings (DESIGN.md §3/§6):

* **obs-sharded** (`solvebakp_obs_sharded`) — rows of ``x`` shard over the
  data-parallel mesh axes.  This is the paper's "only one column needs to be
  on the accelerator" memory story re-architected: every device holds a
  (obs/D × vars) shard and the residual shard that goes with it; the block
  inner products ⟨x_k, e⟩ become one fused ``psum`` of a (thr, k) partial per
  block step.  Per-device peak memory = shard + O(obs/D + vars), preserving
  the paper's O(m+n) *overhead* invariant per device.

* **vars-sharded** (`solvebakp_vars_sharded`) — columns shard over the model
  axis.  Each device updates its local block Jacobi-style from a shared
  residual, then the residual correction is a ``psum`` of the local rank-thr
  updates.  This is Algorithm 2's thread loop lifted across devices: the
  effective block size is ``n_devices * thr_local``, so the paper's
  "thr small w.r.t. vars" condition applies to the *global* block — we default
  to mode="gram" + omega damping to keep it robust.

* **2-D** (`solvebakp_2d`) — both of the above composed; inner products psum
  over the data axes, residual corrections psum over the model axis.

* **rhs-sharded** (`solvebakp_rhs_sharded`) — the multi-RHS ``k`` axis shards
  over the data axes while ``x`` is replicated: each device sweeps the SAME
  blocks against its own slice of right-hand sides, so one mesh-wide stream
  of ``x`` serves all k tenants of a giant same-design serving group.  The
  per-sweep stopping decision psums the local SSEs, so the sweep count (and
  the returned history) is the group-global one — bit-comparable with the
  single-device multi-RHS solve.

All variants accept ``y`` of shape (obs,) or (obs, k) and an optional warm
start ``a0`` of shape (vars,) or (vars, k), matching ``solvebakp``'s
single-device API, so ``repro.serve`` routes its coalesced multi-RHS and
warm-started buckets onto a mesh without changing semantics.

All four run under ``shard_map`` with explicit collectives so the dry-run
HLO shows exactly the communication the paper's algorithm requires — nothing
auto-inserted.  Programs are built once per (mesh, shape, static-knob)
combination and cached, so repeated serving flushes reuse the compiled
executable.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.types import SolveResult, safe_inv, sweep_stop_flags


def _psum(v, axes):
    return lax.psum(v, axes) if axes else v


def _bakp_local(x_loc, y_loc, a0_loc, atol_sse, rtol, *, nvars_loc: int,
                thr: int, max_iter: int, omega: float, mode: str,
                ridge: float, g_axes: Tuple[str, ...],
                corr_axes: Tuple[str, ...], sse_axes: Tuple[str, ...]):
    """Per-device SolveBakP sweeps over a local (rows × cols) shard.

    The same body serves every sharding; only the collective axes differ:
      * ``g_axes``    — block inner products ⟨x_k, e⟩ (and the block Gram /
                        column-norm factors) partial-sum over these axes;
      * ``corr_axes`` — the rank-thr residual correction psums over these
                        (Jacobi across column shards);
      * ``sse_axes``  — the per-sweep SSE psums over these, so the stopping
                        decision (and history) is global and every device
                        runs the same trip count.

    ``x_loc`` is (obs_loc, nvars_loc); ``y_loc``/``a0_loc`` carry the local
    slice of right-hand sides, (obs_loc, k_loc) / (nvars_loc·padded, k_loc).
    ``a0_loc`` may be None (cold start — skips the residual matmul).
    ``atol_sse``/``rtol`` are *traced* replicated scalars, not compile-time
    constants: the serving engine's padding-corrected atol varies with the
    real (unpadded) group size, and must not retrace the shard_map program
    — mirroring the single-device solvers, where they are jit operands.
    """
    obs_loc = x_loc.shape[0]
    nrhs_loc = y_loc.shape[1]
    nblocks = -(-nvars_loc // thr)
    pad = nblocks * thr - nvars_loc
    if pad:
        x_loc = jnp.pad(x_loc, ((0, 0), (0, pad)))
    xb = x_loc.reshape(obs_loc, nblocks, thr)
    mask = (jnp.arange(nblocks * thr) < nvars_loc).astype(jnp.float32)
    mask_b = mask.reshape(nblocks, thr)

    xf = xb.astype(jnp.float32)
    if mode == "gram":
        gram = _psum(jnp.einsum("obt,obs->bts", xf, xf), g_axes)
        gram = gram + ridge * jnp.eye(thr, dtype=jnp.float32)[None]
        factor = jax.vmap(
            lambda g: jax.scipy.linalg.cholesky(g, lower=True))(gram)
    else:
        cn = _psum(jnp.einsum("obt,obt->bt", xf, xf), g_axes)
        factor = safe_inv(cn) * mask_b

    if a0_loc is None:
        ab0 = jnp.zeros((nblocks, thr, nrhs_loc), jnp.float32)
        e0 = y_loc.astype(jnp.float32)
    else:
        a0p = a0_loc.astype(jnp.float32)
        if pad:
            a0p = jnp.pad(a0p, ((0, pad), (0, 0)))
        ab0 = a0p.reshape(nblocks, thr, nrhs_loc)
        # Warm residual: column shards each contribute their slice of x@a0.
        e0 = y_loc.astype(jnp.float32) - _psum(
            x_loc.astype(jnp.float32) @ a0p, corr_axes)
    sse0 = _psum(jnp.vdot(e0, e0), sse_axes)
    history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)

    def block_step(carry, b):
        ab, e = carry
        xblk = lax.dynamic_index_in_dim(xb, b, axis=1, keepdims=False)
        xblk = xblk.astype(jnp.float32)
        g = _psum(xblk.T @ e, g_axes)  # (thr, k) fused collective per block
        if mode == "jacobi":
            da = g * lax.dynamic_index_in_dim(
                factor, b, 0, keepdims=False)[:, None]
        else:
            lb = lax.dynamic_index_in_dim(factor, b, 0, keepdims=False)
            mb = lax.dynamic_index_in_dim(mask_b, b, 0, keepdims=False)
            da = jax.scipy.linalg.cho_solve((lb, True), g) * mb[:, None]
        da = omega * da
        # Residual correction must include every column shard's update:
        # Jacobi across corr_axes (paper's thread loop, lifted to devices).
        e = e - _psum(xblk @ da, corr_axes)
        ab = lax.dynamic_update_index_in_dim(ab, ab[b] + da, b, axis=0)
        return (ab, e), None

    def sweep_body(state):
        ab, e, i, sse_prev, history, converged, stop = state
        (ab, e), _ = lax.scan(block_step, (ab, e), jnp.arange(nblocks))
        sse = _psum(jnp.vdot(e, e), sse_axes)
        history = history.at[i].set(sse)
        converged, stop = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                           rtol)
        return ab, e, i + 1, sse, history, converged, stop

    def cond(state):
        _, _, i, _, _, _, stop = state
        return (i < max_iter) & ~stop

    ab, e, n, sse, history, converged, _ = lax.while_loop(
        cond, sweep_body,
        (ab0, e0, jnp.int32(0), sse0, history0, jnp.bool_(False),
         jnp.bool_(False)))
    coef_loc = ab.reshape(nblocks * thr, nrhs_loc)[:nvars_loc]
    return coef_loc, e, sse, n, converged, history


# Per-kind shard_map spec table: (x, y, a0) in-specs and
# (coef, residual) out-specs as functions of the axis names, plus which
# collective axes the local kernel uses.  d = data axes tuple, m = model.
_KINDS = {
    # kind: (in_x, in_y, in_a0, out_coef, out_e, g_axes, corr_axes, sse_axes)
    "obs": lambda d, m: (P(d, None), P(d, None), P(None, None),
                         P(None, None), P(d, None), d, (), d),
    "vars": lambda d, m: (P(None, m), P(None, None), P(m, None),
                          P(m, None), P(None, None), (), (m,), ()),
    "2d": lambda d, m: (P(d, m), P(d, None), P(m, None),
                        P(m, None), P(d, None), d, (m,), d),
    "rhs": lambda d, m: (P(None, None), P(None, d), P(None, d),
                         P(None, d), P(None, d), (), (), d),
}


@functools.lru_cache(maxsize=128)
def _sharded_program(kind: str, mesh: Mesh, xshape: Tuple[int, int],
                     nrhs: int, warm: bool, data_axes: Tuple[str, ...],
                     model_axis: Optional[str], thr: int, max_iter: int,
                     omega: float, mode: str, ridge: float):
    """Build (once) the jitted shard_map program for one solver config.

    The cache key is the full static configuration — mesh object, padded
    shape, RHS count, warm/cold — so serving flushes that repeat a bucket
    reuse the compiled executable instead of re-tracing the shard_map.
    Tolerances (``atol_sse``/``rtol``) are traced replicated operands, NOT
    part of the key: per-request values never recompile.  ``warm=False``
    programs never take an ``a0`` operand (cold solves skip the warm path's
    extra residual matmul, mirroring the engine's jit signature split for
    single-device solves).
    """
    obs, nvars = xshape
    in_x, in_y, in_a0, out_coef, out_e, g_axes, corr_axes, sse_axes = \
        _KINDS[kind](data_axes, model_axis)
    nvars_loc = nvars // mesh.shape[model_axis] if kind in ("vars", "2d") \
        else nvars
    kw = dict(nvars_loc=nvars_loc, thr=thr, max_iter=max_iter, omega=omega,
              mode=mode, ridge=ridge, g_axes=g_axes, corr_axes=corr_axes,
              sse_axes=sse_axes)
    out_specs = (out_coef, out_e, P(), P(), P(), P(None))

    if warm:
        def run(x_loc, y_loc, a0_loc, atol_sse, rtol):
            return _bakp_local(x_loc, y_loc, a0_loc, atol_sse, rtol, **kw)
        in_specs = (in_x, in_y, in_a0, P(), P())
    else:
        def run(x_loc, y_loc, atol_sse, rtol):
            return _bakp_local(x_loc, y_loc, None, atol_sse, rtol, **kw)
        in_specs = (in_x, in_y, P(), P())
    return jax.jit(shard_map(run, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


def _solve_sharded(kind, x, y, mesh, *, data_axes, model_axis, thr, max_iter,
                   atol, rtol, omega, mode, ridge, a0):
    """Shared driver: normalise y/a0, run the cached program, reshape back."""
    obs, nvars = x.shape
    if y.ndim not in (1, 2):
        raise ValueError(f"y must be (obs,) or (obs, k), got {y.shape}")
    multi = y.ndim == 2
    nrhs = y.shape[1] if multi else 1
    y2 = jnp.asarray(y).reshape(obs, nrhs)
    if a0 is not None:
        a0 = jnp.asarray(a0)
        if a0.shape not in ((nvars,), (nvars, nrhs)):
            raise ValueError(
                f"a0 must be ({nvars},) or ({nvars}, {nrhs}) matching x "
                f"columns and y RHS count, got {a0.shape}")
        # (vars,) broadcasts across all right-hand sides; materialised so
        # rhs-sharding can slice it per device like any other (vars, k).
        a0 = jnp.broadcast_to(a0.reshape(nvars, -1), (nvars, nrhs))

    data_axes = tuple(data_axes)
    dsize = 1
    for ax in data_axes:
        dsize *= mesh.shape[ax]
    if kind in ("obs", "2d") and obs % dsize:
        raise ValueError(f"obs={obs} must divide data axes size {dsize}")
    if kind in ("vars", "2d"):
        msize = mesh.shape[model_axis]
        if nvars % msize:
            raise ValueError(
                f"vars={nvars} must divide model axis size {msize}")
    if kind == "rhs":
        if not multi:
            raise ValueError("rhs-sharded solve needs multi-RHS y=(obs, k)")
        if nrhs % dsize:
            raise ValueError(f"k={nrhs} must divide data axes size {dsize}")

    program = _sharded_program(
        kind, mesh, (obs, nvars), nrhs, a0 is not None, data_axes,
        model_axis, int(thr), int(max_iter), float(omega), mode,
        float(ridge))
    atol_sse = jnp.float32(float(obs) * float(nrhs) * float(atol) ** 2)
    rtol_t = jnp.float32(rtol)
    args = ((x, y2) if a0 is None else (x, y2, a0)) + (atol_sse, rtol_t)
    coef, e, sse, n, converged, history = program(*args)
    if not multi:
        coef, e = coef[:, 0], e[:, 0]
    return SolveResult(coef, e, sse, n, converged, history)


def solvebakp_obs_sharded(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    mode: str = "gram",
    ridge: float = 1e-6,
    a0: Optional[jax.Array] = None,
) -> SolveResult:
    """SolveBakP with rows sharded over ``data_axes`` of ``mesh``.

    ``x`` is (obs, vars) with obs divisible by the product of data axis
    sizes; ``y`` is (obs,) or (obs, k); ``a0`` is an optional (vars,) or
    (vars, k) warm start (replicated).  Returns a replicated SolveResult
    (residual stays obs-sharded).  Block structure and update order match
    the single-device ``solvebakp`` exactly — only the inner products gain
    a psum — so the sweep iterates agree to reduction-order rounding.
    """
    return _solve_sharded(
        "obs", x, y, mesh, data_axes=data_axes, model_axis=None, thr=thr,
        max_iter=max_iter, atol=atol, rtol=rtol, omega=omega, mode=mode,
        ridge=ridge, a0=a0)


def solvebakp_vars_sharded(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    model_axis: str = "model",
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 0.5,
    mode: str = "gram",
    ridge: float = 1e-6,
    a0: Optional[jax.Array] = None,
) -> SolveResult:
    """SolveBakP with columns sharded over ``model_axis``.

    Each device sweeps its local blocks Jacobi-style against a replicated
    residual; every block step ends with a psum'd rank-(D·thr) residual
    correction.  Defaults to gram + ω=0.5 damping because the effective
    cross-device block is large (see module docstring).  ``y`` may be
    (obs, k); ``a0`` warm starts are column-sharded with the coefficients.
    """
    return _solve_sharded(
        "vars", x, y, mesh, data_axes=(), model_axis=model_axis, thr=thr,
        max_iter=max_iter, atol=atol, rtol=rtol, omega=omega, mode=mode,
        ridge=ridge, a0=a0)


def solvebakp_2d(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    model_axis: str = "model",
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 0.5,
    mode: str = "gram",
    ridge: float = 1e-6,
    a0: Optional[jax.Array] = None,
) -> SolveResult:
    """Fully 2-D sharded SolveBakP: obs over data axes, vars over model axis.

    ⟨x_k, e⟩ partials psum over data; residual corrections psum over model.
    This is the production configuration for pod-scale systems (e.g.
    obs=10⁹ tokens × vars=10⁵ features on a 16×16 mesh).  Multi-RHS ``y``
    and warm starts thread through like the 1-D variants.
    """
    return _solve_sharded(
        "2d", x, y, mesh, data_axes=data_axes, model_axis=model_axis,
        thr=thr, max_iter=max_iter, atol=atol, rtol=rtol, omega=omega,
        mode=mode, ridge=ridge, a0=a0)


def solvebakp_rhs_sharded(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    mode: str = "gram",
    ridge: float = 1e-6,
    a0: Optional[jax.Array] = None,
) -> SolveResult:
    """SolveBakP with the multi-RHS ``k`` axis sharded over ``data_axes``.

    ``x`` is replicated; each device runs the identical block sweeps against
    its own (obs, k/D) slice of right-hand sides — the serving engine's
    giant same-design groups scaled across a mesh, one stream of ``x`` per
    device serving k/D tenants.  The only collective is the per-sweep SSE
    psum, which makes the stopping decision (and history) group-global:
    iterates and sweep counts match the single-device multi-RHS solve
    exactly, because per-RHS coordinate updates never interact.

    ``y`` must be (obs, k) with k divisible by the data axes product;
    ``a0`` may be (vars,) (broadcast) or (vars, k) (sharded with ``y``).
    """
    return _solve_sharded(
        "rhs", x, y, mesh, data_axes=data_axes, model_axis=None, thr=thr,
        max_iter=max_iter, atol=atol, rtol=rtol, omega=omega, mode=mode,
        ridge=ridge, a0=a0)
