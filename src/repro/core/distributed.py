"""Distributed SolveBakP — the paper's §6 parallelisation mapped onto a TPU mesh.

Three shardings (DESIGN.md §3/§6):

* **obs-sharded** (`solvebakp_obs_sharded`) — rows of ``x`` shard over the
  data-parallel mesh axes.  This is the paper's "only one column needs to be
  on the accelerator" memory story re-architected: every device holds a
  (obs/D × vars) shard and the residual shard that goes with it; the block
  inner products ⟨x_k, e⟩ become one fused ``psum`` of a (thr,) partial per
  block step.  Per-device peak memory = shard + O(obs/D + vars), preserving
  the paper's O(m+n) *overhead* invariant per device.

* **vars-sharded** (`solvebakp_vars_sharded`) — columns shard over the model
  axis.  Each device updates its local block Jacobi-style from a shared
  residual, then the residual correction is a ``psum`` of the local rank-thr
  updates.  This is Algorithm 2's thread loop lifted across devices: the
  effective block size is ``n_devices * thr_local``, so the paper's
  "thr small w.r.t. vars" condition applies to the *global* block — we default
  to mode="gram" + omega damping to keep it robust.

* **2-D** (`solvebakp_2d`) — both of the above composed; inner products psum
  over the data axes, residual corrections psum over the model axis.

All three run under ``shard_map`` with explicit collectives so the dry-run
HLO shows exactly the communication the paper's algorithm requires — nothing
auto-inserted.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.types import SolveResult, safe_inv


def _block_solve_local(
    xb_loc, e_loc, ab, chol_or_invcn, mask_b, *, mode, omega, data_axes
):
    """One full sweep over the blocks of a local (obs_shard × vars) matrix.

    xb_loc: (obs_loc, nblocks, thr); e_loc: (obs_loc,).
    Inner products are psum'd over ``data_axes`` when given.
    """
    nblocks = xb_loc.shape[1]

    def block_step(carry, b):
        ab, e = carry
        xblk = lax.dynamic_index_in_dim(xb_loc, b, axis=1, keepdims=False)
        xblk = xblk.astype(jnp.float32)
        g = xblk.T @ e
        if data_axes:
            g = lax.psum(g, data_axes)  # one fused (thr,) collective per block
        if mode == "jacobi":
            inv_cn = lax.dynamic_index_in_dim(chol_or_invcn, b, 0, keepdims=False)
            da = g * inv_cn
        else:
            lb = lax.dynamic_index_in_dim(chol_or_invcn, b, 0, keepdims=False)
            mb = lax.dynamic_index_in_dim(mask_b, b, 0, keepdims=False)
            da = jax.scipy.linalg.cho_solve((lb, True), g) * mb
        da = omega * da
        e = e - xblk @ da
        ab = lax.dynamic_update_index_in_dim(ab, ab[b] + da, b, axis=0)
        return (ab, e), None

    (ab, e_loc), _ = lax.scan(block_step, (ab, e_loc), jnp.arange(nblocks))
    return ab, e_loc


def solvebakp_obs_sharded(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    mode: str = "gram",
    ridge: float = 1e-6,
) -> SolveResult:
    """SolveBakP with rows sharded over ``data_axes`` of ``mesh``.

    ``x`` is (obs, vars) with obs divisible by the product of data axis sizes.
    Returns a replicated SolveResult (residual stays obs-sharded).
    """
    obs, nvars = x.shape
    nblocks = -(-nvars // thr)
    pad = nblocks * thr - nvars
    data_axes = tuple(data_axes)
    dspec = P(data_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(data_axes, None), dspec),
        out_specs=(P(None), dspec, P(), P(), P(), P(None)),
        check_rep=False,
    )
    def run(x_loc, y_loc):
        obs_loc = x_loc.shape[0]
        if pad:
            x_loc = jnp.pad(x_loc, ((0, 0), (0, pad)))
        xb = x_loc.reshape(obs_loc, nblocks, thr)
        mask = (jnp.arange(nblocks * thr) < nvars).astype(jnp.float32)
        mask_b = mask.reshape(nblocks, thr)

        xf = xb.astype(jnp.float32)
        if mode == "gram":
            gram = lax.psum(jnp.einsum("obt,obs->bts", xf, xf), data_axes)
            gram = gram + ridge * jnp.eye(thr, dtype=jnp.float32)[None]
            factor = jax.vmap(
                lambda g: jax.scipy.linalg.cholesky(g, lower=True))(gram)
        else:
            cn = lax.psum(jnp.einsum("obt,obt->bt", xf, xf), data_axes)
            factor = safe_inv(cn) * mask_b

        ab = jnp.zeros((nblocks, thr), jnp.float32)
        e0 = y_loc.astype(jnp.float32)
        sse0 = lax.psum(jnp.vdot(e0, e0), data_axes)
        history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)
        atol_sse = jnp.float32(obs) * jnp.float32(atol) ** 2

        def sweep_body(state):
            ab, e, i, sse_prev, history, converged = state
            ab, e = _block_solve_local(
                xb, e, ab, factor, mask_b,
                mode=mode, omega=omega, data_axes=data_axes)
            sse = lax.psum(jnp.vdot(e, e), data_axes)
            history = history.at[i].set(sse)
            hit_atol = (atol_sse > 0.0) & (sse <= atol_sse)
            hit_rtol = (rtol > 0.0) & ((sse_prev - sse) <= rtol * sse_prev)
            return ab, e, i + 1, sse, history, hit_atol | hit_rtol

        def cond(state):
            _, _, i, _, _, converged = state
            return (i < max_iter) & ~converged

        ab, e, n, sse, history, converged = lax.while_loop(
            cond, sweep_body,
            (ab, e0, jnp.int32(0), sse0, history0, jnp.bool_(False)))
        coef = ab.reshape(-1)[:nvars]
        return coef, e, sse, n, converged, history

    coef, e, sse, n, converged, history = run(x, y)
    return SolveResult(coef, e, sse, n, converged, history)


def solvebakp_vars_sharded(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    model_axis: str = "model",
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 0.5,
    mode: str = "gram",
    ridge: float = 1e-6,
) -> SolveResult:
    """SolveBakP with columns sharded over ``model_axis``.

    Each device sweeps its local blocks Jacobi-style against a replicated
    residual; every block step ends with a psum'd rank-(D·thr) residual
    correction.  Defaults to gram + ω=0.5 damping because the effective
    cross-device block is large (see module docstring).
    """
    obs, nvars = x.shape
    d = mesh.shape[model_axis]
    if nvars % d:
        raise ValueError(f"vars={nvars} must divide model axis size {d}")
    nvars_loc = nvars // d
    nblocks = -(-nvars_loc // thr)
    pad = nblocks * thr - nvars_loc

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, model_axis), P(None)),
        out_specs=(P(model_axis), P(None), P(), P(), P(), P(None)),
        check_rep=False,
    )
    def run(x_loc, y_rep):
        obs_loc = x_loc.shape[0]
        if pad:
            x_loc = jnp.pad(x_loc, ((0, 0), (0, pad)))
        xb = x_loc.reshape(obs_loc, nblocks, thr)
        mask = (jnp.arange(nblocks * thr) < nvars_loc).astype(jnp.float32)
        mask_b = mask.reshape(nblocks, thr)
        xf = xb.astype(jnp.float32)
        if mode == "gram":
            gram = jnp.einsum("obt,obs->bts", xf, xf)
            gram = gram + ridge * jnp.eye(thr, dtype=jnp.float32)[None]
            factor = jax.vmap(
                lambda g: jax.scipy.linalg.cholesky(g, lower=True))(gram)
        else:
            factor = safe_inv(jnp.einsum("obt,obt->bt", xf, xf)) * mask_b

        ab0 = jnp.zeros((nblocks, thr), jnp.float32)
        e0 = y_rep.astype(jnp.float32)
        sse0 = jnp.vdot(e0, e0)
        history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)
        atol_sse = jnp.float32(obs) * jnp.float32(atol) ** 2

        def block_step(carry, b):
            ab, e = carry
            xblk = lax.dynamic_index_in_dim(xb, b, axis=1, keepdims=False)
            xblk = xblk.astype(jnp.float32)
            g = xblk.T @ e  # local columns vs replicated residual
            if mode == "jacobi":
                da = g * lax.dynamic_index_in_dim(factor, b, 0, keepdims=False)
            else:
                lb = lax.dynamic_index_in_dim(factor, b, 0, keepdims=False)
                mb = lax.dynamic_index_in_dim(mask_b, b, 0, keepdims=False)
                da = jax.scipy.linalg.cho_solve((lb, True), g) * mb
            da = omega * da
            # Residual correction must include every device's update: Jacobi
            # across the model axis (paper's thread loop, lifted to devices).
            e = e - lax.psum(xblk @ da, model_axis)
            ab = lax.dynamic_update_index_in_dim(ab, ab[b] + da, b, axis=0)
            return (ab, e), None

        def sweep_body(state):
            ab, e, i, sse_prev, history, converged = state
            (ab, e), _ = lax.scan(block_step, (ab, e), jnp.arange(nblocks))
            sse = jnp.vdot(e, e)
            history = history.at[i].set(sse)
            hit_atol = (atol_sse > 0.0) & (sse <= atol_sse)
            hit_rtol = (rtol > 0.0) & ((sse_prev - sse) <= rtol * sse_prev)
            return ab, e, i + 1, sse, history, hit_atol | hit_rtol

        def cond(state):
            _, _, i, _, _, converged = state
            return (i < max_iter) & ~converged

        ab, e, n, sse, converged_h, converged = lax.while_loop(
            cond, sweep_body,
            (ab0, e0, jnp.int32(0), sse0, history0, jnp.bool_(False)))
        coef_loc = ab.reshape(-1)[:nvars_loc]
        return coef_loc, e, sse, n, converged, converged_h

    coef, e, sse, n, converged, history = run(x, y)
    return SolveResult(coef, e, sse, n, converged, history)


def solvebakp_2d(
    x: jax.Array,
    y: jax.Array,
    mesh: Mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    model_axis: str = "model",
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 0.5,
    mode: str = "gram",
    ridge: float = 1e-6,
) -> SolveResult:
    """Fully 2-D sharded SolveBakP: obs over data axes, vars over model axis.

    ⟨x_k, e⟩ partials psum over data; residual corrections psum over model.
    This is the production configuration for pod-scale systems (e.g.
    obs=10⁹ tokens × vars=10⁵ features on a 16×16 mesh).
    """
    obs, nvars = x.shape
    data_axes = tuple(data_axes)
    d = mesh.shape[model_axis]
    if nvars % d:
        raise ValueError(f"vars={nvars} must divide model axis size {d}")
    nvars_loc = nvars // d
    nblocks = -(-nvars_loc // thr)
    pad = nblocks * thr - nvars_loc

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(data_axes, model_axis), P(data_axes)),
        out_specs=(P(model_axis), P(data_axes), P(), P(), P(), P(None)),
        check_rep=False,
    )
    def run(x_loc, y_loc):
        obs_loc = x_loc.shape[0]
        if pad:
            x_loc = jnp.pad(x_loc, ((0, 0), (0, pad)))
        xb = x_loc.reshape(obs_loc, nblocks, thr)
        mask = (jnp.arange(nblocks * thr) < nvars_loc).astype(jnp.float32)
        mask_b = mask.reshape(nblocks, thr)
        xf = xb.astype(jnp.float32)
        if mode == "gram":
            gram = lax.psum(jnp.einsum("obt,obs->bts", xf, xf), data_axes)
            gram = gram + ridge * jnp.eye(thr, dtype=jnp.float32)[None]
            factor = jax.vmap(
                lambda g: jax.scipy.linalg.cholesky(g, lower=True))(gram)
        else:
            cn = lax.psum(jnp.einsum("obt,obt->bt", xf, xf), data_axes)
            factor = safe_inv(cn) * mask_b

        ab0 = jnp.zeros((nblocks, thr), jnp.float32)
        e0 = y_loc.astype(jnp.float32)
        sse0 = lax.psum(jnp.vdot(e0, e0), data_axes)
        history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)
        atol_sse = jnp.float32(obs) * jnp.float32(atol) ** 2

        def block_step(carry, b):
            ab, e = carry
            xblk = lax.dynamic_index_in_dim(xb, b, axis=1, keepdims=False)
            xblk = xblk.astype(jnp.float32)
            g = lax.psum(xblk.T @ e, data_axes)
            if mode == "jacobi":
                da = g * lax.dynamic_index_in_dim(factor, b, 0, keepdims=False)
            else:
                lb = lax.dynamic_index_in_dim(factor, b, 0, keepdims=False)
                mb = lax.dynamic_index_in_dim(mask_b, b, 0, keepdims=False)
                da = jax.scipy.linalg.cho_solve((lb, True), g) * mb
            da = omega * da
            e = e - lax.psum(xblk @ da, model_axis)
            ab = lax.dynamic_update_index_in_dim(ab, ab[b] + da, b, axis=0)
            return (ab, e), None

        def sweep_body(state):
            ab, e, i, sse_prev, history, converged = state
            (ab, e), _ = lax.scan(block_step, (ab, e), jnp.arange(nblocks))
            sse = lax.psum(jnp.vdot(e, e), data_axes)
            history = history.at[i].set(sse)
            hit_atol = (atol_sse > 0.0) & (sse <= atol_sse)
            hit_rtol = (rtol > 0.0) & ((sse_prev - sse) <= rtol * sse_prev)
            return ab, e, i + 1, sse, history, hit_atol | hit_rtol

        def cond(state):
            _, _, i, _, _, converged = state
            return (i < max_iter) & ~converged

        ab, e, n, sse, history, converged = lax.while_loop(
            cond, sweep_body,
            (ab0, e0, jnp.int32(0), sse0, history0, jnp.bool_(False)))
        coef_loc = ab.reshape(-1)[:nvars_loc]
        return coef_loc, e, sse, n, converged, history

    coef, e, sse, n, converged, history = run(x, y)
    return SolveResult(coef, e, sse, n, converged, history)
