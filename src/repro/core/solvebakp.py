"""SolveBakP — Algorithm 2 of the paper (block-parallel CD) + Gram-block upgrade.

The paper parallelises Algorithm 1 by processing ``thr`` columns at a time:
the per-column steps ``da_k = ⟨x_k, e⟩ / ⟨x_k, x_k⟩`` inside a block all read
the *same* residual (Jacobi-within-block), then the residual is corrected once
per block with a rank-``thr`` update

    e ← e - x_blk @ (a_blk - aprev_blk).

On TPU the block update is an MXU matmul and the per-block inner products are
a single (thr × obs)·(obs,) matvec, so this variant is the natural TPU
formulation of the paper's multi-thread loop (DESIGN.md §3).

``mode="jacobi"`` is the paper-faithful Algorithm 2.

``mode="gram"`` is a *beyond-paper* upgrade (recorded separately in
EXPERIMENTS.md §Perf): solve the thr×thr block normal equations exactly,

    da = (x_blkᵀ x_blk + ridge·I)⁻¹ x_blkᵀ e,

i.e. exact block Gauss–Seidel.  The Cholesky factors of all block Gram
matrices are computed once (O(obs·vars·thr) flops, amortised over sweeps) so
the per-sweep cost stays O(obs·vars) like the paper's variant, but each sweep
makes strictly more progress: within-block correlations no longer slow
convergence, and ``thr`` can be as large as VMEM allows instead of the paper's
"small with respect to vars" requirement.

``omega`` is an optional over/under-relaxation factor (beyond-paper; 1.0 is
faithful).  Jacobi-within-block can diverge when columns inside a block are
strongly correlated — the paper's remedy is small ``thr``; ours is ``omega<1``
or ``mode="gram"``.

Multi-RHS: ``y`` may be ``(obs, k)`` — the per-block inner products become a
(thr × obs)·(obs × k) matmul and the residual correction a rank-``thr``
update of a (obs, k) residual, so one stream of ``x`` (and one block-Gram
factorisation in ``mode="gram"``) serves all k systems.  This is the core
primitive behind ``repro.serve``'s same-design request coalescing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import (SolveResult, column_norms_sq, donate_default,
                              safe_inv, sweep_stop_flags)


def _pad_cols(x: jax.Array, thr: int):
    """Zero-pad columns of x to a multiple of thr. Returns (x_pad, mask)."""
    obs, nvars = x.shape
    nblocks = -(-nvars // thr)
    pad = nblocks * thr - nvars
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    mask = (jnp.arange(nblocks * thr) < nvars).astype(jnp.float32)
    return x, mask, nblocks


def block_gram_cholesky(xb: jax.Array, ridge: float) -> jax.Array:
    """Cholesky factors of per-block Gram matrices.

    Args:
      xb: (obs, nblocks, thr) blocked view of the (padded) input matrix.
      ridge: Tikhonov term added to the diagonal; also makes padded (zero)
        columns well-posed.
    Returns:
      (nblocks, thr, thr) lower Cholesky factors in fp32.
    """
    xf = xb.astype(jnp.float32)
    gram = jnp.einsum("obt,obs->bts", xf, xf)
    thr = xb.shape[-1]
    gram = gram + ridge * jnp.eye(thr, dtype=jnp.float32)[None]
    return jax.vmap(lambda g: jax.scipy.linalg.cholesky(g, lower=True))(gram)


def _solvebakp_impl(
    x: jax.Array,
    y: jax.Array,
    a0: Optional[jax.Array],
    cn: Optional[jax.Array],
    chol: Optional[jax.Array],
    atol,
    rtol,
    omega,
    ridge,
    *,
    thr: int,
    max_iter: int,
    mode: str,
) -> SolveResult:
    obs, nvars = x.shape
    if y.ndim not in (1, 2):
        raise ValueError(f"y must be (obs,) or (obs, k), got {y.shape}")
    multi = y.ndim == 2
    nrhs = y.shape[1] if multi else 1
    y2 = y.reshape(obs, nrhs)
    if a0 is not None and a0.shape not in ((nvars,), (nvars, nrhs)):
        raise ValueError(
            f"a0 must be ({nvars},) or ({nvars}, {nrhs}) matching x columns "
            f"and y RHS count, got {a0.shape}")
    x_pad, mask, nblocks = _pad_cols(x, thr)
    xb = x_pad.reshape(obs, nblocks, thr)

    if cn is None:
        cn = column_norms_sq(x_pad)
    inv_cn = (safe_inv(cn) * mask).reshape(nblocks, thr)
    mask_b = mask.reshape(nblocks, thr)

    if mode == "gram":
        if chol is None:
            chol = block_gram_cholesky(xb, ridge)
    elif mode == "jacobi":
        chol = None
    else:
        raise ValueError(f"unknown mode {mode!r}")

    a = jnp.zeros((nblocks * thr, nrhs), jnp.float32)
    if a0 is not None:  # (vars,) broadcasts across all right-hand sides
        a = a.at[:nvars].set(jnp.broadcast_to(
            a0.astype(jnp.float32).reshape(nvars, -1), (nvars, nrhs)))
    e0 = y2.astype(jnp.float32) - x_pad.astype(jnp.float32) @ a
    sse0 = jnp.vdot(e0, e0)
    history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)
    atol_sse = jnp.float32(obs * nrhs) * jnp.float32(atol) ** 2
    ab0 = a.reshape(nblocks, thr, nrhs)

    def block_step(carry, b):
        ab, e = carry
        xblk = lax.dynamic_index_in_dim(xb, b, axis=1, keepdims=False)
        xblk = xblk.astype(jnp.float32)  # (obs, thr)
        g = xblk.T @ e  # (thr, k)  ⟨x_k, e⟩ for all k in block, all RHS
        if mode == "jacobi":
            da = g * inv_cn[b][:, None]
        else:
            lb = lax.dynamic_index_in_dim(chol, b, axis=0, keepdims=False)
            da = jax.scipy.linalg.cho_solve((lb, True), g) * mask_b[b][:, None]
        da = omega * da
        e = e - xblk @ da  # paper line 9 (rank-thr residual correction)
        ab = lax.dynamic_update_index_in_dim(ab, ab[b] + da, b, axis=0)
        return (ab, e), None

    def sweep_body(state):
        ab, e, i, sse_prev, history, converged, stop = state
        (ab, e), _ = lax.scan(block_step, (ab, e), jnp.arange(nblocks))
        sse = jnp.vdot(e, e)
        history = history.at[i].set(sse)
        converged, stop = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                           rtol)
        return ab, e, i + 1, sse, history, converged, stop

    def cond(state):
        _, _, i, _, _, _, stop = state
        return (i < max_iter) & ~stop

    ab, e, n, sse, history, converged, _ = lax.while_loop(
        cond, sweep_body,
        (ab0, e0, jnp.int32(0), sse0, history0, jnp.bool_(False),
         jnp.bool_(False))
    )
    coef = ab.reshape(nblocks * thr, nrhs)[:nvars]
    if not multi:
        coef, e = coef[:, 0], e[:, 0]
    return SolveResult(coef, e, sse, n, converged, history)


@functools.lru_cache(maxsize=None)
def _jitted_solvebakp(thr, max_iter, mode, donate):
    return jax.jit(
        functools.partial(_solvebakp_impl, thr=thr, max_iter=max_iter,
                          mode=mode),
        donate_argnums=(1, 2) if donate else (),   # y, a0
    )


def solvebakp(
    x: jax.Array,
    y: jax.Array,
    *,
    thr: int = 128,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    omega: float = 1.0,
    mode: str = "jacobi",
    ridge: float = 1e-6,
    a0: Optional[jax.Array] = None,
    cn: Optional[jax.Array] = None,
    chol: Optional[jax.Array] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Algorithm 2 (SolveBakP), blocked over ``thr`` columns.

    Args:
      x: (obs, vars) input matrix.
      y: (obs,) right-hand side, or (obs, k) for k right-hand sides solved
        in one pass over ``x`` (multi-RHS; see module doc).
      thr: block width (the paper's thread-count parameter).  Multiples of
        128 line up with TPU lanes/MXU tiles.
      max_iter / atol / rtol: as in ``solvebak``.
      omega: relaxation factor applied to every block update (1.0 = paper).
      mode: "jacobi" (paper Algorithm 2) or "gram" (exact block CD).
      ridge: diagonal regulariser for mode="gram".
      a0: optional initial coefficients, (vars,) or (vars, k); a (vars,)
        guess with multi-RHS ``y`` broadcasts across all k.
      cn: optional precomputed squared column norms of the *padded* matrix,
        shape (nblocks*thr,) — see ``repro.serve.cache``.
      chol: optional precomputed ``block_gram_cholesky(xb, ridge)`` factors,
        shape (nblocks, thr, thr); only used for mode="gram".  Repeated-X
        serving amortises this O(obs·vars·thr) factorisation across requests.
      donate: donate the ``y``/``a0`` buffers to the solve (cuts
        steady-state HBM allocation on the serving flush path).  Default:
        auto-donate only host (numpy) operands on accelerator backends at
        top level; see ``solvebak``.

    Returns:
      SolveResult (coef truncated back to the unpadded ``vars``); multi-RHS
      input gives (vars, k) coef, (obs, k) residual and total-SSE scalars.
    """
    fn = _jitted_solvebakp(int(thr), int(max_iter), mode,
                           donate_default(donate, y, a0))
    return fn(x, y, a0, cn, chol, atol, rtol, omega, ridge)
