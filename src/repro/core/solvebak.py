"""SolveBak — Algorithm 1 of the paper, bit-faithful serial coordinate descent.

For each column ``j`` (cyclically, or in a fresh random order per sweep):

    da   = ⟨x_j, e⟩ / ⟨x_j, x_j⟩
    e   ←  e - x_j * da
    a_j ←  a_j + da

One sweep costs O(obs * vars) flops and touches each element of ``x`` exactly
once; auxiliary memory is O(obs + vars).  This module is the *paper-faithful
baseline*: the TPU-optimised variants live in ``solvebakp.py`` (block CD),
``gram_cd.py`` via ``solvebakp(mode="gram")``, and ``repro.kernels``.

All inner products accumulate in fp32 regardless of the storage dtype of
``x``/``y`` (the paper runs Float32 end-to-end; we additionally support bf16
storage for TPU and validate MAPE against the fp32 oracle in tests).

Multi-RHS: ``y`` may be ``(obs, k)`` — the same single pass over ``x`` then
serves ``k`` right-hand sides at once (``da`` becomes a ``(k,)`` row per
column), amortising the HBM stream of ``x`` over all of them.  This is the
core primitive behind ``repro.serve``'s same-design request coalescing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import (SolveResult, column_norms_sq, donate_default,
                              safe_inv, sweep_stop_flags)


def _solvebak_impl(
    x: jax.Array,
    y: jax.Array,
    a0: Optional[jax.Array],
    cn: Optional[jax.Array],
    key: Optional[jax.Array],
    atol,
    rtol,
    *,
    max_iter: int,
    order: str,
    unroll: int,
) -> SolveResult:
    if x.ndim != 2:
        raise ValueError(f"x must be 2D (obs, vars), got {x.shape}")
    if y.ndim not in (1, 2):
        raise ValueError(f"y must be (obs,) or (obs, k), got {y.shape}")
    obs, nvars = x.shape
    if order not in ("cyclic", "random"):
        raise ValueError(f"unknown order {order!r}")
    if order == "random" and key is None:
        raise ValueError("order='random' requires a PRNG key")
    if key is None:
        key = jax.random.PRNGKey(0)

    multi = y.ndim == 2
    nrhs = y.shape[1] if multi else 1
    y2 = y.reshape(obs, nrhs)
    if a0 is not None and a0.shape not in ((nvars,), (nvars, nrhs)):
        raise ValueError(
            f"a0 must be ({nvars},) or ({nvars}, {nrhs}) matching x columns "
            f"and y RHS count, got {a0.shape}")

    if cn is None:
        cn = column_norms_sq(x)
    inv_cn = safe_inv(cn)

    if a0 is None:
        a = jnp.zeros((nvars, nrhs), jnp.float32)
    else:  # (vars,) broadcasts across all right-hand sides
        a = jnp.broadcast_to(
            a0.astype(jnp.float32).reshape(nvars, -1), (nvars, nrhs))
    e0 = y2.astype(jnp.float32) - x.astype(jnp.float32) @ a  # paper line 2
    sse0 = jnp.vdot(e0, e0)
    history0 = jnp.full((max_iter,), jnp.nan, jnp.float32)

    atol_sse = jnp.float32(obs * nrhs) * jnp.float32(atol) ** 2

    def column_step(i, carry, perm):
        a, e = carry
        j = perm[i]
        xj = lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0].astype(jnp.float32)
        da = (xj @ e) * inv_cn[j]            # (k,)
        e = e - xj[:, None] * da[None, :]
        a = lax.dynamic_update_slice_in_dim(
            a, lax.dynamic_slice_in_dim(a, j, 1, axis=0) + da[None, :], j,
            axis=0)
        return a, e

    def sweep_body(state):
        a, e, i, sse_prev, history, converged, stop = state
        if order == "random":  # static: resolved at trace time
            perm = jax.random.permutation(jax.random.fold_in(key, i), nvars)
        else:
            perm = jnp.arange(nvars)
        a, e = lax.fori_loop(
            0, nvars, functools.partial(column_step, perm=perm), (a, e),
            unroll=unroll,
        )
        sse = jnp.vdot(e, e)
        history = history.at[i].set(sse)
        converged, stop = sweep_stop_flags(sse, sse_prev, sse0, atol_sse,
                                           rtol)
        return a, e, i + 1, sse, history, converged, stop

    def cond(state):
        _, _, i, _, _, _, stop = state
        return (i < max_iter) & ~stop

    a, e, n, sse, history, converged, _ = lax.while_loop(
        cond, sweep_body,
        (a, e0, jnp.int32(0), sse0, history0, jnp.bool_(False),
         jnp.bool_(False))
    )
    if not multi:
        a, e = a[:, 0], e[:, 0]
    return SolveResult(a, e, sse, n, converged, history)


@functools.lru_cache(maxsize=None)
def _jitted_solvebak(max_iter, order, unroll, donate):
    return jax.jit(
        functools.partial(_solvebak_impl, max_iter=max_iter, order=order,
                          unroll=unroll),
        donate_argnums=(1, 2) if donate else (),   # y, a0
    )


def solvebak(
    x: jax.Array,
    y: jax.Array,
    *,
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    a0: Optional[jax.Array] = None,
    order: str = "cyclic",
    key: Optional[jax.Array] = None,
    unroll: int = 1,
    cn: Optional[jax.Array] = None,
    donate: Optional[bool] = None,
) -> SolveResult:
    """Algorithm 1 (SolveBak).

    Args:
      x: (obs, vars) input matrix (any float dtype; fp32 accumulation).
      y: (obs,) right-hand side, or (obs, k) for k right-hand sides solved
        in one pass (multi-RHS; see module doc).
      max_iter: maximum number of full sweeps over all columns.
      atol: absolute tolerance on the *RMSE*; converged when
        ``sse <= obs * atol**2`` (multi-RHS: total SSE vs ``obs*k*atol²``).
        ``0`` disables.
      rtol: relative per-sweep improvement tolerance; converged when
        ``(sse_prev - sse) <= rtol * sse_prev``.  ``0`` disables.
      a0: optional (vars,) / (vars, k) initial guess (paper line 1: zeros);
        a (vars,) guess with multi-RHS ``y`` broadcasts across all k.
      order: "cyclic" (paper Algorithm 1) or "random" (paper §2, randomly
        selected indices; requires ``key``).
      key: PRNG key for ``order="random"``.
      unroll: unroll factor for the inner column loop (compile-time knob).
      cn: optional precomputed squared column norms ``⟨x_j,x_j⟩`` (vars,) —
        lets ``repro.serve``'s design cache skip the norms pass on repeated
        design matrices.
      donate: donate the ``y``/``a0`` buffers to the solve — cuts
        steady-state HBM allocation on the serving flush path (which hands
        in fresh host buffers every batch).  Default: on for accelerator
        backends at top level when ``y``/``a0`` are HOST (numpy) buffers —
        a ``jax.Array`` you pass is never auto-donated, so reuse stays
        safe; force with ``donate=True`` for device buffers you own.

    Returns:
      SolveResult.  ``history[i]`` is the SSE after sweep ``i``; for
      multi-RHS input ``coef``/``residual`` are (vars, k)/(obs, k) and
      ``sse`` is the total over all k systems.
    """
    fn = _jitted_solvebak(int(max_iter), order, int(unroll),
                          donate_default(donate, y, a0))
    return fn(x, y, a0, cn, key, atol, rtol)


def solvebak_onesweep(x: jax.Array, y: jax.Array, a: jax.Array, e: jax.Array):
    """A single cyclic sweep (used by the Pallas-kernel reference tests).

    Returns (a', e') after one pass over all columns, exactly the inner loop
    of Algorithm 1.
    """
    inv_cn = safe_inv(column_norms_sq(x))

    def column_step(j, carry):
        a, e = carry
        xj = lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0].astype(jnp.float32)
        da = jnp.vdot(xj, e) * inv_cn[j]
        return a.at[j].add(da), e - xj * da

    return lax.fori_loop(0, x.shape[1], column_step, (a, e))
