"""SolveBakF — Algorithm 3 of the paper: greedy forward feature selection.

Each step scores *every* feature by the SSE reduction a single CD step on it
would achieve.  With ``da_j = ⟨x_j, e⟩ / ⟨x_j, x_j⟩`` the post-step SSE is

    ||e - x_j da_j||² = ||e||² - ⟨x_j, e⟩² / ⟨x_j, x_j⟩,

so ``argmin_j e_j`` (paper line 5) is ``argmax_j ⟨x_j, e⟩² / ⟨x_j, x_j⟩``.
The scoring of all features is one (vars × obs)·(obs,) matvec — the paper's
"line 3 can be easily vectorised by using basic BLAS functions" — which on TPU
is a single MXU pass over ``x``.

After adding the winning feature we *refit* the coefficients on the selected
set (paper line 7) — here with the BAK solver itself (``solvebakp``) on the
gathered submatrix, which keeps the whole pipeline paper-native.

The jit-friendly formulation keeps fixed shapes: ``selected`` is a
(max_feat,) index buffer and the refit matrix is a (obs, max_feat) gather
with zero columns for not-yet-selected slots (zero columns are inert for the
solver: ``safe_inv`` gives da = 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.solvebakp import solvebakp
from repro.core.types import SelectResult, column_norms_sq, safe_inv


@functools.partial(
    jax.jit, static_argnames=("max_feat", "refit_sweeps", "refit_thr")
)
def solvebakf(
    x: jax.Array,
    y: jax.Array,
    *,
    max_feat: int,
    refit_sweeps: int = 8,
    refit_thr: int = 16,
) -> SelectResult:
    """Algorithm 3 (SolveBakF).

    Args:
      x: (obs, vars) feature matrix.
      y: (obs,) target.
      max_feat: number of features to select (paper's ``max_feat``).
      refit_sweeps: CD sweeps for the per-step refit on the selected set.
      refit_thr: block width for the refit solver.

    Returns:
      SelectResult with selection order, refit coefficients and the SSE path.
    """
    obs, nvars = x.shape
    xf32 = x.astype(jnp.float32)
    inv_cn = safe_inv(column_norms_sq(x))

    e0 = y.astype(jnp.float32)
    selected0 = jnp.full((max_feat,), -1, jnp.int32)
    coef0 = jnp.zeros((max_feat,), jnp.float32)
    sse0 = jnp.full((max_feat,), jnp.nan, jnp.float32)
    taken0 = jnp.zeros((nvars,), jnp.bool_)

    def step(carry, f):
        e, selected, coef, sse_path, taken = carry
        # Score all features in one matvec (paper line 3, vectorised).
        g = xf32.T @ e  # ⟨x_j, e⟩ for all j
        reduction = g * g * inv_cn
        reduction = jnp.where(taken, -jnp.inf, reduction)
        jhat = jnp.argmax(reduction)
        selected = selected.at[f].set(jhat.astype(jnp.int32))
        taken = taken.at[jhat].set(True)

        # Refit on the selected set (paper line 7) with the BAK solver.
        # Gather → (obs, max_feat); unselected slots are zero columns.
        sel_mask = jnp.arange(max_feat) <= f
        gather_idx = jnp.where(sel_mask, jnp.clip(selected, 0, nvars - 1), 0)
        x_sel = jnp.take(xf32, gather_idx, axis=1) * sel_mask[None, :]
        res = solvebakp(
            x_sel, y.astype(jnp.float32),
            thr=refit_thr, max_iter=refit_sweeps, mode="gram", a0=coef,
        )
        coef = res.coef
        e = res.residual
        sse_path = sse_path.at[f].set(res.sse)
        return (e, selected, coef, sse_path, taken), None

    (e, selected, coef, sse_path, _), _ = lax.scan(
        step, (e0, selected0, coef0, sse0, taken0), jnp.arange(max_feat)
    )
    return SelectResult(selected, coef, sse_path, e)


def stepwise_regression_baseline(
    x: jax.Array, y: jax.Array, *, max_feat: int
) -> SelectResult:
    """The paper's comparison baseline (Fig 2): classical stepwise (forward)
    regression — at each step, trial-fit OLS on (selected + candidate) for
    every candidate and keep the best.  O(vars) full least-squares solves per
    step, versus SolveBakF's single matvec — this is the gap Fig 2 plots.

    Implemented with normal-equation Cholesky solves on the gathered
    submatrix, vmapped over candidates.
    """
    obs, nvars = x.shape
    xf32 = x.astype(jnp.float32)
    yf32 = y.astype(jnp.float32)

    selected0 = jnp.full((max_feat,), -1, jnp.int32)
    sse0 = jnp.full((max_feat,), jnp.nan, jnp.float32)
    taken0 = jnp.zeros((nvars,), jnp.bool_)

    def trial_sse(gather_idx, col_mask):
        # OLS on masked columns via ridge-stabilised normal equations.
        xs = jnp.take(xf32, gather_idx, axis=1) * col_mask[None, :]
        g = xs.T @ xs + 1e-5 * jnp.eye(xs.shape[1], dtype=jnp.float32)
        b = xs.T @ yf32
        coef = jnp.linalg.solve(g, b) * col_mask
        r = yf32 - xs @ coef
        return jnp.vdot(r, r), coef

    def step(carry, f):
        selected, sse_path, taken = carry
        sel_mask = jnp.arange(max_feat) < f

        def candidate_sse(j):
            cand_sel = selected.at[f].set(j)
            cand_mask = sel_mask.at[f].set(True)
            idx = jnp.where(cand_mask, jnp.clip(cand_sel, 0, nvars - 1), 0)
            sse, _ = trial_sse(idx, cand_mask.astype(jnp.float32))
            return jnp.where(taken[j], jnp.inf, sse)

        sses = jax.vmap(candidate_sse)(jnp.arange(nvars))
        jhat = jnp.argmin(sses).astype(jnp.int32)
        selected = selected.at[f].set(jhat)
        taken = taken.at[jhat].set(True)
        sse_path = sse_path.at[f].set(sses[jhat])
        return (selected, sse_path, taken), None

    (selected, sse_path, _), _ = lax.scan(
        step, (selected0, sse0, taken0), jnp.arange(max_feat)
    )
    final_mask = (selected >= 0).astype(jnp.float32)
    idx = jnp.where(selected >= 0, jnp.clip(selected, 0, nvars - 1), 0)
    _, coef = trial_sse(idx, final_mask)
    xs = jnp.take(xf32, idx, axis=1) * final_mask[None, :]
    residual = yf32 - xs @ coef
    return SelectResult(selected, coef, sse_path, residual)
