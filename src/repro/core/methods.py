"""Built-in solver methods, registered against ``repro.core.spec``.

Every method the public API dispatches on is declared here as a
``MethodEntry`` whose kernel consumes a ``PreparedDesign``:

  * "bak"        — Algorithm 1, serial cyclic CD (paper-faithful baseline).
  * "bakp"       — Algorithm 2, block-Jacobi CD (paper-faithful parallel).
  * "bakp_gram"  — beyond-paper exact block CD (DESIGN.md §3).
  * "bakp_fused" — Algorithm 2 on the fused whole-solve Pallas megakernel
                   (``repro.kernels.fused_solve``): one kernel launch runs
                   every sweep with x/residual/coefficients VMEM-resident
                   and convergence decided on-chip.  Selected for
                   VMEM-fitting designs; larger ones fall back to the XLA
                   "bakp" path automatically (same algorithm, same result).
  * "bak_fused"  — the megakernel's ``variant="bak"`` body (Algorithm 1
                   sequential order); falls back to "bak" when too large.
  * "bakf"       — Algorithm 3 run to full selection: greedy forward CD over
                   every column with per-step refit.  Single-RHS, ignores
                   warm starts (selection always restarts).
  * "lstsq"      — LAPACK-path baseline (the paper's comparison column).
  * "normal"     — normal-equation Cholesky with a ``SolverSpec.ridge``
                   Tikhonov diagonal (the fast direct baseline).

The BAK family reads its reusable design state (column norms, block Gram
Cholesky factors, per-placement sharded copies) off the handle, so repeated
solves against one design never recompute it; the prepare hooks warm exactly
that state.  The mesh-sharded placements route to
``repro.core.distributed`` — only methods registered ``shardable=True`` are
eligible, which is what the serving placement policy keys on.

Adding a backend = writing a kernel with this signature and calling
``register_method`` — ``solve()``, ``prepare()``, the serving engine, the
async dispatcher and the placement policy all pick it up from the registry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (solvebakp_2d, solvebakp_obs_sharded,
                                    solvebakp_rhs_sharded)
from repro.core.solvebak import solvebak
from repro.core.solvebakf import solvebakf
from repro.core.solvebakp import solvebakp
from repro.core.spec import (_ITER_FIELDS, MethodEntry, SolverSpec,
                             register_method)
from repro.core.types import SolveResult
from repro.obs import record_dispatch

_SHARDED_BACKENDS = {
    "obs_sharded": solvebakp_obs_sharded,
    "rhs_sharded": solvebakp_rhs_sharded,
}


# --------------------------------------------------------------- BAK family
def _bak_solve(p, y, spec: SolverSpec, *, a0=None, key=None, placement=None,
               mesh=None):
    # Kernel-path relay: these solve bodies run eagerly per call (jit lives
    # inside the solvers), so recording here reports the route each solve
    # actually took; the vmap_one closures are jit-traced and must NOT
    # record (they'd only fire at compile time).
    record_dispatch("xla", method="bak")
    return solvebak(p.x_pad, y, max_iter=spec.max_iter, atol=spec.atol,
                    rtol=spec.rtol, a0=a0, order=spec.order, key=key,
                    cn=p.cn)


def _bak_vmap_one(spec: SolverSpec):
    if spec.order != "cyclic":
        # Keep batch and single-solve semantics identical: the single path
        # rejects order="random" without a PRNG key (serving requests carry
        # none), so the vmapped path must error too rather than silently
        # solving with cyclic order.
        raise ValueError(
            f"order={spec.order!r} requires a PRNG key and is not "
            f"vmap-batchable; serve it with order='cyclic'")

    def one(x, y, cn, atol, a0=None):
        return solvebak(x, y, max_iter=spec.max_iter, atol=atol,
                        rtol=spec.rtol, cn=cn, a0=a0)
    return one


def _bakp_solve(mode: str):
    method_name = "bakp" if mode == "jacobi" else "bakp_gram"

    def kernel(p, y, spec: SolverSpec, *, a0=None, key=None, placement=None,
               mesh=None):
        if placement is not None and placement.sharded:
            if mesh is None:
                raise ValueError(
                    f"placement {placement.kind!r} needs a ServeMesh")
            record_dispatch("sharded", method=method_name)
            x_dev = p.x_for_placement(placement, mesh)
            kw = dict(thr=spec.thr, max_iter=spec.max_iter, atol=spec.atol,
                      rtol=spec.rtol, omega=spec.omega, mode=mode,
                      ridge=spec.ridge, a0=a0)
            if placement.kind == "mesh_2d":
                return solvebakp_2d(x_dev, y, mesh.mesh,
                                    data_axes=mesh.data_axes,
                                    model_axis=mesh.model_axis, **kw)
            backend = _SHARDED_BACKENDS.get(placement.kind)
            if backend is None:
                raise ValueError(
                    f"unknown placement kind {placement.kind!r}")
            return backend(x_dev, y, mesh.mesh, data_axes=mesh.data_axes,
                           **kw)
        record_dispatch("xla", method=method_name)
        return solvebakp(
            p.x_pad, y, thr=spec.thr, max_iter=spec.max_iter, atol=spec.atol,
            rtol=spec.rtol, omega=spec.omega, mode=mode, ridge=spec.ridge,
            cn=p.cn_for_thr(spec.thr),
            chol=(p.chol_for(spec.thr, spec.ridge) if mode == "gram"
                  else None),
            a0=a0)
    return kernel


def _bakp_vmap_one(mode: str):
    def build(spec: SolverSpec):
        if mode == "gram":
            def one(x, y, cn, atol, chol, a0=None):
                return solvebakp(x, y, thr=spec.thr, max_iter=spec.max_iter,
                                 atol=atol, rtol=spec.rtol, omega=spec.omega,
                                 mode="gram", ridge=spec.ridge, cn=cn,
                                 chol=chol, a0=a0)
        else:
            def one(x, y, cn, atol, a0=None):
                return solvebakp(x, y, thr=spec.thr, max_iter=spec.max_iter,
                                 atol=atol, rtol=spec.rtol, omega=spec.omega,
                                 mode="jacobi", cn=cn, a0=a0)
        return one
    return build


def _prep_bak(p, spec: SolverSpec):
    p.cn  # property access materialises the lazy column norms


def _prep_bakp(p, spec: SolverSpec):
    p.cn_for_thr(spec.thr)


def _prep_bakp_gram(p, spec: SolverSpec):
    p.cn_for_thr(spec.thr)
    p.chol_for(spec.thr, spec.ridge)


# ------------------------------------------------- fused megakernel methods
def _refine_fp32(p, y, spec: SolverSpec, lp: SolveResult, *, variant: str,
                 nrhs: int) -> SolveResult:
    """fp32 polish for ``precision="bf16_fp32acc"`` (iterative refinement).

    Starts from the low-precision solution: the kernel entry's shared
    ``solve_init`` recomputes the residual in fp32 from the solved
    coefficients against the fp32 design, then up to ``spec.refine_sweeps``
    full-precision sweeps run against it — honouring ``atol``/``rtol``, so
    an already-converged polish exits early.  Routed through the same
    fused-vs-per-sweep fit check as the main solve (at fp32 itemsize); the
    per-sweep stream covers designs where only the bf16 copy fits fused.

    Deliberately does NOT record a dispatch: the solve's reported kernel
    path stays the low-precision route the bulk of the bytes took.
    """
    from repro.kernels.fused_solve import fused_fits, fused_solve
    from repro.kernels.ops import solvebakp_persweep_kernel

    block = spec.thr
    obs_p = p.x_pad.shape[0]
    x_t = p.x_t_for(block)
    kw = dict(inv_cn=p.inv_cn_for(block), a0=lp.coef, block=block,
              max_iter=spec.refine_sweeps, atol=spec.atol, rtol=spec.rtol,
              omega=spec.omega if variant == "bakp" else 1.0,
              variant=variant)
    if fused_fits(x_t.shape[0], obs_p, nrhs, x_t.dtype.itemsize,
                  max_iter=spec.refine_sweeps):
        pol = fused_solve(x_t, y, **kw)
    else:
        pol = solvebakp_persweep_kernel(x_t, y, **kw)
    # Merged accounting: sweeps add, histories concatenate (length
    # max_iter + refine_sweeps for this precision), convergence is the OR
    # (a polish that runs its full budget after a converged lp solve is
    # still a success).
    return SolveResult(
        pol.coef, pol.residual, pol.sse, lp.n_sweeps + pol.n_sweeps,
        lp.converged | pol.converged,
        jnp.concatenate([lp.history, pol.history]))


def _fused_method(variant: str):
    """Whole-solve Pallas megakernel entry (repro.kernels.fused_solve).

    Consumes the handle's cached transposed padded design (``x_t_for``) and
    inverse column norms (``inv_cn_for``) — no per-solve norms pass, no
    ``x_t.T`` materialisation.  Designs whose whole-solve working set
    exceeds ``repro.kernels.cd_sweep.VMEM_BUDGET_BYTES`` (checked via
    ``fused_fits``) fall back to the XLA path of the same algorithm, so
    every dispatch route (``solve()``, ``PreparedDesign.solve``, the
    serving engine) serves any size without raising.

    Precision (PR 7): under ``spec.precision != "fp32"`` the kernels
    stream the handle's bf16 cache tier (``x_bf16_for``) instead — half
    the HBM traffic, and the VMEM fit check runs at itemsize 2, so designs
    twice as large stay on the fused path.  A bf16 solve too large even at
    itemsize 2 falls back to the *per-sweep* bf16 stream (keeping the
    halved traffic) rather than the fp32 XLA solvers.
    ``"bf16_fp32acc"`` appends the ``_refine_fp32`` polish.
    """
    def kernel(p, y, spec: SolverSpec, *, a0=None, key=None, placement=None,
               mesh=None):
        # Imported at call time: repro.kernels itself imports repro.core
        # (types), so a module-level import here would make the package
        # import order matter (kernels-first would hit a half-initialised
        # fused_solve through this registration module).
        from repro.kernels.fused_solve import fused_fits, fused_solve
        from repro.kernels.ops import solvebakp_persweep_kernel

        block = spec.thr
        lowp = spec.precision != "fp32"
        polish = spec.precision == "bf16_fp32acc" and spec.refine_sweeps > 0
        obs_p, vars_p = p.x_pad.shape
        if not hasattr(y, "ndim"):  # host buffers stay host (donation)
            y = jnp.asarray(y)
        nrhs = y.shape[1] if y.ndim == 2 else 1
        vars_pb = -(-vars_p // block) * block
        itemsize = 2 if lowp else p.x_pad.dtype.itemsize
        fits = (spec.max_iter >= 1
                and fused_fits(vars_pb, obs_p, nrhs, itemsize,
                               max_iter=spec.max_iter))
        if spec.max_iter < 1 or (not fits and not lowp):
            record_dispatch(
                "xla", method=f"{variant}_fused",
                reason="max_iter" if spec.max_iter < 1 else "vmem")
            if variant == "bak":
                return solvebak(p.x_pad, y, max_iter=spec.max_iter,
                                atol=spec.atol, rtol=spec.rtol, a0=a0,
                                cn=p.cn)
            return solvebakp(p.x_pad, y, thr=block, max_iter=spec.max_iter,
                             atol=spec.atol, rtol=spec.rtol,
                             omega=spec.omega, mode="jacobi",
                             cn=p.cn_for_thr(block), a0=a0)
        if a0 is not None and vars_pb != vars_p:
            # Pad with the operand's own library: a host a0 must STAY host
            # (numpy) or the solver entry's auto-donation — the flush
            # path's HBM saving — silently turns off (types.donate_default
            # never donates jax.Array operands).
            xp = jnp if isinstance(a0, jax.Array) else np
            a0 = xp.pad(xp.asarray(a0, jnp.float32),
                        ((0, vars_pb - vars_p),) + ((0, 0),) * (a0.ndim - 1))
        x_t = p.x_bf16_for(block) if lowp else p.x_t_for(block)
        kw = dict(inv_cn=p.inv_cn_for(block), a0=a0, block=block,
                  max_iter=spec.max_iter, atol=spec.atol, rtol=spec.rtol,
                  omega=spec.omega if variant == "bakp" else 1.0,
                  variant=variant)
        if fits:
            record_dispatch("fused", method=f"{variant}_fused")
            res = fused_solve(x_t, y, **kw)
        else:
            # bf16-only fallback: stream the bf16 copy per sweep instead of
            # re-inflating to the fp32 XLA path — large designs are exactly
            # where the halved HBM traffic matters most.
            record_dispatch("persweep", method=f"{variant}_fused",
                            reason="vmem")
            res = solvebakp_persweep_kernel(x_t, y, **kw)
        if polish:
            res = _refine_fp32(p, y, spec, res, variant=variant, nrhs=nrhs)
        if vars_pb != vars_p:
            res = res._replace(coef=res.coef[:vars_p])
        return res
    return kernel


def _prep_fused(p, spec: SolverSpec):
    p.x_t_for(spec.thr)
    p.inv_cn_for(spec.thr)
    if spec.precision != "fp32":
        p.x_bf16_for(spec.thr)  # quantized cache tier, warmed off-thread


# ------------------------------------------------- streaming out-of-core
def _stream_solve_method(p, y, spec: SolverSpec, *, a0=None, key=None,
                         placement=None, mesh=None):
    """Algorithm 2 with X streamed rather than VMEM-resident.

    Resident designs run the double-buffered Pallas kernel
    (``repro.kernels.stream_solve``): x tiles live in ``pltpu.ANY`` (HBM)
    and DMA through a two-slot VMEM scratch while residual/coefficients
    stay on-chip, so the VMEM working set is two (block, obs) tiles
    regardless of vars.  Non-resident designs — store-backed handles whose
    X never fits the device budget — take the host block loop
    (``stream_solve_blocks``), fetching tiles through the design store's
    host/disk tiers per block.  Same block-Jacobi math and stopping rule
    as "bakp"/"bakp_fused" either way.
    """
    from repro.kernels.ops import solvebakp_persweep_kernel
    from repro.kernels.stream_solve import (stream_fits, stream_solve,
                                            stream_solve_blocks)

    block = spec.thr
    lowp = spec.precision != "fp32"
    obs_p, vars_p = p.shape
    if not hasattr(y, "ndim"):  # host buffers stay host (donation)
        y = jnp.asarray(y)
    nrhs = y.shape[1] if y.ndim == 2 else 1
    vars_pb = -(-vars_p // block) * block
    if spec.max_iter < 1 and p.x_pad is not None:
        record_dispatch("xla", method="bakp_stream", reason="max_iter")
        return solvebakp(p.x_pad, y, thr=block, max_iter=spec.max_iter,
                         atol=spec.atol, rtol=spec.rtol, omega=spec.omega,
                         mode="jacobi", cn=p.cn_for_thr(block), a0=a0)
    if a0 is not None and vars_pb != vars_p:
        xp = jnp if isinstance(a0, jax.Array) else np
        a0 = xp.pad(xp.asarray(a0, jnp.float32),
                    ((0, vars_pb - vars_p),) + ((0, 0),) * (a0.ndim - 1))
    kw = dict(inv_cn=p.inv_cn_for(block), a0=a0, block=block,
              max_iter=spec.max_iter, atol=spec.atol, rtol=spec.rtol,
              omega=spec.omega)
    if p.x_pad is None:
        record_dispatch("stream_host", method="bakp_stream")
        res = stream_solve_blocks(p.blocks, y, **kw)
    else:
        itemsize = 2 if lowp else 4
        x_t = p.x_bf16_for(block) if lowp else p.x_t_for(block)
        if stream_fits(vars_pb, obs_p, nrhs, itemsize, block=block,
                       max_iter=spec.max_iter):
            record_dispatch("stream", method="bakp_stream")
            res = stream_solve(x_t, y, **kw)
        else:
            # Even the two-tile scratch is over budget (huge obs): the
            # per-sweep stream shares the bounded-VMEM property.
            record_dispatch("persweep", method="bakp_stream", reason="vmem")
            res = solvebakp_persweep_kernel(x_t, y, variant="bakp", **kw)
    if vars_pb != vars_p:
        res = res._replace(coef=res.coef[:vars_p])
    return res


def _prep_stream(p, spec: SolverSpec):
    p.inv_cn_for(spec.thr)
    if p.x_pad is not None:
        p.x_t_for(spec.thr)
        if spec.precision != "fp32":
            p.x_bf16_for(spec.thr)


# ---------------------------------------------------- greedy selection (A3)
def _bakf_solve(p, y, spec: SolverSpec, *, a0=None, key=None, placement=None,
                mesh=None):
    """Algorithm 3 run to full selection as a solver: greedily order every
    column by SSE reduction, refitting after each pick.  The final refit
    over all columns is an exact-block CD solve, so the solution matches
    "bak"/"bakp" on the same system (parity-tested); the selection order
    itself is the extra information this method pays O(vars) matvecs for.
    """
    record_dispatch("xla", method="bakf")
    nvars = p.x_pad.shape[1]
    sel = solvebakf(p.x_pad, y, max_feat=nvars,
                    refit_sweeps=spec.max_iter,
                    refit_thr=min(spec.thr, nvars))
    coef = jnp.zeros((nvars,), jnp.float32).at[sel.selected].set(sel.coef)
    e = sel.residual
    sse = jnp.vdot(e, e)
    hist = jnp.full((spec.max_iter,), jnp.nan, jnp.float32).at[0].set(sse)
    return SolveResult(coef, e, sse, jnp.int32(nvars), jnp.bool_(True), hist)


# ----------------------------------------------------------- direct methods
def _direct_result(x, y, coef, max_iter: int) -> SolveResult:
    e = y.astype(jnp.float32) - x @ coef
    sse = jnp.vdot(e, e)
    hist = jnp.full((max_iter,), jnp.nan, jnp.float32).at[0].set(sse)
    return SolveResult(coef, e, sse, jnp.int32(1), jnp.bool_(True), hist)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def _lstsq_kernel(x, y, max_iter: int) -> SolveResult:
    coef = jnp.linalg.lstsq(x, y.astype(jnp.float32))[0]
    return _direct_result(x, y, coef, max_iter)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def _normal_kernel(x, y, ridge, max_iter: int) -> SolveResult:
    g = x.T @ x + ridge * jnp.eye(x.shape[1], dtype=jnp.float32)
    coef = jax.scipy.linalg.cho_solve(
        (jax.scipy.linalg.cholesky(g, lower=True), True),
        x.T @ y.astype(jnp.float32))
    return _direct_result(x, y, coef, max_iter)


def _lstsq_solve(p, y, spec: SolverSpec, *, a0=None, key=None, placement=None,
                 mesh=None):
    record_dispatch("xla", method="lstsq")
    return _lstsq_kernel(p.x_pad, y, spec.max_iter)


def _normal_solve(p, y, spec: SolverSpec, *, a0=None, key=None,
                  placement=None, mesh=None):
    record_dispatch("xla", method="normal")
    return _normal_kernel(p.x_pad, y, jnp.float32(spec.ridge), spec.max_iter)


# ------------------------------------------------------------- registration
register_method(MethodEntry(
    name="bak", solve=_bak_solve, consumes=_ITER_FIELDS + ("order",),
    iterative=True, multi_rhs=True, batchable=True, shardable=False,
    blocked=False, prepare=_prep_bak, vmap_one=_bak_vmap_one,
    fallback="lstsq",
    summary="Algorithm 1: serial cyclic coordinate descent"))
register_method(MethodEntry(
    name="bakp", solve=_bakp_solve("jacobi"),
    consumes=_ITER_FIELDS + ("thr", "omega"),
    iterative=True, multi_rhs=True, batchable=True, shardable=True,
    blocked=True, prepare=_prep_bakp, vmap_one=_bakp_vmap_one("jacobi"),
    fallback="bakp_stream",
    summary="Algorithm 2: block-Jacobi coordinate descent"))
register_method(MethodEntry(
    name="bakp_gram", solve=_bakp_solve("gram"),
    consumes=_ITER_FIELDS + ("thr", "omega", "ridge"),
    iterative=True, multi_rhs=True, batchable=True, shardable=True,
    blocked=True, needs_chol=True, prepare=_prep_bakp_gram,
    vmap_one=_bakp_vmap_one("gram"), fallback="bakp",
    summary="exact block CD via cached block-Gram Cholesky (beyond-paper)"))
register_method(MethodEntry(
    name="bakp_fused", solve=_fused_method("bakp"),
    consumes=_ITER_FIELDS + ("thr", "omega", "precision", "refine_sweeps"),
    iterative=True, multi_rhs=True, batchable=False, shardable=False,
    blocked=True, precisions=("fp32", "bf16", "bf16_fp32acc"),
    lane="fused", prepare=_prep_fused, fallback="bakp",
    summary="Algorithm 2 on the fused whole-solve Pallas megakernel "
            "(VMEM-resident sweeps, on-chip convergence; XLA fallback "
            "when the design exceeds the VMEM budget; bf16 X streaming "
            "with fp32 accumulators + fp32 polish)"))
register_method(MethodEntry(
    name="bak_fused", solve=_fused_method("bak"),
    consumes=_ITER_FIELDS + ("thr", "precision", "refine_sweeps"),
    iterative=True, multi_rhs=True, batchable=False, shardable=False,
    blocked=True, precisions=("fp32", "bf16", "bf16_fp32acc"),
    lane="fused", prepare=_prep_fused, fallback="bak",
    summary="Algorithm 1 on the fused megakernel (sequential column "
            "order; XLA fallback when over the VMEM budget; bf16 X "
            "streaming with fp32 accumulators + fp32 polish)"))
register_method(MethodEntry(
    name="bakp_stream", solve=_stream_solve_method,
    consumes=_ITER_FIELDS + ("thr", "omega", "precision"),
    iterative=True, multi_rhs=True, batchable=False, shardable=False,
    blocked=True, streams=True, precisions=("fp32", "bf16"),
    lane="stream", prepare=_prep_stream, fallback="lstsq",
    summary="Algorithm 2 streaming out-of-core: x tiles double-buffered "
            "from HBM (pltpu.ANY) through VMEM scratch, or fetched "
            "per-block through the design store's host/disk tiers for "
            "non-resident designs"))
register_method(MethodEntry(
    name="lstsq", solve=_lstsq_solve, consumes=(),
    iterative=False, multi_rhs=True,
    summary="LAPACK lstsq baseline (the paper's comparison column)"))
register_method(MethodEntry(
    name="normal", solve=_normal_solve, consumes=("ridge",),
    iterative=False, multi_rhs=True, fallback="lstsq",
    summary="normal-equation Cholesky with SolverSpec.ridge diagonal"))
register_method(MethodEntry(
    name="bakf", solve=_bakf_solve, consumes=("max_iter", "thr"),
    iterative=False, multi_rhs=False,
    summary="Algorithm 3 to full selection: greedy forward CD + refit"))
