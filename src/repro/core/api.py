"""Public solve API — legacy one-shot entry points over the spec/prepare model.

The primary API is the two-step handle model (see ``repro.core.prepare``):

    spec = SolverSpec(method="bakp_gram", rtol=1e-8)
    design = prepare(x, spec)        # once per design matrix
    res = design.solve(y)            # cheap per-RHS solves, warm-startable

``solve(x, y, method=..., **knobs)`` and ``fit_linear_probe`` below are thin
shims kept for one-shot callers and backwards compatibility: they build a
``SolverSpec`` from the loose kwargs, ``prepare`` the design and run a
single solve.  Methods are dispatched through the registry
(``repro.core.spec``) — ``method_names()`` lists what is available,
including "bakf" (Algorithm 3 to full selection) alongside the original
five.

All multi-RHS-capable methods accept ``y`` of shape (obs,) or (obs, k): the
multi-RHS form solves k systems against the same design matrix in one pass
over ``x`` (coef/residual come back as (vars, k)/(obs, k)).  ``repro.serve``
builds its same-design request coalescing on this.

Iterative methods accept ``a0`` initial coefficients ((vars,) or (vars, k))
and start from that point instead of zeros — the warm-start primitive behind
``repro.serve``'s per-tenant coefficient retention.  Direct methods ignore
``a0`` (documented once, on ``SolverSpec``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

import repro.core.methods  # noqa: F401  (populates the method registry)
from repro.core.prepare import prepare
from repro.core.spec import SolverSpec, method_names
from repro.core.types import SolveResult


def solve(
    x: jax.Array,
    y: jax.Array,
    *,
    method: str = "bakp_gram",
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    thr: int = 128,
    omega: float = 1.0,
    ridge: float = 1e-6,
    order: str = "cyclic",
    a0: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    spec: Optional[SolverSpec] = None,
) -> SolveResult:
    """One-shot solve: ``prepare(x, spec).solve(y, a0, key=key)``.

    ``spec`` (a ``SolverSpec``) overrides every loose knob when given.
    Repeated solves against the same ``x`` should hold a ``prepare`` handle
    instead — this shim rebuilds the design state every call.
    """
    if spec is None:
        spec = SolverSpec(method=method, max_iter=max_iter, atol=atol,
                          rtol=rtol, thr=thr, omega=omega, ridge=ridge,
                          order=order)
    return prepare(x, spec).solve(y, a0, key=key)


def fit_linear_probe(
    features: jax.Array,
    targets: jax.Array,
    *,
    method: str = "bakp_gram",
    max_iter: int = 64,
    rtol: float = 1e-7,
    thr: int = 128,
    a0: Optional[jax.Array] = None,
    spec: Optional[SolverSpec] = None,
) -> SolveResult:
    """Fit a linear readout ``features @ a ≈ targets``.

    ``features``: (..., tokens, d) frozen backbone activations, flattened
    over leading axes (tall system — exactly the paper's regression
    setting).  ``targets``: matching (..., tokens) scalar target, or
    (..., tokens, k) for ``k`` readouts fit in ONE multi-RHS pass over the
    activations (k logits, k value heads, k probe classes) — coef comes
    back (d, k).  ``a0``: optional (d,) / (d, k) warm start — pass the
    previous fit's ``coef`` when re-fitting on a grown activation buffer.
    """
    feats = features.reshape(-1, features.shape[-1])
    targets = jnp.asarray(targets)
    if targets.ndim == features.ndim:
        # (..., tokens, k): multi-output — keep k and ride the multi-RHS
        # path instead of silently flattening k targets into one.
        t = targets.reshape(-1, targets.shape[-1])
    else:
        t = targets.reshape(-1)
    if t.shape[0] != feats.shape[0]:
        raise ValueError(
            f"targets {tuple(targets.shape)} do not match features "
            f"{tuple(features.shape)}: expected (..., tokens) or "
            f"(..., tokens, k) with the same leading/token axes")
    return solve(feats, t, method=method, max_iter=max_iter, rtol=rtol,
                 thr=thr, a0=a0, spec=spec)


# Deprecated alias (pre-registry): the live list is ``method_names()``.
_METHODS = method_names()
