"""Public solve API — one entry point over the BAK family + LAPACK baseline.

``solve(x, y, method=...)`` dispatches to:

  * "bak"        — Algorithm 1, serial cyclic CD (paper-faithful baseline).
  * "bakp"       — Algorithm 2, block-Jacobi CD (paper-faithful parallel).
  * "bakp_gram"  — beyond-paper exact block CD (DESIGN.md §3).
  * "lstsq"      — LAPACK-path baseline (the paper's comparison column),
                   via jnp.linalg.lstsq.
  * "normal"     — normal-equation Cholesky (the fast direct baseline for
                   tall systems).

``fit_linear_probe`` is the framework-integration entry point: fit a linear
readout on (tokens × features) activations — the tall-system regression the
paper targets.

All methods accept ``y`` of shape (obs,) or (obs, k): the multi-RHS form
solves k systems against the same design matrix in one pass over ``x``
(coef/residual come back as (vars, k)/(obs, k)).  ``repro.serve`` builds its
same-design request coalescing on this.

The iterative methods accept ``a0`` initial coefficients ((vars,) or
(vars, k)) and start from that point instead of zeros — the warm-start
primitive behind ``repro.serve``'s per-tenant coefficient retention: a
tenant re-solving against the same design with a slightly-drifted ``y``
converges in a fraction of the cold sweeps, something one-shot
sketching/direct solvers structurally cannot exploit.  Direct methods
ignore ``a0``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.solvebak import solvebak
from repro.core.solvebakp import solvebakp
from repro.core.types import SolveResult

_METHODS = ("bak", "bakp", "bakp_gram", "lstsq", "normal")


def solve(
    x: jax.Array,
    y: jax.Array,
    *,
    method: str = "bakp_gram",
    max_iter: int = 50,
    atol: float = 0.0,
    rtol: float = 0.0,
    thr: int = 128,
    omega: float = 1.0,
    a0: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    if method == "bak":
        return solvebak(x, y, max_iter=max_iter, atol=atol, rtol=rtol, a0=a0,
                        key=key)
    if method == "bakp":
        return solvebakp(x, y, thr=thr, max_iter=max_iter, atol=atol,
                         rtol=rtol, omega=omega, mode="jacobi", a0=a0)
    if method == "bakp_gram":
        return solvebakp(x, y, thr=thr, max_iter=max_iter, atol=atol,
                         rtol=rtol, omega=omega, mode="gram", a0=a0)
    if method == "lstsq":
        coef = jnp.linalg.lstsq(x.astype(jnp.float32), y.astype(jnp.float32))[0]
        return _direct_result(x, y, coef, max_iter)
    if method == "normal":
        xf = x.astype(jnp.float32)
        g = xf.T @ xf + 1e-6 * jnp.eye(x.shape[1], dtype=jnp.float32)
        coef = jax.scipy.linalg.cho_solve(
            (jax.scipy.linalg.cholesky(g, lower=True), True),
            xf.T @ y.astype(jnp.float32))
        return _direct_result(x, y, coef, max_iter)
    raise ValueError(f"method must be one of {_METHODS}, got {method!r}")


def _direct_result(x, y, coef, max_iter) -> SolveResult:
    e = y.astype(jnp.float32) - x.astype(jnp.float32) @ coef
    sse = jnp.vdot(e, e)
    hist = jnp.full((max_iter,), jnp.nan, jnp.float32).at[0].set(sse)
    return SolveResult(coef, e, sse, jnp.int32(1), jnp.bool_(True), hist)


def fit_linear_probe(
    features: jax.Array,
    targets: jax.Array,
    *,
    method: str = "bakp_gram",
    max_iter: int = 64,
    rtol: float = 1e-7,
    thr: int = 128,
    a0: Optional[jax.Array] = None,
) -> SolveResult:
    """Fit a linear readout ``features @ a ≈ targets``.

    ``features``: (tokens, d) frozen backbone activations (tall system —
    exactly the paper's regression setting).  ``targets``: (tokens,) scalar
    target (e.g. a logit, a value-head label, a probe class margin).
    ``a0``: optional (d,) warm start — pass the previous fit's ``coef`` when
    re-fitting the probe on a grown activation buffer.
    """
    feats = features.reshape(-1, features.shape[-1])
    return solve(feats, targets.reshape(-1), method=method,
                 max_iter=max_iter, rtol=rtol, thr=thr, a0=a0)
