"""Column preconditioning for the BAK solvers.

Coordinate descent's per-sweep progress depends on column scaling and
correlation; normalising columns to unit norm is free to undo (rescale the
coefficients) and makes ``⟨x_j, x_j⟩ = 1``, which both stabilises bf16
storage and lets the kernels skip the per-column divide.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import column_norms_sq


class ColumnScaling(NamedTuple):
    scale: jax.Array  # (vars,) multiplier applied to columns (1/||x_j||)


def normalize_columns(x: jax.Array):
    """Returns (x_normalised, ColumnScaling).  Zero columns are left as-is."""
    cn = column_norms_sq(x)
    norm = jnp.sqrt(jnp.where(cn > 0, cn, 1.0))
    scale = jnp.where(cn > 0, 1.0 / norm, 1.0).astype(jnp.float32)
    return (x.astype(jnp.float32) * scale[None, :]).astype(x.dtype), ColumnScaling(scale)


def unscale_coef(coef: jax.Array, scaling: ColumnScaling) -> jax.Array:
    """Map coefficients of the normalised system back to the original one."""
    return coef * scaling.scale
