"""Shared result/record types for the BAK solver family."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SolveResult(NamedTuple):
    """Result of a linear-system solve.

    Attributes:
      coef:       (vars,) solution vector ``a`` with ``x @ a ≈ y``; for a
                  multi-RHS solve (``y`` of shape (obs, k)): (vars, k).
      residual:   (obs,) final residual ``e = y - x @ a`` (fp32); multi-RHS:
                  (obs, k).
      sse:        scalar fp32 sum of squared residuals at exit (multi-RHS:
                  total over all k systems).
      n_sweeps:   scalar int32, number of full sweeps executed.
      converged:  scalar bool, True if a tolerance criterion fired before
                  ``max_iter`` was exhausted.
      history:    (max_iter,) fp32 SSE after each sweep (NaN for sweeps not
                  executed).  Used by the convergence benchmarks/tests; the
                  paper's Theorem 1 asserts this sequence is non-increasing.
    """

    coef: jax.Array
    residual: jax.Array
    sse: jax.Array
    n_sweeps: jax.Array
    converged: jax.Array
    history: jax.Array


class SelectResult(NamedTuple):
    """Result of SolveBakF greedy feature selection.

    Attributes:
      selected:  (max_feat,) int32 indices of selected columns, in selection
                 order.
      coef:      (max_feat,) fp32 coefficients of the refit on the selected
                 columns (aligned with ``selected``).
      sse_path:  (max_feat,) fp32 SSE after each selection + refit step — the
                 greedy error-reduction path.
      residual:  (obs,) fp32 final residual.
    """

    selected: jax.Array
    coef: jax.Array
    sse_path: jax.Array
    residual: jax.Array


def column_norms_sq(x: jax.Array) -> jax.Array:
    """Squared column norms ``⟨x_j, x_j⟩`` accumulated in fp32, shape (vars,).

    ``preferred_element_type`` forces the *accumulator* to fp32 even for a
    bf16 design — an in-dtype accumulation would lose norm accuracy that
    ``safe_inv``/``inv_cn`` then amplifies in every sweep's update.
    """
    return jnp.einsum("ij,ij->j", x, x,
                      preferred_element_type=jnp.float32)


def column_norms_sq_t(x_t: jax.Array) -> jax.Array:
    """``column_norms_sq`` on the TRANSPOSED (vars, obs) kernel layout.

    A paper-"column" is a contiguous row of ``x_t``, so the norms reduce
    over the trailing (obs) axis directly — no ``x_t.T`` materialisation,
    which for the kernel wrappers used to be a full (obs, vars) relayout
    just to throw it away after one reduction.  Accumulates in fp32
    regardless of input dtype (see ``column_norms_sq``).
    """
    return jnp.einsum("vo,vo->v", x_t, x_t,
                      preferred_element_type=jnp.float32)


def safe_inv(cn: jax.Array) -> jax.Array:
    """1/cn with zero (not inf) for zero-norm columns.

    A zero column can never reduce the residual, so the paper's ``da`` is
    defined as 0 for it; this keeps the update well-posed.
    """
    return jnp.where(cn > 0.0, 1.0 / jnp.where(cn > 0.0, cn, 1.0), 0.0)


def donate_default(donate, *operands) -> bool:
    """Shared buffer-donation default for the jitted solver entry points.

    Auto-donation must be safe for every caller, so it fires only when ALL
    of the following hold — an explicit ``donate`` always wins:

      * accelerator backend (the CPU backend cannot donate; requesting it
        just emits warnings);
      * top level (a re-entrant call under vmap / shard_map / an outer jit
        cannot consume donations);
      * every donatable ``operand`` is a HOST buffer (numpy / None), whose
        device transfer inside the jit is fresh by construction — nobody
        else can hold it.  A ``jax.Array`` operand is never auto-donated:
        the caller may reuse it (benchmarks re-solving one ``y``, parity
        loops), and a deleted-buffer crash is worse than a copy.  The
        serving engine hands the solvers host buffers, so the flush path
        donates; pass ``donate=True`` to force it for device operands you
        own.
    """
    if donate is not None:
        return bool(donate)
    return (jax.default_backend() != "cpu"
            and jax.core.trace_state_clean()
            and not any(isinstance(op, jax.Array) for op in operands))


def warm_retention_ok(res: "SolveResult") -> bool:
    """Whether a solve's coefficients are safe to retain as a warm start.

    False exactly when the solve looks *diverged*: ``converged`` is False
    AND its recorded SSE history net-rose (last finite entry materially
    above the first — the geometric blow-up ``sweep_stop_flags`` classifies
    as genuine divergence).  Plain budget exhaustion (``converged=False``
    with the non-increasing history Theorem 1 guarantees, e.g. ``rtol=0``
    runs that simply spent ``max_iter``) still retains — those coefficients
    are the best seen and warm-starting from them is the whole point.

    A diverged solve's coefficients, by contrast, are *worse than zero*:
    retaining them poisons the tenant's next warm start into starting from
    the blown-up point (and likely diverging again).  Both retention sites
    gate on this — ``PreparedDesign.solve``'s tenant store and the serving
    engine's ``_strip``.

    Scalar (single/multi-RHS group) flags only; a batched ``converged``
    (the vmapped path) returns True and the caller gates per row.
    """
    try:
        conv = np.asarray(res.converged)
        if conv.ndim != 0 or bool(conv):
            return True
        h = np.asarray(res.history, np.float32).ravel()
        h = h[np.isfinite(h)]
        if h.size >= 2 and float(h[-1]) > 1.01 * float(h[0]):
            return False
    except Exception:
        return True  # malformed/absent history: keep the old behaviour
    return True


def sweep_stop_flags(sse, sse_prev, sse0, atol_sse, rtol):
    """Per-sweep stopping decision shared by every iterative solver.

    Returns ``(converged, stop)``:

      * ``stop`` — the loop should exit: the absolute tolerance fired, the
        sweep improved SSE by less than ``rtol * sse_prev``, or SSE *rose*
        (no further progress is coming from more sweeps either way).
      * ``converged`` — whether that exit may be reported as success.  An
        SSE rise splits on net progress: staying at/near the starting
        ``sse0`` (within a 1% band — float-accumulation jitter, e.g. a cold
        run stalled at its accuracy floor or a warm start that was already
        at the fixed point) is a stall and reports True exactly like the
        classic rtol exit, while ending materially *above* ``sse0`` is
        genuine divergence (Jacobi-within-block with correlated columns /
        too-large ω blows up geometrically, so it clears the band within a
        sweep) and reports False.  Without the distinction,
        ``(sse_prev - sse) <= rtol * sse_prev`` is trivially true for any
        SSE increase and a diverging solve would stop after one sweep
        claiming success.

    With ``rtol == 0`` the relative/divergence checks are off (the solve
    runs its full ``max_iter`` budget exactly as before).
    """
    improved = sse <= sse_prev
    hit_atol = (atol_sse > 0.0) & (sse <= atol_sse)
    hit_rtol = (rtol > 0.0) & improved & ((sse_prev - sse) <= rtol * sse_prev)
    rose = (rtol > 0.0) & ~improved
    converged = hit_atol | hit_rtol | (rose & (sse <= 1.01 * sse0))
    return converged, hit_atol | hit_rtol | rose
