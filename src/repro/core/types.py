"""Shared result/record types for the BAK solver family."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SolveResult(NamedTuple):
    """Result of a linear-system solve.

    Attributes:
      coef:       (vars,) solution vector ``a`` with ``x @ a ≈ y``; for a
                  multi-RHS solve (``y`` of shape (obs, k)): (vars, k).
      residual:   (obs,) final residual ``e = y - x @ a`` (fp32); multi-RHS:
                  (obs, k).
      sse:        scalar fp32 sum of squared residuals at exit (multi-RHS:
                  total over all k systems).
      n_sweeps:   scalar int32, number of full sweeps executed.
      converged:  scalar bool, True if a tolerance criterion fired before
                  ``max_iter`` was exhausted.
      history:    (max_iter,) fp32 SSE after each sweep (NaN for sweeps not
                  executed).  Used by the convergence benchmarks/tests; the
                  paper's Theorem 1 asserts this sequence is non-increasing.
    """

    coef: jax.Array
    residual: jax.Array
    sse: jax.Array
    n_sweeps: jax.Array
    converged: jax.Array
    history: jax.Array


class SelectResult(NamedTuple):
    """Result of SolveBakF greedy feature selection.

    Attributes:
      selected:  (max_feat,) int32 indices of selected columns, in selection
                 order.
      coef:      (max_feat,) fp32 coefficients of the refit on the selected
                 columns (aligned with ``selected``).
      sse_path:  (max_feat,) fp32 SSE after each selection + refit step — the
                 greedy error-reduction path.
      residual:  (obs,) fp32 final residual.
    """

    selected: jax.Array
    coef: jax.Array
    sse_path: jax.Array
    residual: jax.Array


def column_norms_sq(x: jax.Array) -> jax.Array:
    """Squared column norms ``⟨x_j, x_j⟩`` accumulated in fp32, shape (vars,)."""
    xf = x.astype(jnp.float32)
    return jnp.einsum("ij,ij->j", xf, xf)


def safe_inv(cn: jax.Array) -> jax.Array:
    """1/cn with zero (not inf) for zero-norm columns.

    A zero column can never reduce the residual, so the paper's ``da`` is
    defined as 0 for it; this keeps the update well-posed.
    """
    return jnp.where(cn > 0.0, 1.0 / jnp.where(cn > 0.0, cn, 1.0), 0.0)


def sweep_stop_flags(sse, sse_prev, sse0, atol_sse, rtol):
    """Per-sweep stopping decision shared by every iterative solver.

    Returns ``(converged, stop)``:

      * ``stop`` — the loop should exit: the absolute tolerance fired, the
        sweep improved SSE by less than ``rtol * sse_prev``, or SSE *rose*
        (no further progress is coming from more sweeps either way).
      * ``converged`` — whether that exit may be reported as success.  An
        SSE rise splits on net progress: staying at/near the starting
        ``sse0`` (within a 1% band — float-accumulation jitter, e.g. a cold
        run stalled at its accuracy floor or a warm start that was already
        at the fixed point) is a stall and reports True exactly like the
        classic rtol exit, while ending materially *above* ``sse0`` is
        genuine divergence (Jacobi-within-block with correlated columns /
        too-large ω blows up geometrically, so it clears the band within a
        sweep) and reports False.  Without the distinction,
        ``(sse_prev - sse) <= rtol * sse_prev`` is trivially true for any
        SSE increase and a diverging solve would stop after one sweep
        claiming success.

    With ``rtol == 0`` the relative/divergence checks are off (the solve
    runs its full ``max_iter`` budget exactly as before).
    """
    improved = sse <= sse_prev
    hit_atol = (atol_sse > 0.0) & (sse <= atol_sse)
    hit_rtol = (rtol > 0.0) & improved & ((sse_prev - sse) <= rtol * sse_prev)
    rose = (rtol > 0.0) & ~improved
    converged = hit_atol | hit_rtol | (rose & (sse <= 1.01 * sse0))
    return converged, hit_atol | hit_rtol | rose
