"""Shared result/record types for the BAK solver family."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SolveResult(NamedTuple):
    """Result of a linear-system solve.

    Attributes:
      coef:       (vars,) solution vector ``a`` with ``x @ a ≈ y``; for a
                  multi-RHS solve (``y`` of shape (obs, k)): (vars, k).
      residual:   (obs,) final residual ``e = y - x @ a`` (fp32); multi-RHS:
                  (obs, k).
      sse:        scalar fp32 sum of squared residuals at exit (multi-RHS:
                  total over all k systems).
      n_sweeps:   scalar int32, number of full sweeps executed.
      converged:  scalar bool, True if a tolerance criterion fired before
                  ``max_iter`` was exhausted.
      history:    (max_iter,) fp32 SSE after each sweep (NaN for sweeps not
                  executed).  Used by the convergence benchmarks/tests; the
                  paper's Theorem 1 asserts this sequence is non-increasing.
    """

    coef: jax.Array
    residual: jax.Array
    sse: jax.Array
    n_sweeps: jax.Array
    converged: jax.Array
    history: jax.Array


class SelectResult(NamedTuple):
    """Result of SolveBakF greedy feature selection.

    Attributes:
      selected:  (max_feat,) int32 indices of selected columns, in selection
                 order.
      coef:      (max_feat,) fp32 coefficients of the refit on the selected
                 columns (aligned with ``selected``).
      sse_path:  (max_feat,) fp32 SSE after each selection + refit step — the
                 greedy error-reduction path.
      residual:  (obs,) fp32 final residual.
    """

    selected: jax.Array
    coef: jax.Array
    sse_path: jax.Array
    residual: jax.Array


def column_norms_sq(x: jax.Array) -> jax.Array:
    """Squared column norms ``⟨x_j, x_j⟩`` accumulated in fp32, shape (vars,)."""
    xf = x.astype(jnp.float32)
    return jnp.einsum("ij,ij->j", xf, xf)


def safe_inv(cn: jax.Array) -> jax.Array:
    """1/cn with zero (not inf) for zero-norm columns.

    A zero column can never reduce the residual, so the paper's ``da`` is
    defined as 0 for it; this keeps the update well-posed.
    """
    return jnp.where(cn > 0.0, 1.0 / jnp.where(cn > 0.0, cn, 1.0), 0.0)
