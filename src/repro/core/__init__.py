"""repro.core — the paper's contribution: the BAK solver family.

Layout:
  solvebak.py     Algorithm 1 (serial cyclic CD) — paper-faithful baseline.
  solvebakp.py    Algorithm 2 (block-parallel CD) + beyond-paper gram mode.
  solvebakf.py    Algorithm 3 (greedy feature selection) + stepwise baseline.
  distributed.py  shard_map obs-/vars-/2D-/rhs-sharded pod-scale solvers
                  (multi-RHS + warm-start capable, serving-placement ready).
  precondition.py column normalisation.
  api.py          public entry points (solve, fit_linear_probe).
"""
from repro.core.api import fit_linear_probe, solve
from repro.core.distributed import (
    solvebakp_2d,
    solvebakp_obs_sharded,
    solvebakp_rhs_sharded,
    solvebakp_vars_sharded,
)
from repro.core.precondition import normalize_columns, unscale_coef
from repro.core.solvebak import solvebak, solvebak_onesweep
from repro.core.solvebakf import solvebakf, stepwise_regression_baseline
from repro.core.solvebakp import block_gram_cholesky, solvebakp
from repro.core.types import SelectResult, SolveResult

__all__ = [
    "SelectResult",
    "SolveResult",
    "block_gram_cholesky",
    "fit_linear_probe",
    "normalize_columns",
    "solve",
    "solvebak",
    "solvebak_onesweep",
    "solvebakf",
    "solvebakp",
    "solvebakp_2d",
    "solvebakp_obs_sharded",
    "solvebakp_rhs_sharded",
    "solvebakp_vars_sharded",
    "stepwise_regression_baseline",
    "unscale_coef",
]
