"""repro.core — the paper's contribution: the BAK solver family.

Public API model (PR 4): a frozen ``SolverSpec`` names the method + every
knob; ``prepare(x, spec)`` builds a ``PreparedDesign`` handle owning the
reusable per-design state (fingerprint, column norms, block-Gram Cholesky,
sharded copies, warm-start coefficients); ``handle.solve(y, a0)`` runs cheap
per-RHS solves.  ``solve()``/``fit_linear_probe`` are one-shot shims over
that model.  Methods live in a registry (``register_method``) — the serving
stack dispatches through it, so new backends plug in without touching it.

Layout:
  spec.py         SolverSpec + the method registry (MethodEntry).
  prepare.py      prepare()/PreparedDesign — the design-handle API.
  methods.py      built-in method registrations (bak/bakp/bakp_gram/bakf/
                  lstsq/normal) as PreparedDesign-consuming kernels.
  solvebak.py     Algorithm 1 (serial cyclic CD) — paper-faithful baseline.
  solvebakp.py    Algorithm 2 (block-parallel CD) + beyond-paper gram mode.
  solvebakf.py    Algorithm 3 (greedy feature selection) + stepwise baseline.
  distributed.py  shard_map obs-/vars-/2D-/rhs-sharded pod-scale solvers
                  (multi-RHS + warm-start capable, serving-placement ready).
  precondition.py column normalisation.
  api.py          one-shot entry points (solve, fit_linear_probe).
"""
from repro.core.api import fit_linear_probe, solve
from repro.core.distributed import (
    solvebakp_2d,
    solvebakp_obs_sharded,
    solvebakp_rhs_sharded,
    solvebakp_vars_sharded,
)
from repro.core.precondition import normalize_columns, unscale_coef
from repro.core.prepare import PreparedDesign, design_fingerprint, prepare
from repro.core.solvebak import solvebak, solvebak_onesweep
from repro.core.solvebakf import solvebakf, stepwise_regression_baseline
from repro.core.solvebakp import block_gram_cholesky, solvebakp
from repro.core.spec import (PRECISIONS, MethodEntry, SolverSpec,
                             UnsupportedSpecError, method_names,
                             methods_for_precision, register_method,
                             solver_method)
from repro.core.types import SelectResult, SolveResult

__all__ = [
    "MethodEntry",
    "PRECISIONS",
    "PreparedDesign",
    "SelectResult",
    "SolveResult",
    "SolverSpec",
    "UnsupportedSpecError",
    "block_gram_cholesky",
    "design_fingerprint",
    "fit_linear_probe",
    "method_names",
    "methods_for_precision",
    "normalize_columns",
    "prepare",
    "register_method",
    "solve",
    "solvebak",
    "solvebak_onesweep",
    "solvebakf",
    "solvebakp",
    "solvebakp_2d",
    "solvebakp_obs_sharded",
    "solvebakp_rhs_sharded",
    "solvebakp_vars_sharded",
    "solver_method",
    "stepwise_regression_baseline",
    "unscale_coef",
]
