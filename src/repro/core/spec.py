"""SolverSpec + the solver-method registry — the public solver configuration.

The paper's structural split — everything reusable about the design matrix
is computable once up front, while each solve streams ``x`` against one (or
k) right-hand sides — is expressed here as two first-class objects:

  * ``SolverSpec``: a frozen, hashable bag of every solver knob.  It replaces
    the ``method="..."`` string plus loose kwargs that used to be duplicated
    across ``core.solve()``, ``serve.SolveRequest`` and the serving cache.
    Because it is hashable it keys compiled-program caches and serving batch
    groups directly.
  * the **method registry**: each solver method ("bak", "bakp", "bakp_gram",
    "bakf", "lstsq", "normal", ...) is a ``MethodEntry`` naming its kernel
    (a callable consuming a ``repro.core.prepare.PreparedDesign``), the spec
    fields it consumes, and its serving capabilities (multi-RHS?
    vmap-batchable? mesh-shardable?).  New backends register one entry plus
    an optional prepare hook instead of patching dispatch sites in
    ``core.api``, the serving engine, the placement policy and the async
    dispatcher.

This module is dependency-light on purpose (no jax import): specs are
constructed by CLIs and request validators that must stay cheap, and the
registry is populated by ``repro.core.methods`` at package import.

``SolverSpec`` semantics shared by every method:

  * ``atol``/``rtol`` — iterative stopping tolerances (see ``solvebak``);
    direct methods ("lstsq"/"normal") ignore them.
  * ``a0`` warm starts are a *solve-time* argument, not a spec field; direct
    methods ignore ``a0`` entirely (this is THE place that documents it —
    the per-solver docstrings defer here).
  * ``ridge`` — Tikhonov diagonal used by the "normal" baseline's normal
    equations AND by ``mode="gram"`` block factorisations (previously a
    hardcoded 1e-6 inside ``solve()``).
  * fields a method does not consume (``MethodEntry.consumes``) are ignored
    by it; ``canonical()`` resets them to defaults so equivalent specs
    compare/hash equal — serving uses this to coalesce requests whose knob
    differences are irrelevant to their method.
  * ``precision`` — the X-streaming storage precision (PR 7):

      - ``"fp32"``        — full-precision design everywhere (default; a
        spec constructed without the field is bit-identical in hash and
        equality to a pre-precision-API spec, so serving/cache keys never
        cold-start on upgrade).
      - ``"bf16"``        — the kernels stream a bf16 resident copy of X
        (half the HBM traffic, double the design size that fits the fused
        megakernel's VMEM budget) while every accumulator — residual,
        coefficients, SSE, column norms — stays fp32.  Accuracy lands at
        the bf16 representation floor (~1e-2 relative).
      - ``"bf16_fp32acc"`` — the bf16 stream plus ``refine_sweeps`` fp32
        polish sweeps (iterative refinement: the residual is recomputed in
        fp32 from the solved coefficients, then swept against the fp32
        design), recovering full fp32 accuracy the same way the sketching
        literature recovers it from a cheap approximate first pass.

    A method advertises what it can run via ``MethodEntry.precisions``;
    requesting an unsupported combination raises the typed
    ``UnsupportedSpecError`` from ``prepare``/``PreparedDesign.solve``
    (the serving engine instead downgrades to "fp32" and counts a
    ``solver_fallback_total{reason="precision"}``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

# Spec fields every iterative BAK-family method consumes.
_ITER_FIELDS = ("max_iter", "atol", "rtol")

# Recognised SolverSpec.precision values (storage precision of the X
# stream; accumulators are always fp32 — see module doc).
PRECISIONS = ("fp32", "bf16", "bf16_fp32acc")

# Default fp32 polish budget for precision="bf16_fp32acc" (also what
# canonical() resets refine_sweeps to when the precision ignores it).
_REFINE_DEFAULT = 4


class UnsupportedSpecError(ValueError):
    """A structurally valid ``SolverSpec`` names a capability its method
    does not implement (e.g. ``precision="bf16"`` on a method whose
    ``MethodEntry.precisions`` is fp32-only).

    A subclass of ``ValueError`` so pre-existing error handling keeps
    working, but typed so callers (the serving engine's downgrade path,
    batch validators) can catch exactly this case without string-matching
    assorted ValueErrors.
    """


@dataclass(frozen=True)
class SolverSpec:
    """Frozen, hashable solver configuration.

    Attributes:
      method:   registry name of the solver method (see ``method_names()``).
      max_iter: sweep budget for iterative methods.
      atol:     absolute RMSE tolerance (0 disables).
      rtol:     relative per-sweep improvement tolerance (0 disables).
      thr:      block width for the SolveBakP family (paper thread count).
      omega:    block-update relaxation factor (1.0 = paper-faithful).
      order:    column order for "bak": "cyclic" or "random" (the latter
                needs a PRNG ``key`` at solve time).
      ridge:    Tikhonov diagonal for the "normal" baseline and for
                ``mode="gram"`` block Gram factorisations.
      precision: storage precision of the X stream — "fp32" (default),
                "bf16" or "bf16_fp32acc" (see module doc).  Accumulators
                are always fp32; "bf16_fp32acc" adds the fp32 polish.
      refine_sweeps: fp32 polish-sweep budget for "bf16_fp32acc" (the
                polish still honours ``atol``/``rtol`` early exit, so this
                is a cap, not a fixed cost).  Ignored by every other
                precision — ``canonical()`` resets it there.

    Warm starts (``a0``) and PRNG keys are solve-time arguments — see
    ``PreparedDesign.solve``.  Direct methods ignore ``a0``.
    """

    method: str = "bakp_gram"
    max_iter: int = 50
    atol: float = 0.0
    rtol: float = 0.0
    thr: int = 128
    omega: float = 1.0
    order: str = "cyclic"
    ridge: float = 1e-6
    precision: str = "fp32"
    refine_sweeps: int = _REFINE_DEFAULT

    def __post_init__(self):
        # Type-normalise so e.g. rtol=0 and rtol=0.0 hash identically
        # (specs key program caches and serving groups).  Knob *values* are
        # deliberately not range-checked here: the kernels validate at
        # trace/call time, which lets the serving engine isolate a poisoned
        # request's batch instead of failing a whole flush at grouping.
        object.__setattr__(self, "max_iter", int(self.max_iter))
        object.__setattr__(self, "thr", int(self.thr))
        object.__setattr__(self, "refine_sweeps", int(self.refine_sweeps))
        for f in ("atol", "rtol", "omega", "ridge"):
            object.__setattr__(self, f, float(getattr(self, f)))
        # precision names a closed value set, so it IS range-checked here
        # (a typo'd precision is a malformed spec, not a per-kernel knob);
        # whether a given *method* supports it is a capability question
        # answered later by ensure_precision_supported — the split lets the
        # serving engine downgrade unsupported combinations instead of
        # rejecting the request at construction.
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, "
                f"got {self.precision!r}")
        # Unknown methods fail on use (registry population happens at
        # repro.core import); validate eagerly when the registry is live.
        if _REGISTRY and self.method not in _REGISTRY:
            raise ValueError(
                f"method must be one of {method_names()}, got {self.method!r}")

    def replace(self, **changes) -> "SolverSpec":
        """A copy with ``changes`` applied (dataclasses.replace)."""
        return dataclasses.replace(self, **changes)

    def canonical(self) -> "SolverSpec":
        """The spec with every field its method ignores reset to defaults.

        Two requests whose canonical specs compare equal can legally share
        one compiled solve — the serving engine groups on this (e.g. any
        mix of ``max_iter``/``thr`` still coalesces under "lstsq").

        Precision normalisation: a method that never consumes ``precision``
        has it reset to "fp32" here, so legacy-kwargs requests, pre-PR-7
        pickled configs and new fp32 requests all land on byte-identical
        grouping/``config_key``/warm-coef keys — no compiled-program or
        cache cold-start on upgrade.  ``refine_sweeps`` only changes the
        result under ``precision="bf16_fp32acc"``, so any other precision
        resets it too (mixed refine budgets still coalesce under fp32).
        """
        entry = solver_method(self.method)
        changes = {
            f.name: f.default
            for f in dataclasses.fields(self)
            if f.name != "method" and f.name not in entry.consumes
        }
        c = self.replace(**changes) if changes else self
        if (c.precision != "bf16_fp32acc"
                and c.refine_sweeps != _REFINE_DEFAULT):
            c = c.replace(refine_sweeps=_REFINE_DEFAULT)
        return c


@dataclass(frozen=True)
class MethodEntry:
    """One registered solver method.

    Attributes:
      name:      registry key (``SolverSpec.method``).
      solve:     kernel ``(prepared, y, spec, *, a0, key, placement, mesh)
                 -> SolveResult`` consuming a ``PreparedDesign``.
      consumes:  SolverSpec fields that change this method's result —
                 drives ``SolverSpec.canonical()`` and therefore serving
                 batch grouping.
      iterative: consumes ``max_iter``/``atol``/``rtol`` and honours ``a0``
                 warm starts (direct methods ignore all four).
      multi_rhs: accepts ``y`` of shape (obs, k) — required for the serving
                 engine's same-design coalescing.
      batchable: vmap-batchable across designs (needs ``vmap_one``).
      shardable: has mesh-sharded backends (``repro.core.distributed``) the
                 serving placement policy may route to.
      blocked:   consumes ``thr`` (SolveBakP family) — tells callers which
                 cached column-norm layout the kernel wants.
      needs_chol: wants precomputed block-Gram Cholesky factors
                 (``PreparedDesign.chol_for``).
      streams:   can solve a *non-resident* ``PreparedDesign`` (one with
                 ``x_pad=None`` whose X blocks are fetched through a
                 ``blocks`` source — the ``repro.store`` tiers).  Methods
                 without it raise ``UnsupportedSpecError`` on such handles;
                 the serving engine reroutes over-budget designs to a
                 streaming method instead (``"bakp_stream"``).
      precisions: ``SolverSpec.precision`` values this method can run —
                 the capability the registry/engine/placement check exactly
                 like ``shardable``.  Default fp32-only; the Pallas kernel
                 methods additionally stream a bf16 X
                 (``PreparedDesign.x_bf16_for``) with fp32 accumulators.
      lane:      single-device execution-lane kind for the serving stack
                 ("xla" for the jit'd XLA family, "fused" for the Pallas
                 whole-solve megakernels).  Together with ``shardable`` and
                 ``precisions`` this makes spec→lane routing one registry
                 lookup (``repro.serve.lanes.lane_for``): sharded
                 placements run on their mesh lane, everything else on the
                 method's declared single-device lane.
      prepare:   optional hook ``(prepared, spec) -> None`` warming the
                 per-design state this method reuses (column norms for a
                 given ``thr``, Gram factors, ...); run by ``prepare()`` and
                 by the serving cache's pre-warm path.
      vmap_one:  optional ``(spec) -> one(x, y, cn, atol[, chol][, a0])``
                 per-system callable the serving engine wraps in
                 ``jit(vmap(...))`` for cross-design batches.
      fallback:  name of the method a failed/diverged solve degrades to
                 (the serving engine's retry ladder —
                 ``repro.resilience.ladder``): fused megakernels fall back
                 to their per-sweep XLA family, the resident block-Jacobi
                 methods to the streaming out-of-core path, and the chain
                 bottoms out at the direct ``"lstsq"`` baseline (None =
                 ladder floor).
      summary:   one-line description (shown by ``describe_methods()``).
    """

    name: str
    solve: Callable
    consumes: Tuple[str, ...]
    iterative: bool = True
    multi_rhs: bool = True
    batchable: bool = False
    shardable: bool = False
    blocked: bool = False
    needs_chol: bool = False
    streams: bool = False
    precisions: Tuple[str, ...] = ("fp32",)
    lane: str = "xla"
    prepare: Optional[Callable] = None
    vmap_one: Optional[Callable] = None
    fallback: Optional[str] = None
    summary: str = ""


_REGISTRY: Dict[str, MethodEntry] = {}


def register_method(entry: MethodEntry, *, overwrite: bool = False) -> MethodEntry:
    """Register a solver method.  Third-party backends call this once and
    become dispatchable from ``solve()``, ``prepare()`` and ``repro.serve``
    without touching any of those call sites."""
    if not overwrite and entry.name in _REGISTRY:
        raise ValueError(f"method {entry.name!r} is already registered")
    _REGISTRY[entry.name] = entry
    return entry


def solver_method(name: str) -> MethodEntry:
    """Look up a registered method; raises ValueError on unknown names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {method_names()}, got {name!r}") from None


def method_names() -> Tuple[str, ...]:
    """Registered method names, in registration order."""
    return tuple(_REGISTRY)


def is_registered(name: str) -> bool:
    return name in _REGISTRY


def shardable_methods() -> Tuple[str, ...]:
    """Methods with a mesh-sharded backend (serving placement eligibility)."""
    return tuple(n for n, e in _REGISTRY.items() if e.shardable)


def streaming_methods() -> Tuple[str, ...]:
    """Methods that can solve non-resident (store-backed) designs."""
    return tuple(n for n, e in _REGISTRY.items() if e.streams)


def methods_for_precision(precision: str) -> Tuple[str, ...]:
    """Methods whose registry entry supports ``precision`` (serving/CLI
    eligibility listing, the precision analogue of ``shardable_methods``)."""
    return tuple(n for n, e in _REGISTRY.items() if precision in e.precisions)


def ensure_precision_supported(spec: SolverSpec) -> MethodEntry:
    """Look up ``spec.method`` and verify it implements ``spec.precision``.

    The single choke point for precision capability: ``prepare()`` and
    ``PreparedDesign.solve`` both call it, so an unsupported combination
    always surfaces as the one typed ``UnsupportedSpecError`` (never
    assorted ValueErrors from deep inside a kernel).  Returns the entry so
    callers don't pay a second registry lookup.
    """
    entry = solver_method(spec.method)
    if spec.precision not in entry.precisions:
        raise UnsupportedSpecError(
            f"method {spec.method!r} does not support "
            f"precision={spec.precision!r} (supports {entry.precisions}); "
            f"pick one of methods {methods_for_precision(spec.precision)} "
            f"or precision='fp32'")
    return entry


def batchable_methods() -> Tuple[str, ...]:
    """Methods the serving engine may vmap-batch across designs."""
    return tuple(n for n, e in _REGISTRY.items() if e.batchable)


def describe_methods() -> str:
    """Human-readable registry listing (CLI ``--help`` fodder)."""
    width = max((len(n) for n in _REGISTRY), default=0)
    return "\n".join(f"{e.name:<{width}}  {e.summary}"
                     for e in _REGISTRY.values())
