"""SolverSpec + the solver-method registry — the public solver configuration.

The paper's structural split — everything reusable about the design matrix
is computable once up front, while each solve streams ``x`` against one (or
k) right-hand sides — is expressed here as two first-class objects:

  * ``SolverSpec``: a frozen, hashable bag of every solver knob.  It replaces
    the ``method="..."`` string plus loose kwargs that used to be duplicated
    across ``core.solve()``, ``serve.SolveRequest`` and the serving cache.
    Because it is hashable it keys compiled-program caches and serving batch
    groups directly.
  * the **method registry**: each solver method ("bak", "bakp", "bakp_gram",
    "bakf", "lstsq", "normal", ...) is a ``MethodEntry`` naming its kernel
    (a callable consuming a ``repro.core.prepare.PreparedDesign``), the spec
    fields it consumes, and its serving capabilities (multi-RHS?
    vmap-batchable? mesh-shardable?).  New backends register one entry plus
    an optional prepare hook instead of patching dispatch sites in
    ``core.api``, the serving engine, the placement policy and the async
    dispatcher.

This module is dependency-light on purpose (no jax import): specs are
constructed by CLIs and request validators that must stay cheap, and the
registry is populated by ``repro.core.methods`` at package import.

``SolverSpec`` semantics shared by every method:

  * ``atol``/``rtol`` — iterative stopping tolerances (see ``solvebak``);
    direct methods ("lstsq"/"normal") ignore them.
  * ``a0`` warm starts are a *solve-time* argument, not a spec field; direct
    methods ignore ``a0`` entirely (this is THE place that documents it —
    the per-solver docstrings defer here).
  * ``ridge`` — Tikhonov diagonal used by the "normal" baseline's normal
    equations AND by ``mode="gram"`` block factorisations (previously a
    hardcoded 1e-6 inside ``solve()``).
  * fields a method does not consume (``MethodEntry.consumes``) are ignored
    by it; ``canonical()`` resets them to defaults so equivalent specs
    compare/hash equal — serving uses this to coalesce requests whose knob
    differences are irrelevant to their method.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

# Spec fields every iterative BAK-family method consumes.
_ITER_FIELDS = ("max_iter", "atol", "rtol")


@dataclass(frozen=True)
class SolverSpec:
    """Frozen, hashable solver configuration.

    Attributes:
      method:   registry name of the solver method (see ``method_names()``).
      max_iter: sweep budget for iterative methods.
      atol:     absolute RMSE tolerance (0 disables).
      rtol:     relative per-sweep improvement tolerance (0 disables).
      thr:      block width for the SolveBakP family (paper thread count).
      omega:    block-update relaxation factor (1.0 = paper-faithful).
      order:    column order for "bak": "cyclic" or "random" (the latter
                needs a PRNG ``key`` at solve time).
      ridge:    Tikhonov diagonal for the "normal" baseline and for
                ``mode="gram"`` block Gram factorisations.

    Warm starts (``a0``) and PRNG keys are solve-time arguments — see
    ``PreparedDesign.solve``.  Direct methods ignore ``a0``.
    """

    method: str = "bakp_gram"
    max_iter: int = 50
    atol: float = 0.0
    rtol: float = 0.0
    thr: int = 128
    omega: float = 1.0
    order: str = "cyclic"
    ridge: float = 1e-6

    def __post_init__(self):
        # Type-normalise so e.g. rtol=0 and rtol=0.0 hash identically
        # (specs key program caches and serving groups).  Knob *values* are
        # deliberately not range-checked here: the kernels validate at
        # trace/call time, which lets the serving engine isolate a poisoned
        # request's batch instead of failing a whole flush at grouping.
        object.__setattr__(self, "max_iter", int(self.max_iter))
        object.__setattr__(self, "thr", int(self.thr))
        for f in ("atol", "rtol", "omega", "ridge"):
            object.__setattr__(self, f, float(getattr(self, f)))
        # Unknown methods fail on use (registry population happens at
        # repro.core import); validate eagerly when the registry is live.
        if _REGISTRY and self.method not in _REGISTRY:
            raise ValueError(
                f"method must be one of {method_names()}, got {self.method!r}")

    def replace(self, **changes) -> "SolverSpec":
        """A copy with ``changes`` applied (dataclasses.replace)."""
        return dataclasses.replace(self, **changes)

    def canonical(self) -> "SolverSpec":
        """The spec with every field its method ignores reset to defaults.

        Two requests whose canonical specs compare equal can legally share
        one compiled solve — the serving engine groups on this (e.g. any
        mix of ``max_iter``/``thr`` still coalesces under "lstsq").
        """
        entry = solver_method(self.method)
        changes = {
            f.name: f.default
            for f in dataclasses.fields(self)
            if f.name != "method" and f.name not in entry.consumes
        }
        return self.replace(**changes) if changes else self


@dataclass(frozen=True)
class MethodEntry:
    """One registered solver method.

    Attributes:
      name:      registry key (``SolverSpec.method``).
      solve:     kernel ``(prepared, y, spec, *, a0, key, placement, mesh)
                 -> SolveResult`` consuming a ``PreparedDesign``.
      consumes:  SolverSpec fields that change this method's result —
                 drives ``SolverSpec.canonical()`` and therefore serving
                 batch grouping.
      iterative: consumes ``max_iter``/``atol``/``rtol`` and honours ``a0``
                 warm starts (direct methods ignore all four).
      multi_rhs: accepts ``y`` of shape (obs, k) — required for the serving
                 engine's same-design coalescing.
      batchable: vmap-batchable across designs (needs ``vmap_one``).
      shardable: has mesh-sharded backends (``repro.core.distributed``) the
                 serving placement policy may route to.
      blocked:   consumes ``thr`` (SolveBakP family) — tells callers which
                 cached column-norm layout the kernel wants.
      needs_chol: wants precomputed block-Gram Cholesky factors
                 (``PreparedDesign.chol_for``).
      prepare:   optional hook ``(prepared, spec) -> None`` warming the
                 per-design state this method reuses (column norms for a
                 given ``thr``, Gram factors, ...); run by ``prepare()`` and
                 by the serving cache's pre-warm path.
      vmap_one:  optional ``(spec) -> one(x, y, cn, atol[, chol][, a0])``
                 per-system callable the serving engine wraps in
                 ``jit(vmap(...))`` for cross-design batches.
      summary:   one-line description (shown by ``describe_methods()``).
    """

    name: str
    solve: Callable
    consumes: Tuple[str, ...]
    iterative: bool = True
    multi_rhs: bool = True
    batchable: bool = False
    shardable: bool = False
    blocked: bool = False
    needs_chol: bool = False
    prepare: Optional[Callable] = None
    vmap_one: Optional[Callable] = None
    summary: str = ""


_REGISTRY: Dict[str, MethodEntry] = {}


def register_method(entry: MethodEntry, *, overwrite: bool = False) -> MethodEntry:
    """Register a solver method.  Third-party backends call this once and
    become dispatchable from ``solve()``, ``prepare()`` and ``repro.serve``
    without touching any of those call sites."""
    if not overwrite and entry.name in _REGISTRY:
        raise ValueError(f"method {entry.name!r} is already registered")
    _REGISTRY[entry.name] = entry
    return entry


def solver_method(name: str) -> MethodEntry:
    """Look up a registered method; raises ValueError on unknown names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {method_names()}, got {name!r}") from None


def method_names() -> Tuple[str, ...]:
    """Registered method names, in registration order."""
    return tuple(_REGISTRY)


def is_registered(name: str) -> bool:
    return name in _REGISTRY


def shardable_methods() -> Tuple[str, ...]:
    """Methods with a mesh-sharded backend (serving placement eligibility)."""
    return tuple(n for n, e in _REGISTRY.items() if e.shardable)


def batchable_methods() -> Tuple[str, ...]:
    """Methods the serving engine may vmap-batch across designs."""
    return tuple(n for n, e in _REGISTRY.items() if e.batchable)


def describe_methods() -> str:
    """Human-readable registry listing (CLI ``--help`` fodder)."""
    width = max((len(n) for n in _REGISTRY), default=0)
    return "\n".join(f"{e.name:<{width}}  {e.summary}"
                     for e in _REGISTRY.values())
