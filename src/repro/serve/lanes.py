"""Execution lanes — per-placement executor threads for the serving stack.

A **lane** is a (device set, kernel path) pair with its own executor
thread, its own most-urgent-first queue of fired batches, and its own
compiled-program affinity.  The async dispatcher used to flush every batch
through ONE solver thread, so single-device fused solves, vmapped
micro-batches and mesh-sharded solves serialised behind each other even
when they targeted disjoint devices/program families.  Lanes let them
overlap: the engine's ``flush()`` builds batches and *submits* work units
here, the dispatcher routes each fired batch to its lane, and each lane
drains independently.

Routing is one table lookup (``lane_for``): a sharded ``Placement`` maps to
its mesh lane (kind + the mesh's device ids), everything else to the
method's registry-declared single-device lane (``MethodEntry.lane`` —
"xla" for the jit'd family, "fused" for the Pallas megakernels, "stream"
for the out-of-core ``"bakp_stream"`` solves, whose host/disk block
fetches would otherwise stall resident-path batches) on the default
device.  ``Placement.lane_key`` supplies the kind half of the
identity; ``LaneKey.devices`` the device-set half, so two engines on
disjoint meshes get disjoint lanes while one engine's repeat buckets share
theirs.

Concurrency contract:

  * one thread per lane, started lazily on first submit — an engine that
    only ever solves single-device xla traffic runs exactly one lane
    thread, same threading footprint as the old architecture;
  * per-lane FIFO broken by urgency: works submit with an ``urgency``
    (the dispatcher passes the batch's most urgent absolute deadline;
    ``inf`` = plain FIFO by submission order);
  * ``LanePool(serial=True)`` maps every key to ONE ``"serial"`` lane —
    the old single-solver-thread architecture, kept as the benchmark
    baseline and reachable via ``ServeConfig(lane_execution=False)``;
  * ``current_lane()`` marks lane threads (thread-local): engine flushes
    nested inside a lane work run their units inline instead of
    re-submitting, so a lane can never deadlock waiting on itself;
  * per-lane gauges (``serve_lane_queue_depth`` / ``serve_lane_inflight``)
    and a ``LaneStats`` counter mirror record into the engine's registry.

Shutdown: ``shutdown(drain=True)`` finishes queued work then parks the
thread; ``drain=False`` abandons queued works (their ``error`` is set and
their events fire, so no waiter hangs) and stops after the in-flight work
completes.
"""
from __future__ import annotations

import heapq
import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro import obs
from repro.serve.placement import Placement, ServeMesh

_SINGLE = Placement()


def _device_ids(smesh: Optional[ServeMesh] = None) -> Tuple[int, ...]:
    """Device-set identity for a lane (mesh devices, or the default
    device).  Imported lazily so this module stays importable before jax
    backend selection."""
    import jax

    if smesh is not None:
        return tuple(int(d.id) for d in smesh.mesh.devices.flat)
    return (int(jax.devices()[0].id),)


@dataclass(frozen=True)
class LaneKey:
    """Identity of one execution lane: the placement/kernel-path kind
    (``Placement.lane_key`` string, e.g. ``"single:xla"``, ``"single:fused"``,
    ``"mesh:obs_sharded"``) plus the device ids it owns.  Frozen/hashable:
    keys the pool's executor map and the per-lane metric labels."""

    label: str
    devices: Tuple[int, ...] = ()


#: The one lane of a ``LanePool(serial=True)`` — the legacy architecture.
SERIAL_LANE = LaneKey("serial", ())


def lane_for(method: str, placement: Optional[Placement] = None,
             smesh: Optional[ServeMesh] = None) -> LaneKey:
    """spec→lane routing: one registry/placement table lookup.

    Sharded placements (with a live mesh) own the mesh's whole device set;
    single-device methods land on the default device under their registry
    ``MethodEntry.lane`` kind.
    """
    if placement is not None and placement.sharded and smesh is not None:
        return LaneKey(placement.lane_key(method), _device_ids(smesh))
    return LaneKey((placement or _SINGLE).lane_key(method), _device_ids())


class LaneWork:
    """One unit of lane work: a zero-arg callable plus completion event.

    ``urgency`` orders the lane's queue (lower = sooner; ties resolve
    FIFO by submission sequence).  ``error`` carries an exception the
    callable raised (or the shutdown abandonment), for the waiter to
    re-raise or translate; the event always fires, so waiters never hang.
    """

    __slots__ = ("fn", "urgency", "size", "tag", "enqueued_at",
                 "started_at", "error", "_event")

    def __init__(self, fn: Callable[[], None], urgency: float = float("inf"),
                 size: int = 1, tag: str = ""):
        self.fn = fn
        self.urgency = float(urgency)
        self.size = int(size)
        self.tag = tag
        self.enqueued_at = obs.now()
        self.started_at: Optional[float] = None
        self.error: Optional[BaseException] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)


@dataclass
class LaneStats:
    """Per-lane counters (convenience mirror of the ``serve_lane_*``
    gauges; see ``ServeStats`` for the pattern)."""

    batches: int = 0
    requests: int = 0
    failures: int = 0
    busy_s: float = 0.0
    max_queue_depth: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class LaneShutdown(RuntimeError):
    """The lane was shut down before (or while) the work could run."""


# Thread-local lane marker: set once per executor thread, read by the
# engine to run nested flushes inline (a lane must never block on itself).
_lane_local = threading.local()


def current_lane() -> Optional[LaneKey]:
    """The ``LaneKey`` of the lane thread we are on (None elsewhere)."""
    return getattr(_lane_local, "current", None)


class LaneExecutor:
    """One lane: a daemon thread draining a most-urgent-first work heap."""

    def __init__(self, key: LaneKey,
                 registry: Optional[obs.MetricsRegistry] = None):
        self.key = key
        self.stats = LaneStats()
        reg = registry or obs.default_registry()
        self._g_depth = reg.gauge(
            "serve_lane_queue_depth",
            "fired batches waiting per execution lane").labels(
                lane=key.label)
        self._g_inflight = reg.gauge(
            "serve_lane_inflight",
            "batches submitted and not yet finished per execution "
            "lane").labels(lane=key.label)
        self._cv = threading.Condition()
        self._heap: List[Tuple[float, int, LaneWork]] = []
        self._seq = 0
        self._inflight = 0      # submitted, not yet finished
        self._stopping = False
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ submit
    def submit(self, work: LaneWork) -> LaneWork:
        with self._cv:
            if self._stopping:
                raise LaneShutdown(f"lane {self.key.label} is shut down")
            heapq.heappush(self._heap, (work.urgency, self._seq, work))
            self._seq += 1
            self._inflight += 1
            depth = len(self._heap)
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             depth)
            self._g_depth.set(depth)
            self._g_inflight.set(self._inflight)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop,
                    name=f"serve-lane-{self.key.label}", daemon=True)
                self._thread.start()
            self._cv.notify_all()
        return work

    # -------------------------------------------------------------- loop
    def _loop(self) -> None:
        _lane_local.current = self.key
        while True:
            with self._cv:
                while not self._heap and not self._stopping:
                    self._cv.wait()
                if not self._heap:  # stopping and drained
                    return
                _, _, work = heapq.heappop(self._heap)
                self._g_depth.set(len(self._heap))
            t0 = obs.now()
            work.started_at = t0
            try:
                work.fn()
            except BaseException as exc:  # surfaced via work.error
                work.error = exc
                self.stats.failures += 1
            dt = obs.now() - t0
            with self._cv:
                self.stats.batches += 1
                self.stats.requests += work.size
                self.stats.busy_s += dt
                self._inflight -= 1
                self._g_inflight.set(self._inflight)
                self._cv.notify_all()
            work._event.set()

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every submitted work has finished."""
        deadline = None if timeout is None else obs.now() + timeout
        with self._cv:
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - obs.now())
                if remaining is not None and remaining <= 0:
                    return False
                self._cv.wait(remaining)
        return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the lane.  ``drain`` (default) runs queued work first;
        otherwise queued works are abandoned (``error`` set, events fired)
        and only the in-flight work completes."""
        abandoned: List[LaneWork] = []
        with self._cv:
            self._stopping = True
            if not drain and self._heap:
                abandoned = [w for _, _, w in self._heap]
                self._heap.clear()
                self._inflight -= len(abandoned)
                self._g_depth.set(0)
                self._g_inflight.set(self._inflight)
            self._cv.notify_all()
            thread = self._thread
        for w in abandoned:
            w.error = LaneShutdown(f"lane {self.key.label} shut down")
            w._event.set()
        if thread is not None:
            thread.join(timeout)

    @property
    def inflight(self) -> int:
        with self._cv:
            return self._inflight


class LanePool:
    """Lazily-created ``LaneExecutor`` map, keyed by ``LaneKey``.

    ``serial=True`` collapses every key to ``SERIAL_LANE`` — one executor
    thread for everything, i.e. exactly the pre-lane single-solver-thread
    architecture (``ServeConfig.lane_execution=False`` and the benchmark
    baseline use this).
    """

    def __init__(self, registry: Optional[obs.MetricsRegistry] = None,
                 serial: bool = False):
        self.registry = registry or obs.default_registry()
        self.serial = serial
        self._lock = threading.Lock()
        self._lanes: Dict[LaneKey, LaneExecutor] = {}

    # ----------------------------------------------------------- routing
    def lane_for(self, method: str, placement: Optional[Placement] = None,
                 smesh: Optional[ServeMesh] = None) -> LaneKey:
        if self.serial:
            return SERIAL_LANE
        return lane_for(method, placement, smesh)

    def executor(self, key: LaneKey) -> LaneExecutor:
        with self._lock:
            ex = self._lanes.get(key)
            if ex is None:
                ex = self._lanes[key] = LaneExecutor(key, self.registry)
            return ex

    def submit(self, key: LaneKey, work: LaneWork) -> LaneWork:
        return self.executor(key).submit(work)

    # ------------------------------------------------------------- reads
    def lane_keys(self) -> List[LaneKey]:
        with self._lock:
            return list(self._lanes)

    def stats(self) -> Dict[str, dict]:
        """Per-lane counters keyed by lane label (live lanes only)."""
        with self._lock:
            lanes = dict(self._lanes)
        return {k.label: ex.stats.as_dict() for k, ex in lanes.items()}

    @property
    def inflight(self) -> int:
        with self._lock:
            lanes = list(self._lanes.values())
        return sum(ex.inflight for ex in lanes)

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else obs.now() + timeout
        with self._lock:
            lanes = list(self._lanes.values())
        ok = True
        for ex in lanes:
            remaining = None if deadline is None else deadline - obs.now()
            ok = ex.drain(remaining) and ok
        return ok

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop every lane thread.  The pool stays usable: stopped lanes
        are dropped from the map, so a later submit lazily starts a fresh
        executor for its key (their ``LaneStats`` start over)."""
        with self._lock:
            lanes, self._lanes = list(self._lanes.values()), {}
        for ex in lanes:
            ex.shutdown(drain=drain, timeout=timeout)
