"""Execution lanes — per-placement executor threads for the serving stack.

A **lane** is a (device set, kernel path) pair with its own executor
thread, its own most-urgent-first queue of fired batches, and its own
compiled-program affinity.  The async dispatcher used to flush every batch
through ONE solver thread, so single-device fused solves, vmapped
micro-batches and mesh-sharded solves serialised behind each other even
when they targeted disjoint devices/program families.  Lanes let them
overlap: the engine's ``flush()`` builds batches and *submits* work units
here, the dispatcher routes each fired batch to its lane, and each lane
drains independently.

Routing is one table lookup (``lane_for``): a sharded ``Placement`` maps to
its mesh lane (kind + the mesh's device ids), everything else to the
method's registry-declared single-device lane (``MethodEntry.lane`` —
"xla" for the jit'd family, "fused" for the Pallas megakernels, "stream"
for the out-of-core ``"bakp_stream"`` solves, whose host/disk block
fetches would otherwise stall resident-path batches) on the default
device.  ``Placement.lane_key`` supplies the kind half of the
identity; ``LaneKey.devices`` the device-set half, so two engines on
disjoint meshes get disjoint lanes while one engine's repeat buckets share
theirs.

Concurrency contract:

  * one thread per lane, started lazily on first submit — an engine that
    only ever solves single-device xla traffic runs exactly one lane
    thread, same threading footprint as the old architecture;
  * per-lane FIFO broken by urgency: works submit with an ``urgency``
    (the dispatcher passes the batch's most urgent absolute deadline;
    ``inf`` = plain FIFO by submission order);
  * ``LanePool(serial=True)`` maps every key to ONE ``"serial"`` lane —
    the old single-solver-thread architecture, kept as the benchmark
    baseline and reachable via ``ServeConfig(lane_execution=False)``;
  * ``current_lane()`` marks lane threads (thread-local): engine flushes
    nested inside a lane work run their units inline instead of
    re-submitting, so a lane can never deadlock waiting on itself;
  * per-lane gauges (``serve_lane_queue_depth`` / ``serve_lane_inflight``)
    and a ``LaneStats`` counter mirror record into the engine's registry.

Shutdown: ``shutdown(drain=True)`` finishes queued work then parks the
thread; ``drain=False`` abandons queued works (their ``error`` is set and
their events fire, so no waiter hangs) and stops after the in-flight work
completes.

Supervision (PR 10): lane executors survive worker-thread death.  An
exception escaping the loop *outside* the per-work try (a harness bug, or
the ``"lane.worker"`` fault-injection site) fails only the in-flight work
(its ``error``/``on_fail``/event fire, so no waiter hangs and the
dispatcher can claim-and-fail its tickets), counts
``serve_lane_restarts_total{lane}``, dips the ``serve_lane_health`` gauge
to 0, and hands the intact queue to a fresh worker thread after a
jittered, bounded backoff.  After ``max_restarts`` *consecutive* crashes
(any completed work resets the streak) the lane's circuit breaker trips:
health pins at 0, queued works are rerouted, and ``LanePool.submit``
sends all later traffic for that key to the ``SERIAL_LANE`` fallback
executor (which never trips — it restarts forever, the fallback of last
resort).
"""
from __future__ import annotations

import heapq
import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro import obs
from repro.resilience import faults
from repro.serve.placement import Placement, ServeMesh

_SINGLE = Placement()


def _device_ids(smesh: Optional[ServeMesh] = None) -> Tuple[int, ...]:
    """Device-set identity for a lane (mesh devices, or the default
    device).  Imported lazily so this module stays importable before jax
    backend selection."""
    import jax

    if smesh is not None:
        return tuple(int(d.id) for d in smesh.mesh.devices.flat)
    return (int(jax.devices()[0].id),)


@dataclass(frozen=True)
class LaneKey:
    """Identity of one execution lane: the placement/kernel-path kind
    (``Placement.lane_key`` string, e.g. ``"single:xla"``, ``"single:fused"``,
    ``"mesh:obs_sharded"``) plus the device ids it owns.  Frozen/hashable:
    keys the pool's executor map and the per-lane metric labels."""

    label: str
    devices: Tuple[int, ...] = ()


#: The one lane of a ``LanePool(serial=True)`` — the legacy architecture.
SERIAL_LANE = LaneKey("serial", ())


def lane_for(method: str, placement: Optional[Placement] = None,
             smesh: Optional[ServeMesh] = None) -> LaneKey:
    """spec→lane routing: one registry/placement table lookup.

    Sharded placements (with a live mesh) own the mesh's whole device set;
    single-device methods land on the default device under their registry
    ``MethodEntry.lane`` kind.
    """
    if placement is not None and placement.sharded and smesh is not None:
        return LaneKey(placement.lane_key(method), _device_ids(smesh))
    return LaneKey((placement or _SINGLE).lane_key(method), _device_ids())


class LaneWork:
    """One unit of lane work: a zero-arg callable plus completion event.

    ``urgency`` orders the lane's queue (lower = sooner; ties resolve
    FIFO by submission sequence).  ``error`` carries an exception the
    callable raised (or the shutdown abandonment), for the waiter to
    re-raise or translate; the event always fires, so waiters never hang.

    ``on_fail`` (optional) is invoked with the exception when the work is
    failed *without its callable completing* — worker-thread death,
    shutdown abandonment, a tripped breaker with no reroute — before the
    event fires.  The dispatcher uses it to claim-and-fail the work's
    tickets so ``drain()`` never waits on a dead lane; it must be cheap
    and must not raise (failures are swallowed).
    """

    __slots__ = ("fn", "urgency", "size", "tag", "on_fail", "enqueued_at",
                 "started_at", "error", "_event")

    def __init__(self, fn: Callable[[], None], urgency: float = float("inf"),
                 size: int = 1, tag: str = "",
                 on_fail: Optional[Callable[[BaseException], None]] = None):
        self.fn = fn
        self.urgency = float(urgency)
        self.size = int(size)
        self.tag = tag
        self.on_fail = on_fail
        self.enqueued_at = obs.now()
        self.started_at: Optional[float] = None
        self.error: Optional[BaseException] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)


@dataclass
class LaneStats:
    """Per-lane counters (convenience mirror of the ``serve_lane_*``
    gauges; see ``ServeStats`` for the pattern)."""

    batches: int = 0
    requests: int = 0
    failures: int = 0
    busy_s: float = 0.0
    max_queue_depth: int = 0
    restarts: int = 0      # worker-thread deaths survived by restart
    tripped: bool = False  # circuit breaker open (rerouting to serial)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class LaneShutdown(RuntimeError):
    """The lane was shut down before (or while) the work could run."""


class LaneWorkerDeath(RuntimeError):
    """The lane's worker thread died while this work was in flight.

    Only the in-flight work gets this error — queued works survive the
    restart.  ``__cause__`` carries the exception that killed the thread.
    """


# Thread-local lane marker: set once per executor thread, read by the
# engine to run nested flushes inline (a lane must never block on itself).
_lane_local = threading.local()


def current_lane() -> Optional[LaneKey]:
    """The ``LaneKey`` of the lane thread we are on (None elsewhere)."""
    return getattr(_lane_local, "current", None)


class LaneExecutor:
    """One lane: a supervised daemon thread draining a most-urgent-first
    work heap.

    Supervision knobs (instance attributes, patchable in tests):
    ``max_restarts`` — consecutive crashes before the circuit breaker
    trips (any completed work resets the streak; a lane with no
    ``on_trip`` reroute — e.g. the serial fallback itself — never trips
    and just keeps restarting); ``backoff_base_s``/``backoff_cap_s`` —
    the jittered exponential restart backoff bounds.
    """

    max_restarts: int = 3
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 1.0

    def __init__(self, key: LaneKey,
                 registry: Optional[obs.MetricsRegistry] = None,
                 max_restarts: Optional[int] = None):
        self.key = key
        self.stats = LaneStats()
        if max_restarts is not None:
            self.max_restarts = int(max_restarts)
        #: Reroute hook the pool installs: called (outside the lane lock)
        #: with the queued works of a lane whose breaker just tripped.
        self.on_trip: Optional[Callable[[List[LaneWork]], None]] = None
        reg = registry or obs.default_registry()
        self._g_depth = reg.gauge(
            "serve_lane_queue_depth",
            "fired batches waiting per execution lane").labels(
                lane=key.label)
        self._g_inflight = reg.gauge(
            "serve_lane_inflight",
            "batches submitted and not yet finished per execution "
            "lane").labels(lane=key.label)
        self._c_restarts = reg.counter(
            "serve_lane_restarts_total",
            "lane worker-thread deaths survived by supervised "
            "restart").labels(lane=key.label)
        self._g_health = reg.gauge(
            "serve_lane_health",
            "1 = lane serving normally, 0 = crashed (restarting) or "
            "circuit-broken").labels(lane=key.label)
        self._g_health.set(1.0)
        self._cv = threading.Condition()
        self._heap: List[Tuple[float, int, LaneWork]] = []
        self._seq = 0
        self._inflight = 0      # submitted, not yet finished
        self._stopping = False
        self._tripped = False
        self._consec_crashes = 0
        self._current: Optional[LaneWork] = None  # worker-thread owned
        self._thread: Optional[threading.Thread] = None

    @property
    def tripped(self) -> bool:
        return self._tripped

    # ------------------------------------------------------------ submit
    def submit(self, work: LaneWork) -> LaneWork:
        with self._cv:
            if self._stopping:
                raise LaneShutdown(f"lane {self.key.label} is shut down")
            if self._tripped:
                raise LaneShutdown(
                    f"lane {self.key.label} circuit breaker is open")
            heapq.heappush(self._heap, (work.urgency, self._seq, work))
            self._seq += 1
            self._inflight += 1
            depth = len(self._heap)
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             depth)
            self._g_depth.set(depth)
            self._g_inflight.set(self._inflight)
            if self._thread is None:
                self._spawn_locked()
            self._cv.notify_all()
        return work

    def _spawn_locked(self) -> None:
        self._thread = threading.Thread(
            target=self._run,
            name=f"serve-lane-{self.key.label}", daemon=True)
        self._thread.start()

    # -------------------------------------------------------------- loop
    def _run(self) -> None:
        """Worker-thread body: the drain loop under a supervisor.

        ``_loop`` returning means a clean stop.  Anything escaping it is
        worker-thread death: ``_handle_crash`` fails ONLY the in-flight
        work (queued works stay on the heap), then — unless the breaker
        tripped — a replacement thread is spawned after a jittered,
        bounded backoff and this one exits.
        """
        _lane_local.current = self.key
        try:
            self._loop()
            return
        except BaseException as exc:
            if not self._handle_crash(exc):
                return  # breaker tripped: health stays 0, no replacement
        delay = min(self.backoff_cap_s,
                    self.backoff_base_s * (2 ** (self._consec_crashes - 1)))
        time.sleep(delay * (0.5 + random.random()))
        with self._cv:
            self._spawn_locked()
        self._g_health.set(1.0)

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._heap and not self._stopping:
                    self._cv.wait()
                if not self._heap:  # stopping and drained
                    return
                _, _, work = heapq.heappop(self._heap)
                self._g_depth.set(len(self._heap))
            t0 = obs.now()
            work.started_at = t0
            self._current = work
            # Chaos sites: a "lane.worker" raise here is OUTSIDE the
            # per-work try — exactly a worker-thread death; "lane.delay"
            # simulates a slow device (deadline storms).  Both are no-ops
            # without an armed FaultPlan.
            faults.maybe_raise("lane.worker", self.key.label)
            faults.maybe_delay("lane.delay", self.key.label)
            try:
                work.fn()
            except BaseException as exc:  # surfaced via work.error
                work.error = exc
                self.stats.failures += 1
            dt = obs.now() - t0
            self._current = None
            with self._cv:
                self.stats.batches += 1
                self.stats.requests += work.size
                self.stats.busy_s += dt
                self._inflight -= 1
                self._consec_crashes = 0  # completed work resets the streak
                self._g_inflight.set(self._inflight)
                self._cv.notify_all()
            work._event.set()

    # -------------------------------------------------------- supervision
    @staticmethod
    def _fail_work(work: LaneWork, exc: BaseException) -> None:
        """Settle a work that will never run its callable to completion:
        error + on_fail + event, so no waiter hangs."""
        work.error = exc
        if work.on_fail is not None:
            try:
                work.on_fail(exc)
            except Exception:
                pass  # on_fail must not take the supervisor down
        work._event.set()

    def _handle_crash(self, exc: BaseException) -> bool:
        """Account one worker-thread death.  Returns True when a
        replacement thread should spawn (False = breaker tripped)."""
        work, self._current = self._current, None
        with self._cv:
            self._consec_crashes += 1
            self.stats.failures += 1
            self.stats.restarts += 1
            if work is not None:
                # Fail ONLY the in-flight work; queued works survive.
                self._inflight -= 1
                self.stats.batches += 1
                self.stats.requests += work.size
            trip = (self.on_trip is not None
                    and self._consec_crashes > self.max_restarts)
            abandoned: List[LaneWork] = []
            if trip:
                self._tripped = True
                self.stats.tripped = True
                abandoned = [w for _, _, w in self._heap]
                self._heap.clear()
                self._inflight -= len(abandoned)
                self._g_depth.set(0)
            self._g_inflight.set(self._inflight)
            self._cv.notify_all()
        self._c_restarts.inc(1)
        self._g_health.set(0.0)
        if work is not None:
            death = LaneWorkerDeath(
                f"lane {self.key.label} worker thread died: "
                f"{type(exc).__name__}: {exc}")
            death.__cause__ = exc
            self._fail_work(work, death)
        if trip and abandoned:
            try:
                self.on_trip(abandoned)
            except Exception:
                for w in abandoned:
                    self._fail_work(w, LaneShutdown(
                        f"lane {self.key.label} circuit breaker open and "
                        f"reroute failed"))
        return not trip

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every submitted work has finished."""
        deadline = None if timeout is None else obs.now() + timeout
        with self._cv:
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - obs.now())
                if remaining is not None and remaining <= 0:
                    return False
                self._cv.wait(remaining)
        return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the lane.  ``drain`` (default) runs queued work first;
        otherwise queued works are abandoned (``error`` set, ``on_fail``
        invoked, events fired) and only the in-flight work completes."""
        abandoned: List[LaneWork] = []
        with self._cv:
            self._stopping = True
            if not drain and self._heap:
                abandoned = [w for _, _, w in self._heap]
                self._heap.clear()
                self._inflight -= len(abandoned)
                self._g_depth.set(0)
                self._g_inflight.set(self._inflight)
            self._cv.notify_all()
            thread = self._thread
        for w in abandoned:
            self._fail_work(w, LaneShutdown(
                f"lane {self.key.label} shut down"))
        # A supervised restart may have handed the queue to a replacement
        # thread while we joined the old one — follow the chain until the
        # live thread is the one we joined.
        while thread is not None:
            thread.join(timeout)
            with self._cv:
                nxt = self._thread
            if nxt is thread or timeout is not None:
                break
            thread = nxt

    @property
    def inflight(self) -> int:
        with self._cv:
            return self._inflight


class LanePool:
    """Lazily-created ``LaneExecutor`` map, keyed by ``LaneKey``.

    ``serial=True`` collapses every key to ``SERIAL_LANE`` — one executor
    thread for everything, i.e. exactly the pre-lane single-solver-thread
    architecture (``ServeConfig.lane_execution=False`` and the benchmark
    baseline use this).

    Circuit breaking: every non-serial executor gets an ``on_trip`` hook
    that reroutes its queued works to the serial fallback executor when
    its breaker opens (> ``max_restarts`` consecutive worker-thread
    deaths), and ``submit`` routes new work for a tripped lane there too —
    the fleet degrades to the pre-lane architecture for that traffic
    instead of erroring it.  The serial lane itself has no ``on_trip`` and
    therefore never trips (it just keeps restarting).
    """

    def __init__(self, registry: Optional[obs.MetricsRegistry] = None,
                 serial: bool = False, max_restarts: int = 3):
        self.registry = registry or obs.default_registry()
        self.serial = serial
        self.max_restarts = max_restarts
        self._lock = threading.Lock()
        self._lanes: Dict[LaneKey, LaneExecutor] = {}

    # ----------------------------------------------------------- routing
    def lane_for(self, method: str, placement: Optional[Placement] = None,
                 smesh: Optional[ServeMesh] = None) -> LaneKey:
        if self.serial:
            return SERIAL_LANE
        return lane_for(method, placement, smesh)

    def executor(self, key: LaneKey) -> LaneExecutor:
        with self._lock:
            ex = self._lanes.get(key)
            if ex is None:
                ex = self._lanes[key] = LaneExecutor(
                    key, self.registry, max_restarts=self.max_restarts)
                if key != SERIAL_LANE:
                    ex.on_trip = self._reroute_serial
            return ex

    def _reroute_serial(self, works: List[LaneWork]) -> None:
        """Trip hook: hand a broken lane's queued works to the serial
        fallback executor (called from the dying lane's thread).  A work
        the serial lane cannot take (pool mid-shutdown) is settled
        individually so the ones already resubmitted are never touched
        twice."""
        serial = self.executor(SERIAL_LANE)
        for w in works:
            try:
                serial.submit(w)
            except Exception as exc:
                LaneExecutor._fail_work(w, exc)

    def submit(self, key: LaneKey, work: LaneWork) -> LaneWork:
        ex = self.executor(key)
        if ex.tripped and key != SERIAL_LANE:
            ex = self.executor(SERIAL_LANE)
        return ex.submit(work)

    # ------------------------------------------------------------- reads
    def lane_keys(self) -> List[LaneKey]:
        with self._lock:
            return list(self._lanes)

    def stats(self) -> Dict[str, dict]:
        """Per-lane counters keyed by lane label (live lanes only)."""
        with self._lock:
            lanes = dict(self._lanes)
        return {k.label: ex.stats.as_dict() for k, ex in lanes.items()}

    @property
    def inflight(self) -> int:
        with self._lock:
            lanes = list(self._lanes.values())
        return sum(ex.inflight for ex in lanes)

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else obs.now() + timeout
        with self._lock:
            lanes = list(self._lanes.values())
        ok = True
        for ex in lanes:
            remaining = None if deadline is None else deadline - obs.now()
            ok = ex.drain(remaining) and ok
        return ok

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop every lane thread.  The pool stays usable: stopped lanes
        are dropped from the map, so a later submit lazily starts a fresh
        executor for its key (their ``LaneStats`` start over)."""
        with self._lock:
            lanes, self._lanes = list(self._lanes.values()), {}
        for ex in lanes:
            ex.shutdown(drain=drain, timeout=timeout)
