"""Placement layer — routing serving buckets onto mesh-sharded solvers.

The engine's compiled programs are keyed by (bucket, solver config); this
module adds the *where*: a ``Placement`` names which backend a bucket's
solves run on, a ``PlacementPolicy`` picks one per bucket from its padded
size, and a ``ServeMesh`` wraps the jax device mesh the sharded placements
run over.  Placement is part of the engine's grouping key, so a compiled
program only ever sees one mesh layout — single-device and sharded solves
never mix inside a batch.

Placements (backends in ``repro.core.distributed``):

  * ``single``       — the jit'd single-device solver family (default; the
                       only placement when the engine has no mesh).
  * ``obs_sharded``  — ``solvebakp_obs_sharded``: design rows shard over the
                       mesh data axes.  Chosen when a bucket's padded
                       ``obs_p × vars_p`` cell count crosses
                       ``obs_shard_min_cells`` — the regime where one
                       device's HBM stream is the bottleneck (or the design
                       no longer fits).  Per-device memory: shard +
                       O(obs/D + vars) overhead.
  * ``rhs_sharded``  — ``solvebakp_rhs_sharded``: a giant same-design
                       multi-RHS group's ``k`` axis shards over the data
                       devices, ``x`` replicated — one stream of ``x`` per
                       device serves k/D tenants, and the group-global SSE
                       stopping keeps results bit-comparable with the
                       single-device coalesced solve.  Chosen per *group*
                       (k is only known after design coalescing) when
                       ``k_pad >= rhs_shard_min_k``.
  * ``mesh_2d``      — ``solvebakp_2d``: rows over data axes AND columns
                       over the model axis; pod-scale designs.  Off by
                       default (``mesh_2d_min_cells=None``) because its
                       cross-device Jacobi block ordering changes the
                       iterates (needs ω damping) — opt in for buckets too
                       wide for a replicated coefficient vector.

Eligibility guards: sharded placements only apply to the block solvers
("bakp"/"bakp_gram" — the distributed backends are SolveBakP-shaped) and
only when the padded bucket divides the mesh axes (power-of-two buckets on
power-of-two meshes, so in practice: bucket at least as large as the axis).
Everything else falls back to ``single``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

import repro.core.methods  # noqa: F401  (populates the method registry)
from repro import obs
from repro.core.spec import is_registered, solver_method

# Module-level hooks record to the global default registry (placement is a
# pure function, not owned by one engine; per-engine routing detail is on
# serve_solves_total{placement=...}).
_m_decisions = obs.default_registry().counter(
    "serve_placement_decisions_total",
    "placement routing decisions, by level and chosen kind")


def _is_shardable(method: str) -> bool:
    """A method is placement-eligible iff its registry entry says so —
    third-party backends registered ``shardable=True`` route like the
    built-in SolveBakP family without touching this module.  O(1): this
    runs once per request in the grouping hot path."""
    return is_registered(method) and solver_method(method).shardable


@dataclass(frozen=True)
class Placement:
    """Where a bucket's solves run.  Frozen/hashable: part of group keys."""

    kind: str = "single"  # single | obs_sharded | rhs_sharded | mesh_2d

    @property
    def sharded(self) -> bool:
        return self.kind != "single"

    def lane_key(self, method: str) -> str:
        """Stable execution-lane identity for this placement + method.

        Sharded placements each get their own lane (one compiled mesh
        layout per lane, so programs never migrate); single-device solves
        split by the method's registry ``lane`` capability ("xla" vs the
        Pallas "fused" path — distinct compiled-program families that
        would otherwise serialise behind each other).  The device-set half
        of the identity lives on ``repro.serve.lanes.LaneKey``; this
        string is the kind half, shared by the grouping/config key and the
        per-lane metrics labels.
        """
        if self.sharded:
            return f"mesh:{self.kind}"
        lane = (solver_method(method).lane if is_registered(method)
                else "xla")
        return f"single:{lane}"


SINGLE = Placement("single")
OBS_SHARDED = Placement("obs_sharded")
RHS_SHARDED = Placement("rhs_sharded")
MESH_2D = Placement("mesh_2d")


@dataclass(frozen=True)
class PlacementPolicy:
    """Size thresholds mapping buckets/groups onto placements.

    Attributes:
      obs_shard_min_cells: padded ``obs_p * vars_p`` at or above which a
        bucket's solves route to the obs-sharded backend.  The default
        (2²¹ ≈ 2M cells ≈ 8 MB fp32) is sized for real accelerators; tests
        and CPU-mesh benchmarks pass something tiny to force the path.
      rhs_shard_min_k: padded RHS count at or above which a same-design
        multi-RHS group in a ``single`` bucket upgrades to the k-sharded
        backend (requires ``k_pad`` divisible by the data axes product).
      mesh_2d_min_cells: cell count at or above which a bucket routes to
        the 2-D mesh backend instead of obs-sharded (needs a model axis).
        None (default) disables 2-D placement — see module docstring.
    """

    obs_shard_min_cells: int = 1 << 21
    rhs_shard_min_k: int = 32
    mesh_2d_min_cells: Optional[int] = None


@dataclass(frozen=True)
class ServeMesh:
    """The engine's device mesh + the axis names the backends shard over."""

    mesh: object                       # jax.sharding.Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = None

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis]) if self.model_axis else 1

    def describe(self) -> str:
        axes = ", ".join(f"{a}={self.mesh.shape[a]}"
                         for a in self.mesh.axis_names)
        return f"ServeMesh({axes})"


def build_serve_mesh(spec: str) -> ServeMesh:
    """Build a ``ServeMesh`` from a ``"D"`` or ``"DxM"`` spec string.

    ``"8"`` → a 1-D (data=8) mesh; ``"4x2"`` → (data=4, model=2).  The
    total must not exceed the visible device count (on CPU, force virtual
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    *before* importing jax — ``repro.launch.solver_serve --mesh`` does this
    for you).
    """
    # Shares the jax-version compat shim with the production mesh builders
    # (imported lazily: building a mesh is the first jax touch here).
    from repro.launch.mesh import _make_mesh

    parts = [int(p) for p in spec.lower().split("x")]
    if not parts or any(p < 1 for p in parts) or len(parts) > 2:
        raise ValueError(f"mesh spec must be 'D' or 'DxM', got {spec!r}")
    if len(parts) == 1 or parts[1] == 1:
        mesh = _make_mesh((parts[0],), ("data",))
        return ServeMesh(mesh=mesh, data_axes=("data",), model_axis=None)
    mesh = _make_mesh(tuple(parts), ("data", "model"))
    return ServeMesh(mesh=mesh, data_axes=("data",), model_axis="model")


def mesh_device_count(spec: str) -> int:
    """Devices a ``"D"``/``"DxM"`` spec needs (no jax import)."""
    return int(np.prod([int(p) for p in spec.lower().split("x")]))


def placement_for_bucket(bucket: Tuple[int, int], method: str,
                         policy: PlacementPolicy,
                         smesh: Optional[ServeMesh]) -> Placement:
    """Bucket-level placement (known before design coalescing)."""
    chosen = SINGLE
    if smesh is not None and _is_shardable(method):
        obs_p, vars_p = bucket
        cells = obs_p * vars_p
        if (policy.mesh_2d_min_cells is not None
                and cells >= policy.mesh_2d_min_cells
                and smesh.model_size > 1
                and obs_p % smesh.data_size == 0
                and vars_p % smesh.model_size == 0):
            chosen = MESH_2D
        elif (cells >= policy.obs_shard_min_cells
                and obs_p % smesh.data_size == 0):
            chosen = OBS_SHARDED
    _m_decisions.inc(1, level="bucket", kind=chosen.kind)
    return chosen


def placement_for_group(base: Placement, k_pad: int,
                        policy: PlacementPolicy,
                        smesh: Optional[ServeMesh]) -> Placement:
    """Group-level upgrade: a big-k same-design group in a single-device
    bucket shards its RHS axis instead (obs-/2-D-sharded buckets already
    span the mesh, so they keep their bucket placement)."""
    if (smesh is not None and base.kind == "single"
            and k_pad >= policy.rhs_shard_min_k
            and k_pad % smesh.data_size == 0):
        _m_decisions.inc(1, level="group", kind=RHS_SHARDED.kind)
        return RHS_SHARDED
    return base
