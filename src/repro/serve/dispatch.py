"""Async deadline-aware dispatcher for the solver-serving engine.

``SolverServeEngine`` is a synchronous submit/flush window: callers decide
when to flush, and while a flush runs on the device nothing else happens —
request validation, design hashing and padding all serialize behind it.
``AsyncDispatcher`` layers an async pipeline on top:

  * the **dispatch thread** drains a bounded intake queue, normalises each
    request (``prepare_request``: numpy views, shape/knob validation, design
    fingerprint), pre-warms the engine's design cache (bucket padding +
    host→device transfer + column norms + lane-resident copies), and groups
    requests into per-(bucket, solver-config, placement) pending batches;
  * fired batches are submitted to the engine's **execution lanes**
    (``repro.serve.lanes``): one executor thread per (device set, kernel
    path), so a slow mesh-sharded solve no longer blocks cheap
    single-device traffic — each lane drains its own most-urgent-first
    queue concurrently.

Host-side bucketing of *incoming* requests still overlaps the solves *in
flight* — the dispatch thread is hashing and padding batch N+1 while the
lanes run batch N — and additionally batches bound for different lanes
overlap each other.

**Flush policy** — a pending batch fires when the first of these holds:

  * it reaches ``max_batch`` requests (full);
  * its most urgent member's deadline is ``deadline_margin_s`` away
    (deadline pressure; batches fire most-urgent-first);
  * no request has joined it for ``idle_timeout_s`` (idle — bounds the
    latency of deadline-less traffic).

The dispatch thread sleeps on a condition variable whose timeout is
computed from the most urgent pending deadline/idle expiry (no fixed-rate
polling): it wakes exactly when the next batch could fire, or immediately
on submit()/drain()/stop().

**Backpressure** — at most ``max_queue`` requests may be incomplete
(queued + pending + solving) at once.  ``backpressure="reject"`` makes
``submit`` raise ``QueueFullError`` immediately; ``"block"`` makes it wait
for capacity, propagating the slowdown to the caller.
``max_lane_inflight`` additionally bounds each execution lane separately
(same reject/block policy), so a backed-up mesh lane exerts backpressure on
its own traffic while cheap single-device requests keep flowing.

**Deadlines** — a request may carry ``deadline_s`` (relative to submit).
The dispatcher flushes so the solve *starts* with at least the margin left
and records on each ticket whether completion beat the deadline;
``DispatchStats.deadline_misses`` aggregates the misses.

Example::

    with AsyncDispatcher(engine=SolverServeEngine()) as disp:
        tickets = [disp.submit(SolveRequest(x=x, y=y, deadline_s=0.2))
                   for x, y in workload]
        coefs = [t.result().coef for t in tickets]
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.serve.batching import (bucket_shape, config_key, pad_x,
                                  prepare_request, request_bucket)
from repro.serve.engine import ServeConfig, SolverServeEngine
from repro.serve.lanes import LaneKey, LaneWork
from repro.serve.types import ServedSolve, SolveRequest


class QueueFullError(RuntimeError):
    """Raised by ``submit`` under the "reject" backpressure policy."""


class DispatcherStopped(RuntimeError):
    """Raised when submitting to (or awaiting a ticket of) a stopped
    dispatcher that will never serve it."""


class TicketCancelled(RuntimeError):
    """Raised by ``SolveTicket.result()`` after a successful ``cancel()``
    — the request was dropped before its batch fired and will never be
    solved."""


@dataclass
class DispatchConfig:
    """Dispatcher knobs (engine knobs live on ``ServeConfig``)."""

    max_queue: int = 256           # max incomplete requests (backpressure)
    backpressure: str = "reject"   # "reject" | "block"
    max_batch: int = 32            # fire a batch at this occupancy
    deadline_margin_s: float = 0.05  # fire when an oldest deadline is this close
    idle_timeout_s: float = 0.02   # fire a batch this long after its last join
    poll_interval_s: float = 0.002  # DEPRECATED, ignored: the dispatch
    # thread now sleeps until the most urgent pending deadline/idle expiry
    # (condition-variable wakeup), so there is no poll rate to tune.  Kept
    # so existing DispatchConfig(**kwargs) call sites keep constructing.
    max_lane_inflight: Optional[int] = None  # per-execution-lane cap on
    # incomplete requests (None = only the global max_queue applies).
    # Applied under the same reject/block policy; requests whose lane can't
    # be determined cheaply at submit (non-array x) only count globally.
    default_deadline_s: Optional[float] = None  # applied when request has none
    prewarm_cache: bool = True     # build design entries on the dispatch thread


@dataclass
class DispatchStats:
    """Per-dispatcher counters (convenience mirror of the
    ``serve_dispatch_*`` families this dispatcher records into its engine's
    ``repro.obs`` registry — see ``ServeStats`` for the pattern; the
    registry is what the exporters read)."""

    submitted: int = 0
    rejected: int = 0
    completed: int = 0
    cancelled: int = 0
    deadline_misses: int = 0
    fired_full: int = 0
    fired_deadline: int = 0
    fired_idle: int = 0
    fired_drain: int = 0
    max_inflight: int = 0
    # Batches fired per execution lane, by lane label (dispatch-thread
    # owned; the engine's LanePool.stats() carries the execution side).
    lane_batches: Dict[str, int] = field(default_factory=dict)

    @property
    def deadline_hit_rate(self) -> float:
        """Fraction of completed requests that met their deadline
        (requests submitted without a deadline count as hits)."""
        total = self.completed
        if not total:
            return 1.0
        return 1.0 - self.deadline_misses / total

    def as_dict(self) -> dict:
        return {"submitted": self.submitted, "rejected": self.rejected,
                "completed": self.completed,
                "cancelled": self.cancelled,
                "deadline_misses": self.deadline_misses,
                "deadline_hit_rate": self.deadline_hit_rate,
                "fired_full": self.fired_full,
                "fired_deadline": self.fired_deadline,
                "fired_idle": self.fired_idle,
                "fired_drain": self.fired_drain,
                "max_inflight": self.max_inflight,
                "lane_batches": dict(self.lane_batches)}


class SolveTicket:
    """Future-like handle for one dispatched request.

    ``result()`` blocks until the solve lands (or raises on timeout /
    dispatcher failure).  Timing fields are filled in as the request moves
    through the pipeline: ``submitted_at`` → ``fired_at`` → ``completed_at``
    (``repro.obs.now()`` values — the single serving clock, so queue wait
    and engine solve time compose); ``deadline`` is absolute or None.
    """

    def __init__(self, request: SolveRequest, deadline: Optional[float],
                 dispatcher: Optional["AsyncDispatcher"] = None):
        self.request = request
        self.deadline = deadline
        self.submitted_at = obs.now()
        self.fired_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self.deadline_met: Optional[bool] = None
        self._event = threading.Event()
        self._result: Optional[ServedSolve] = None
        self._exception: Optional[BaseException] = None
        self._dispatcher = dispatcher
        self._cancelled = False
        self._bp_lane: Optional[str] = None  # lane label counted for
        # per-lane backpressure at submit (None = not lane-counted)

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServedSolve:
        """Wait for the solve.  A ``TimeoutError`` leaves the ticket live —
        the solve still completes and still counts against the caller's
        backpressure budget; a caller that is *done* with a timed-out
        ticket should ``cancel()`` it so the dispatcher can drop it."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id!r} not completed "
                f"within {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._result

    def cancel(self) -> bool:
        """Drop the request if its batch has not fired yet.

        Returns True when the cancellation won: the ticket completes
        immediately (``result()`` raises ``TicketCancelled``, no deadline
        miss recorded) and the dispatcher releases its backpressure slot —
        the fix for the ``result(timeout=...)`` leak, where every timed-out
        ticket stayed in flight forever and eventually wedged ``drain()``
        and the queue budget.  Returns False when the ticket already fired
        (the solve proceeds and will land on the ticket normally), already
        completed, or was already cancelled.
        """
        disp = self._dispatcher
        if disp is None:
            return False
        with disp._cv:
            # fired_at is the cut-off, stamped under this same lock by
            # _fire_ready: after it, the lane owns the ticket.
            if (self._event.is_set() or self._cancelled
                    or self.fired_at is not None):
                return False
            self._cancelled = True
        self.completed_at = obs.now()
        self._exception = TicketCancelled(
            f"request {self.request.request_id!r} cancelled")
        # deadline_met stays None: a cancelled ticket is not a miss.
        self._event.set()
        disp._on_cancel(self)
        return True

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Submit → fire wait (None until the batch fires)."""
        if self.fired_at is None:
            return None
        return self.fired_at - self.submitted_at

    @property
    def telemetry(self):
        """The completed result's ``repro.obs.SolveTelemetry`` (None until
        completion, on failure, or when obs is disabled)."""
        return self._result.telemetry if self._result is not None else None

    # ------------------------------------------------- dispatcher-side
    def _complete(self, result: ServedSolve) -> None:
        self.completed_at = obs.now()
        self._result = result
        if self.deadline is not None:
            self.deadline_met = self.completed_at <= self.deadline
        tel = result.telemetry
        if tel is not None:
            # Back-fill the async-path timings the engine can't see: how
            # long the request waited in the dispatcher before its batch
            # fired, and how much deadline headroom was left at completion.
            tel.queue_wait_s = self.queue_wait_s
            if self.deadline is not None:
                tel.deadline_margin_s = self.deadline - self.completed_at
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self.completed_at = obs.now()
        self._exception = exc
        if self.deadline is not None:
            self.deadline_met = False
        self._event.set()


@dataclass
class _PendingBatch:
    """One per-(bucket, solver-config, placement) accumulation of tickets.

    ``lane`` is the execution lane the batch will fire onto — fixed at
    creation, since every member shares the config key the lane derives
    from, so a compiled program never migrates across lanes.
    """

    lane: LaneKey
    tickets: List[SolveTicket] = field(default_factory=list)
    last_join: float = 0.0

    @property
    def min_deadline(self) -> float:
        dls = [t.deadline for t in self.tickets if t.deadline is not None]
        return min(dls) if dls else float("inf")


class AsyncDispatcher:
    """Deadline-aware async front-end over ``SolverServeEngine``."""

    def __init__(self, engine: Optional[SolverServeEngine] = None,
                 config: Optional[DispatchConfig] = None):
        self.engine = engine or SolverServeEngine(ServeConfig())
        self.config = config or DispatchConfig()
        if self.config.backpressure not in ("reject", "block"):
            raise ValueError(
                f"backpressure must be 'reject' or 'block', "
                f"got {self.config.backpressure!r}")
        self.stats = DispatchStats()
        reg = self.engine.registry
        self._m_submitted = reg.counter(
            "serve_dispatch_submitted_total", "requests accepted by submit()")
        self._m_rejected = reg.counter(
            "serve_dispatch_rejected_total",
            "requests rejected by backpressure")
        self._m_completed = reg.counter(
            "serve_dispatch_completed_total",
            "tickets completed (served or failed)")
        self._m_cancelled = reg.counter(
            "serve_dispatch_cancelled_total",
            "tickets cancelled before their batch fired")
        self._m_deadline_misses = reg.counter(
            "serve_dispatch_deadline_misses_total",
            "completed tickets that missed their deadline")
        self._m_fired = reg.counter(
            "serve_dispatch_fired_total", "batches fired, by flush reason")
        self._m_inflight = reg.gauge(
            "serve_dispatch_inflight",
            "requests accepted and not yet completed")
        self._m_queue_wait = reg.histogram(
            "serve_queue_wait_seconds",
            "submit-to-fire wait per request", obs.LATENCY_BUCKETS)
        self._m_req_latency = reg.histogram(
            "serve_request_latency_seconds",
            "submit-to-complete latency per request", obs.LATENCY_BUCKETS)
        self._cv = threading.Condition()
        self._intake: deque = deque()
        self._inflight = 0          # accepted and not yet completed
        self._lane_inflight: Dict[str, int] = {}  # per-lane, submit-counted
        self._draining = False
        self._stopping = False
        self._abandon = False       # stop(drain=False): fail, don't serve
        self._started = False
        self._seq = 0
        # Dispatch-thread-only state.
        self._pending: "Dict[Tuple, _PendingBatch]" = {}
        # Fired batches live on the engine's execution lanes; this maps each
        # outstanding LaneWork -> (claim fn, tickets) so stop(drain=False)
        # can claim and fail queued-but-unstarted batches with no orphaned
        # tickets.
        self._works: Dict[LaneWork, Tuple] = {}
        self._works_lock = threading.Lock()
        self._dispatch_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AsyncDispatcher":
        if self._started:
            return self
        self._started = True
        self._stopping = False
        self._abandon = False
        self._dispatch_thread = threading.Thread(
            target=self._dispatch_loop, name="serve-dispatch", daemon=True)
        self._dispatch_thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the dispatcher; with ``drain`` (default) serve what's queued
        first, otherwise fail unserved tickets with ``DispatcherStopped``.

        Either way every ticket is complete (served or failed) when this
        returns — fired batches still queued on a lane are claimed and
        failed, in-flight ones are waited for.  The engine's lane threads
        themselves are engine-owned and stay up (``engine.shutdown()``
        stops them).
        """
        if not self._started:
            return
        if drain:
            self.drain()
        with self._cv:
            self._abandon = not drain
            self._stopping = True
            self._cv.notify_all()
        self._dispatch_thread.join()
        if not drain:
            self._finalize_abandoned()
        self._started = False

    def __enter__(self) -> "AsyncDispatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    # --------------------------------------------------------------- intake
    def submit(self, request: SolveRequest,
               deadline_s: Optional[float] = None) -> SolveTicket:
        """Queue a request; returns a ``SolveTicket`` immediately.

        ``deadline_s`` (relative, seconds) overrides ``request.deadline_s``;
        with neither set, ``config.default_deadline_s`` applies.  Under the
        "reject" policy a full pipeline raises ``QueueFullError``; under
        "block" this call waits for capacity.
        """
        if not self._started:
            raise DispatcherStopped("dispatcher is not running; call start()")
        rel = deadline_s
        if rel is None:
            rel = request.deadline_s
        if rel is None:
            rel = self.config.default_deadline_s
        if rel is not None and rel <= 0:
            raise ValueError(f"deadline_s must be positive, got {rel}")
        ticket = SolveTicket(
            request, None if rel is None else obs.now() + float(rel),
            dispatcher=self)
        # Stamp the absolute deadline onto the request so the engine's
        # retry ladder (repro.resilience) is bounded by it.
        request.deadline_at = ticket.deadline
        cfg = self.config
        lane_lbl = (self._lane_label_of(request)
                    if cfg.max_lane_inflight is not None else None)
        with self._cv:
            if self._stopping:
                raise DispatcherStopped("dispatcher stopped")
            if request.request_id is None:
                request.request_id = f"areq-{self._seq}"
            self._seq += 1

            def _over() -> Optional[str]:
                if self._inflight >= cfg.max_queue:
                    return (f"dispatcher at capacity ({cfg.max_queue} "
                            f"in flight)")
                if (lane_lbl is not None
                        and self._lane_inflight.get(lane_lbl, 0)
                        >= cfg.max_lane_inflight):
                    return (f"lane {lane_lbl} at capacity "
                            f"({cfg.max_lane_inflight} in flight)")
                return None

            over = _over()
            if over is not None:
                if cfg.backpressure == "reject":
                    self.stats.rejected += 1
                    self._m_rejected.inc()
                    raise QueueFullError(over)
                while _over() is not None:
                    if self._stopping:
                        raise DispatcherStopped("dispatcher stopped")
                    self._cv.wait(0.01)
            self._inflight += 1
            if lane_lbl is not None:
                ticket._bp_lane = lane_lbl
                self._lane_inflight[lane_lbl] = (
                    self._lane_inflight.get(lane_lbl, 0) + 1)
            self.stats.submitted += 1
            self._m_submitted.inc()
            self._m_inflight.set(self._inflight)
            self.stats.max_inflight = max(self.stats.max_inflight,
                                          self._inflight)
            self._intake.append(ticket)
            self._cv.notify_all()
        return ticket

    def _lane_label_of(self, req: SolveRequest) -> Optional[str]:
        """Cheap submit-time lane estimate for per-lane backpressure.

        Uses only the request's array shape + spec + the engine's routing
        tables (no padding, hashing or device work).  Returns None when the
        lane can't be determined without normalising (e.g. ``x`` is a
        list) — those requests only count against the global queue; the
        authoritative lane is still assigned at admit time.
        """
        try:
            shape = getattr(req.x, "shape", None)
            if shape is None or len(shape) != 2:
                return None
            eng = self.engine
            bucket = bucket_shape(int(shape[0]), int(shape[1]),
                                  min_obs=eng.config.min_obs,
                                  min_vars=eng.config.min_vars)
            spec = eng.spec_for(req)
            placement = eng.placement_for(bucket, spec.method)
            return eng.lanes.lane_for(spec.method, placement,
                                      eng.mesh).label
        except Exception:
            return None

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Fire everything pending and wait for the pipeline to empty.

        Returns False if ``timeout`` elapsed first.
        """
        deadline = None if timeout is None else obs.now() + timeout
        with self._cv:
            self._draining = True
            self._cv.notify_all()
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - obs.now())
                if remaining is not None and remaining <= 0:
                    self._draining = False
                    return False
                self._cv.wait(0.005 if remaining is None
                              else min(0.005, remaining))
            self._draining = False
        return True

    @property
    def inflight(self) -> int:
        with self._cv:
            return self._inflight

    # ------------------------------------------------------ dispatch thread
    def _next_wake_delay(self) -> Optional[float]:
        """Seconds until the most urgent pending batch could fire (its
        deadline-margin or idle expiry, whichever is sooner), or None when
        nothing is pending — sleep until a notify.  Dispatch-thread only."""
        if not self._pending:
            return None
        cfg = self.config
        t = float("inf")
        for batch in self._pending.values():
            t = min(t,
                    batch.last_join + cfg.idle_timeout_s,
                    batch.min_deadline - cfg.deadline_margin_s)
        return max(0.0, t - obs.now())

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                if not self._intake and not self._stopping:
                    # Sleep exactly until the most urgent pending batch's
                    # deadline-margin/idle expiry; fully idle we sleep
                    # until submit()/drain()/stop() notifies (no polling).
                    self._cv.wait(self._next_wake_delay())
                arrivals = []
                while self._intake:
                    arrivals.append(self._intake.popleft())
                stopping = self._stopping
                draining = self._draining
                abandon = self._abandon
            if stopping and abandon:
                residual = [t for t in arrivals if not t._cancelled]
                residual += [t for b in self._pending.values()
                             for t in b.tickets if not t._cancelled]
                self._pending.clear()
                for t in residual:
                    t._fail(DispatcherStopped("dispatcher stopped"))
                if residual:
                    self._on_complete(residual)
                return  # stop() finalizes fired-but-unserved lane works
            for ticket in arrivals:
                self._admit(ticket)
            now = obs.now()
            for lane, urgency, chunk in self._fire_ready(
                    now, drain_all=draining or stopping):
                self._submit_batch(lane, urgency, chunk)
            if stopping and not self._pending:
                self._drain_works()
                return

    def _admit(self, ticket: SolveTicket) -> None:
        """Normalise + fingerprint one request and join it to its batch.

        This is the host-side work that overlaps in-flight device solves:
        array normalisation, design hashing and (optionally) design-cache
        pre-warm (padding + device transfer + column norms) all happen here
        on the dispatch thread.
        """
        if ticket._cancelled:
            return  # cancel() already settled and accounted the ticket
        req = ticket.request
        try:
            prepare_request(req, fingerprint=True)
        except Exception as exc:
            ticket._fail(exc)
            self._on_complete([ticket])
            return
        ecfg = self.engine.config
        bucket = request_bucket(req, min_obs=ecfg.min_obs,
                                min_vars=ecfg.min_vars)
        spec = self.engine.spec_for(req)
        # Placement- and spec-aware key: batches the dispatcher accumulates
        # line up with the engine's flush grouping, so a sharded bucket's
        # requests never share a pending batch with single-device ones.
        placement = self.engine.placement_for(bucket, spec.method)
        if self.config.prewarm_cache:
            try:
                # record_stats=False: the flush-time lookup is the one cache
                # event per request, so hit rates stay comparable with the
                # synchronous path ("hit" = design state resident at flush).
                # Passing the effective spec also warms the method's derived
                # design state (thr-padded column norms, block-Gram Cholesky
                # factors) here on the dispatch thread, and the placement
                # additionally binds the entry's home lane and builds the
                # lane-resident sharded copy — all overlapping whatever
                # solves are in flight on the lanes.  On a store-backed
                # engine this is also the async tier *promotion*: a design
                # demoted to host/disk climbs back to device here, while
                # its request still waits in the intake queue.
                self.engine.cache.get_or_build(
                    req.design_key,
                    lambda: pad_x(np.asarray(req.x), bucket),
                    spec=spec,
                    record_stats=False,
                    placement=placement,
                    mesh=self.engine.mesh)
            except Exception:
                pass  # engine flush will surface the failure per-request
        batch = self._pending.setdefault(
            config_key(req, bucket, placement, spec),
            _PendingBatch(lane=self.engine.lanes.lane_for(
                spec.method, placement, self.engine.mesh)))
        batch.tickets.append(ticket)
        batch.last_join = obs.now()

    def _fire_ready(self, now: float, drain_all: bool = False
                    ) -> List[Tuple[LaneKey, float, List[SolveTicket]]]:
        """Pop every batch whose flush condition holds, most urgent first.

        Returns (lane, urgency, tickets) triples: the batch's execution
        lane and its most urgent member's absolute deadline (``inf`` for
        deadline-less batches), which orders each lane's queue.
        """
        cfg = self.config
        ready: List[Tuple[float, Tuple, str]] = []
        for key, batch in self._pending.items():
            if not batch.tickets:
                continue
            min_dl = batch.min_deadline
            if drain_all:
                ready.append((min_dl, key, "drain"))
            elif len(batch.tickets) >= cfg.max_batch:
                ready.append((min_dl, key, "full"))
            elif min_dl - cfg.deadline_margin_s <= now:
                ready.append((min_dl, key, "deadline"))
            elif now - batch.last_join >= cfg.idle_timeout_s:
                ready.append((min_dl, key, "idle"))
        # Deadline-ordered firing: the batch with the most urgent member
        # submits to its lane first (and carries its deadline as the lane
        # queue's urgency, so lanes also drain most-urgent-first).
        ready.sort(key=lambda r: r[0])
        fired: List[Tuple[LaneKey, float, List[SolveTicket]]] = []
        for min_dl, key, why in ready:
            batch = self._pending.pop(key)
            # max_batch is an upper bound too: a burst admitted in one
            # iteration fires as several max_batch-sized solves, keeping
            # the configured latency/memory bound per engine call.
            for lo in range(0, len(batch.tickets), cfg.max_batch):
                chunk = batch.tickets[lo:lo + cfg.max_batch]
                # fired_at is the cancel() cut-off and is stamped under
                # _cv: a cancel that won the race is dropped here; one
                # that arrives after sees fired_at set and returns False.
                with self._cv:
                    live = [t for t in chunk if not t._cancelled]
                    for t in live:
                        t.fired_at = now
                if not live:
                    continue
                setattr(self.stats, f"fired_{why}",
                        getattr(self.stats, f"fired_{why}") + 1)
                self._m_fired.inc(1, reason=why)
                lbl = batch.lane.label
                self.stats.lane_batches[lbl] = (
                    self.stats.lane_batches.get(lbl, 0) + 1)
                for t in live:
                    self._m_queue_wait.observe(now - t.submitted_at)
                fired.append((batch.lane, min_dl, live))
        return fired

    # ------------------------------------------------------ lane execution
    def _submit_batch(self, lane: LaneKey, urgency: float,
                      tickets: List[SolveTicket]) -> None:
        """Hand one fired batch to its execution lane.

        The work closure carries a claim flag: exactly one of the lane
        thread and ``_finalize_abandoned`` (after ``stop(drain=False)``)
        gets to settle the tickets, so none are served twice and none are
        orphaned.
        """
        claim_lock = threading.Lock()
        claimed = [False]

        def try_claim() -> bool:
            with claim_lock:
                if claimed[0]:
                    return False
                claimed[0] = True
                return True

        def run() -> None:
            if not try_claim():
                return
            if self._abandon:
                for t in tickets:
                    t._fail(DispatcherStopped("dispatcher stopped"))
            else:
                try:
                    with obs.span("dispatch.solve_batch", size=len(tickets),
                                  lane=lane.label):
                        served = self.engine.serve(
                            [t.request for t in tickets])
                    for ticket, result in zip(tickets, served):
                        ticket._complete(result)
                except Exception as exc:  # engine failure: fail the batch
                    for ticket in tickets:
                        ticket._fail(exc)
            self._on_complete(tickets)
            with self._works_lock:
                self._works.pop(work, None)

        def on_fail(exc: BaseException) -> None:
            # Lane-side failure without the callable completing — worker-
            # thread death (LaneWorkerDeath) or an abandoning shutdown.
            # Claim-protected like every other settle path: if the work
            # half-ran, run() already owns the tickets and this is a no-op.
            if not try_claim():
                return
            for t in tickets:
                t._fail(exc)
            self._on_complete(tickets)
            with self._works_lock:
                self._works.pop(work, None)

        work = LaneWork(run, urgency=urgency, size=len(tickets),
                        tag=lane.label, on_fail=on_fail)
        with self._works_lock:
            self._works[work] = (try_claim, tickets)
        try:
            self.engine.lanes.submit(lane, work)
        except Exception as exc:  # lane shut down under us
            if try_claim():
                for t in tickets:
                    t._fail(exc)
                self._on_complete(tickets)
            with self._works_lock:
                self._works.pop(work, None)

    def _drain_works(self) -> None:
        """Wait for every outstanding lane work (dispatch-thread, on a
        draining stop) so ``stop()`` returns with all tickets complete."""
        with self._works_lock:
            works = list(self._works)
        for w in works:
            w.wait()

    def _finalize_abandoned(self) -> None:
        """After ``stop(drain=False)``: claim queued-but-unstarted lane
        works and fail their tickets; wait out the ones already running."""
        with self._works_lock:
            works = list(self._works.items())
        for w, (claim, tickets) in works:
            if claim():
                for t in tickets:
                    t._fail(DispatcherStopped("dispatcher stopped"))
                self._on_complete(tickets)
                with self._works_lock:
                    self._works.pop(w, None)
            else:
                w.wait()

    def _on_cancel(self, ticket: SolveTicket) -> None:
        """Release a cancelled ticket's pipeline slot (called by
        ``SolveTicket.cancel`` after it settled the ticket).  Mirrors
        ``_on_complete`` minus the latency/deadline recording — a cancel
        is neither a served request nor a miss."""
        with self._cv:
            self._inflight -= 1
            if ticket._bp_lane is not None:
                left = self._lane_inflight.get(ticket._bp_lane, 0) - 1
                if left > 0:
                    self._lane_inflight[ticket._bp_lane] = left
                else:
                    self._lane_inflight.pop(ticket._bp_lane, None)
                ticket._bp_lane = None
            self.stats.completed += 1
            self.stats.cancelled += 1
            self._m_inflight.set(self._inflight)
            self._cv.notify_all()
        self._m_completed.inc(1)
        self._m_cancelled.inc(1)

    def _on_complete(self, tickets: List[SolveTicket]) -> None:
        misses = sum(1 for t in tickets if t.deadline_met is False)
        with self._cv:
            self._inflight -= len(tickets)
            for t in tickets:
                if t._bp_lane is not None:
                    left = self._lane_inflight.get(t._bp_lane, 0) - 1
                    if left > 0:
                        self._lane_inflight[t._bp_lane] = left
                    else:
                        self._lane_inflight.pop(t._bp_lane, None)
                    t._bp_lane = None
            self.stats.completed += len(tickets)
            # Failures count as misses too: _fail() marks deadline_met
            # False on any ticket that carried a deadline.
            self.stats.deadline_misses += misses
            self._m_inflight.set(self._inflight)
            self._cv.notify_all()
        self._m_completed.inc(len(tickets))
        if misses:
            self._m_deadline_misses.inc(misses)
        for t in tickets:
            if t.latency_s is not None:
                self._m_req_latency.observe(t.latency_s)
