"""Async deadline-aware dispatcher for the solver-serving engine.

``SolverServeEngine`` is a synchronous submit/flush window: callers decide
when to flush, and while a flush runs on the device nothing else happens —
request validation, design hashing and padding all serialize behind it.
``AsyncDispatcher`` layers a two-thread pipeline on top:

  * the **dispatch thread** drains a bounded intake queue, normalises each
    request (``prepare_request``: numpy views, shape/knob validation, design
    fingerprint), pre-warms the engine's design cache (bucket padding +
    host→device transfer + column norms), and groups requests into
    per-(bucket, solver-config) pending batches;
  * the **solver thread** pops fired batches and runs the engine's batched
    flush (multi-RHS coalescing / vmap / warm starts, unchanged).

Because these run concurrently, host-side bucketing of *incoming* requests
overlaps the device solve *in flight* — the dispatch thread is hashing and
padding batch N+1 while the solver thread blocks on batch N.

**Flush policy** — a pending batch fires when the first of these holds:

  * it reaches ``max_batch`` requests (full);
  * its most urgent member's deadline is ``deadline_margin_s`` away
    (deadline pressure; batches fire most-urgent-first);
  * no request has joined it for ``idle_timeout_s`` (idle — bounds the
    latency of deadline-less traffic).

**Backpressure** — at most ``max_queue`` requests may be incomplete
(queued + pending + solving) at once.  ``backpressure="reject"`` makes
``submit`` raise ``QueueFullError`` immediately; ``"block"`` makes it wait
for capacity, propagating the slowdown to the caller.

**Deadlines** — a request may carry ``deadline_s`` (relative to submit).
The dispatcher flushes so the solve *starts* with at least the margin left
and records on each ticket whether completion beat the deadline;
``DispatchStats.deadline_misses`` aggregates the misses.

Example::

    with AsyncDispatcher(engine=SolverServeEngine()) as disp:
        tickets = [disp.submit(SolveRequest(x=x, y=y, deadline_s=0.2))
                   for x, y in workload]
        coefs = [t.result().coef for t in tickets]
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.serve.batching import config_key, pad_x, prepare_request, request_bucket
from repro.serve.engine import ServeConfig, SolverServeEngine
from repro.serve.types import ServedSolve, SolveRequest


class QueueFullError(RuntimeError):
    """Raised by ``submit`` under the "reject" backpressure policy."""


class DispatcherStopped(RuntimeError):
    """Raised when submitting to (or awaiting a ticket of) a stopped
    dispatcher that will never serve it."""


@dataclass
class DispatchConfig:
    """Dispatcher knobs (engine knobs live on ``ServeConfig``)."""

    max_queue: int = 256           # max incomplete requests (backpressure)
    backpressure: str = "reject"   # "reject" | "block"
    max_batch: int = 32            # fire a batch at this occupancy
    deadline_margin_s: float = 0.05  # fire when an oldest deadline is this close
    idle_timeout_s: float = 0.02   # fire a batch this long after its last join
    poll_interval_s: float = 0.002  # dispatch-thread wakeup bound
    default_deadline_s: Optional[float] = None  # applied when request has none
    prewarm_cache: bool = True     # build design entries on the dispatch thread


@dataclass
class DispatchStats:
    """Per-dispatcher counters (convenience mirror of the
    ``serve_dispatch_*`` families this dispatcher records into its engine's
    ``repro.obs`` registry — see ``ServeStats`` for the pattern; the
    registry is what the exporters read)."""

    submitted: int = 0
    rejected: int = 0
    completed: int = 0
    deadline_misses: int = 0
    fired_full: int = 0
    fired_deadline: int = 0
    fired_idle: int = 0
    fired_drain: int = 0
    max_inflight: int = 0

    @property
    def deadline_hit_rate(self) -> float:
        """Fraction of completed requests that met their deadline
        (requests submitted without a deadline count as hits)."""
        total = self.completed
        if not total:
            return 1.0
        return 1.0 - self.deadline_misses / total

    def as_dict(self) -> dict:
        return {"submitted": self.submitted, "rejected": self.rejected,
                "completed": self.completed,
                "deadline_misses": self.deadline_misses,
                "deadline_hit_rate": self.deadline_hit_rate,
                "fired_full": self.fired_full,
                "fired_deadline": self.fired_deadline,
                "fired_idle": self.fired_idle,
                "fired_drain": self.fired_drain,
                "max_inflight": self.max_inflight}


class SolveTicket:
    """Future-like handle for one dispatched request.

    ``result()`` blocks until the solve lands (or raises on timeout /
    dispatcher failure).  Timing fields are filled in as the request moves
    through the pipeline: ``submitted_at`` → ``fired_at`` → ``completed_at``
    (``repro.obs.now()`` values — the single serving clock, so queue wait
    and engine solve time compose); ``deadline`` is absolute or None.
    """

    def __init__(self, request: SolveRequest, deadline: Optional[float]):
        self.request = request
        self.deadline = deadline
        self.submitted_at = obs.now()
        self.fired_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self.deadline_met: Optional[bool] = None
        self._event = threading.Event()
        self._result: Optional[ServedSolve] = None
        self._exception: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServedSolve:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id!r} not completed "
                f"within {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._result

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Submit → fire wait (None until the batch fires)."""
        if self.fired_at is None:
            return None
        return self.fired_at - self.submitted_at

    @property
    def telemetry(self):
        """The completed result's ``repro.obs.SolveTelemetry`` (None until
        completion, on failure, or when obs is disabled)."""
        return self._result.telemetry if self._result is not None else None

    # ------------------------------------------------- dispatcher-side
    def _complete(self, result: ServedSolve) -> None:
        self.completed_at = obs.now()
        self._result = result
        if self.deadline is not None:
            self.deadline_met = self.completed_at <= self.deadline
        tel = result.telemetry
        if tel is not None:
            # Back-fill the async-path timings the engine can't see: how
            # long the request waited in the dispatcher before its batch
            # fired, and how much deadline headroom was left at completion.
            tel.queue_wait_s = self.queue_wait_s
            if self.deadline is not None:
                tel.deadline_margin_s = self.deadline - self.completed_at
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self.completed_at = obs.now()
        self._exception = exc
        if self.deadline is not None:
            self.deadline_met = False
        self._event.set()


@dataclass
class _PendingBatch:
    """One per-(bucket, solver-config) accumulation of tickets."""

    tickets: List[SolveTicket] = field(default_factory=list)
    last_join: float = 0.0

    @property
    def min_deadline(self) -> float:
        dls = [t.deadline for t in self.tickets if t.deadline is not None]
        return min(dls) if dls else float("inf")


class AsyncDispatcher:
    """Deadline-aware async front-end over ``SolverServeEngine``."""

    def __init__(self, engine: Optional[SolverServeEngine] = None,
                 config: Optional[DispatchConfig] = None):
        self.engine = engine or SolverServeEngine(ServeConfig())
        self.config = config or DispatchConfig()
        if self.config.backpressure not in ("reject", "block"):
            raise ValueError(
                f"backpressure must be 'reject' or 'block', "
                f"got {self.config.backpressure!r}")
        self.stats = DispatchStats()
        reg = self.engine.registry
        self._m_submitted = reg.counter(
            "serve_dispatch_submitted_total", "requests accepted by submit()")
        self._m_rejected = reg.counter(
            "serve_dispatch_rejected_total",
            "requests rejected by backpressure")
        self._m_completed = reg.counter(
            "serve_dispatch_completed_total",
            "tickets completed (served or failed)")
        self._m_deadline_misses = reg.counter(
            "serve_dispatch_deadline_misses_total",
            "completed tickets that missed their deadline")
        self._m_fired = reg.counter(
            "serve_dispatch_fired_total", "batches fired, by flush reason")
        self._m_inflight = reg.gauge(
            "serve_dispatch_inflight",
            "requests accepted and not yet completed")
        self._m_queue_wait = reg.histogram(
            "serve_queue_wait_seconds",
            "submit-to-fire wait per request", obs.LATENCY_BUCKETS)
        self._m_req_latency = reg.histogram(
            "serve_request_latency_seconds",
            "submit-to-complete latency per request", obs.LATENCY_BUCKETS)
        self._cv = threading.Condition()
        self._intake: deque = deque()
        self._inflight = 0          # accepted and not yet completed
        self._draining = False
        self._stopping = False
        self._abandon = False       # stop(drain=False): fail, don't serve
        self._started = False
        self._seq = 0
        # Dispatch-thread-only state.
        self._pending: "Dict[Tuple, _PendingBatch]" = {}
        # Solver handoff: fired batches, most-urgent-first within a scan.
        self._solve_q: deque = deque()
        self._solve_cv = threading.Condition()
        self._dispatch_thread: Optional[threading.Thread] = None
        self._solver_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AsyncDispatcher":
        if self._started:
            return self
        self._started = True
        self._stopping = False
        self._abandon = False
        self._dispatch_thread = threading.Thread(
            target=self._dispatch_loop, name="serve-dispatch", daemon=True)
        self._solver_thread = threading.Thread(
            target=self._solve_loop, name="serve-solver", daemon=True)
        self._dispatch_thread.start()
        self._solver_thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop both threads; with ``drain`` (default) serve what's queued
        first, otherwise fail unserved tickets with ``DispatcherStopped``."""
        if not self._started:
            return
        if drain:
            self.drain()
        with self._cv:
            self._abandon = not drain
            self._stopping = True
            self._cv.notify_all()
        with self._solve_cv:
            self._solve_cv.notify_all()
        self._dispatch_thread.join()
        self._solver_thread.join()
        self._started = False

    def __enter__(self) -> "AsyncDispatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    # --------------------------------------------------------------- intake
    def submit(self, request: SolveRequest,
               deadline_s: Optional[float] = None) -> SolveTicket:
        """Queue a request; returns a ``SolveTicket`` immediately.

        ``deadline_s`` (relative, seconds) overrides ``request.deadline_s``;
        with neither set, ``config.default_deadline_s`` applies.  Under the
        "reject" policy a full pipeline raises ``QueueFullError``; under
        "block" this call waits for capacity.
        """
        if not self._started:
            raise DispatcherStopped("dispatcher is not running; call start()")
        rel = deadline_s
        if rel is None:
            rel = request.deadline_s
        if rel is None:
            rel = self.config.default_deadline_s
        if rel is not None and rel <= 0:
            raise ValueError(f"deadline_s must be positive, got {rel}")
        ticket = SolveTicket(
            request, None if rel is None else obs.now() + float(rel))
        with self._cv:
            if self._stopping:
                raise DispatcherStopped("dispatcher stopped")
            if request.request_id is None:
                request.request_id = f"areq-{self._seq}"
            self._seq += 1
            if self._inflight >= self.config.max_queue:
                if self.config.backpressure == "reject":
                    self.stats.rejected += 1
                    self._m_rejected.inc()
                    raise QueueFullError(
                        f"dispatcher at capacity ({self.config.max_queue} "
                        f"in flight)")
                while self._inflight >= self.config.max_queue:
                    if self._stopping:
                        raise DispatcherStopped("dispatcher stopped")
                    self._cv.wait(0.01)
            self._inflight += 1
            self.stats.submitted += 1
            self._m_submitted.inc()
            self._m_inflight.set(self._inflight)
            self.stats.max_inflight = max(self.stats.max_inflight,
                                          self._inflight)
            self._intake.append(ticket)
            self._cv.notify_all()
        return ticket

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Fire everything pending and wait for the pipeline to empty.

        Returns False if ``timeout`` elapsed first.
        """
        deadline = None if timeout is None else obs.now() + timeout
        with self._cv:
            self._draining = True
            self._cv.notify_all()
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - obs.now())
                if remaining is not None and remaining <= 0:
                    self._draining = False
                    return False
                self._cv.wait(0.005 if remaining is None
                              else min(0.005, remaining))
            self._draining = False
        return True

    @property
    def inflight(self) -> int:
        with self._cv:
            return self._inflight

    # ------------------------------------------------------ dispatch thread
    def _dispatch_loop(self) -> None:
        cfg = self.config
        while True:
            with self._cv:
                if not self._intake and not self._stopping:
                    # With pending batches a timed wake drives the
                    # deadline/idle flush checks; fully idle we sleep until
                    # submit()/drain()/stop() notifies (no busy-poll).
                    self._cv.wait(cfg.poll_interval_s if self._pending
                                  else None)
                arrivals = []
                while self._intake:
                    arrivals.append(self._intake.popleft())
                stopping = self._stopping
                draining = self._draining
                abandon = self._abandon
            if stopping and abandon:
                residual = arrivals + [t for b in self._pending.values()
                                       for t in b.tickets]
                self._pending.clear()
                for t in residual:
                    t._fail(DispatcherStopped("dispatcher stopped"))
                if residual:
                    self._on_complete(residual)
                with self._solve_cv:
                    self._solve_q.append(None)  # solver-thread sentinel
                    self._solve_cv.notify_all()
                return
            for ticket in arrivals:
                self._admit(ticket)
            now = obs.now()
            fired = self._fire_ready(now, drain_all=draining or stopping)
            if fired:
                with self._solve_cv:
                    self._solve_q.extend(fired)
                    self._solve_cv.notify_all()
            if stopping and not self._pending:
                with self._solve_cv:
                    self._solve_q.append(None)  # solver-thread sentinel
                    self._solve_cv.notify_all()
                return

    def _admit(self, ticket: SolveTicket) -> None:
        """Normalise + fingerprint one request and join it to its batch.

        This is the host-side work that overlaps in-flight device solves:
        array normalisation, design hashing and (optionally) design-cache
        pre-warm (padding + device transfer + column norms) all happen here
        on the dispatch thread.
        """
        req = ticket.request
        try:
            prepare_request(req, fingerprint=True)
        except Exception as exc:
            ticket._fail(exc)
            self._on_complete([ticket])
            return
        ecfg = self.engine.config
        bucket = request_bucket(req, min_obs=ecfg.min_obs,
                                min_vars=ecfg.min_vars)
        spec = self.engine.spec_for(req)
        if self.config.prewarm_cache:
            try:
                # record_stats=False: the flush-time lookup is the one cache
                # event per request, so hit rates stay comparable with the
                # synchronous path ("hit" = design state resident at flush).
                # Passing the effective spec also warms the method's derived
                # design state (thr-padded column norms, block-Gram Cholesky
                # factors) here on the dispatch thread, overlapping those
                # builds with whatever solve is in flight on the device.
                self.engine.cache.get_or_build(
                    req.design_key,
                    lambda: pad_x(np.asarray(req.x), bucket),
                    spec=spec,
                    record_stats=False)
            except Exception:
                pass  # engine flush will surface the failure per-request
        # Placement- and spec-aware key: batches the dispatcher accumulates
        # line up with the engine's flush grouping, so a sharded bucket's
        # requests never share a pending batch with single-device ones.
        placement = self.engine.placement_for(bucket, spec.method)
        batch = self._pending.setdefault(
            config_key(req, bucket, placement, spec), _PendingBatch())
        batch.tickets.append(ticket)
        batch.last_join = obs.now()

    def _fire_ready(self, now: float,
                    drain_all: bool = False) -> List[List[SolveTicket]]:
        """Pop every batch whose flush condition holds, most urgent first."""
        cfg = self.config
        ready: List[Tuple[float, Tuple, str]] = []
        for key, batch in self._pending.items():
            if not batch.tickets:
                continue
            min_dl = batch.min_deadline
            if drain_all:
                ready.append((min_dl, key, "drain"))
            elif len(batch.tickets) >= cfg.max_batch:
                ready.append((min_dl, key, "full"))
            elif min_dl - cfg.deadline_margin_s <= now:
                ready.append((min_dl, key, "deadline"))
            elif now - batch.last_join >= cfg.idle_timeout_s:
                ready.append((min_dl, key, "idle"))
        # Deadline-ordered flushing: the batch with the most urgent member
        # reaches the (FIFO) solver queue first.
        ready.sort(key=lambda r: r[0])
        fired = []
        for min_dl, key, why in ready:
            batch = self._pending.pop(key)
            # max_batch is an upper bound too: a burst admitted in one
            # iteration fires as several max_batch-sized solves, keeping
            # the configured latency/memory bound per engine call.
            for lo in range(0, len(batch.tickets), cfg.max_batch):
                chunk = batch.tickets[lo:lo + cfg.max_batch]
                setattr(self.stats, f"fired_{why}",
                        getattr(self.stats, f"fired_{why}") + 1)
                self._m_fired.inc(1, reason=why)
                for t in chunk:
                    t.fired_at = now
                    self._m_queue_wait.observe(now - t.submitted_at)
                fired.append(chunk)
        return fired

    # ------------------------------------------------------- solver thread
    def _solve_loop(self) -> None:
        while True:
            with self._solve_cv:
                while not self._solve_q:
                    self._solve_cv.wait()  # every producer notifies
                batch = self._solve_q.popleft()
            if batch is None:
                self._fail_residual()
                return
            try:
                with obs.span("dispatch.solve_batch", size=len(batch)):
                    served = self.engine.serve([t.request for t in batch])
                for ticket, result in zip(batch, served):
                    ticket._complete(result)
            except Exception as exc:  # engine-level failure: fail the batch
                for ticket in batch:
                    ticket._fail(exc)
            self._on_complete(batch)

    def _fail_residual(self) -> None:
        """After a no-drain stop: fail anything still in the pipeline."""
        residual: List[SolveTicket] = []
        with self._solve_cv:
            while self._solve_q:
                batch = self._solve_q.popleft()
                if batch:
                    residual.extend(batch)
        with self._cv:
            while self._intake:
                residual.append(self._intake.popleft())
        for ticket in residual:
            if not ticket.done():
                ticket._fail(DispatcherStopped("dispatcher stopped"))
        if residual:
            self._on_complete(residual)

    def _on_complete(self, tickets: List[SolveTicket]) -> None:
        misses = sum(1 for t in tickets if t.deadline_met is False)
        with self._cv:
            self._inflight -= len(tickets)
            self.stats.completed += len(tickets)
            # Failures count as misses too: _fail() marks deadline_met
            # False on any ticket that carried a deadline.
            self.stats.deadline_misses += misses
            self._m_inflight.set(self._inflight)
            self._cv.notify_all()
        self._m_completed.inc(len(tickets))
        if misses:
            self._m_deadline_misses.inc(misses)
        for t in tickets:
            if t.latency_s is not None:
                self._m_req_latency.observe(t.latency_s)
