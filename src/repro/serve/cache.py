"""Design cache — memoised per-design solver state for repeated-X traffic.

Serving workloads are dominated by repeated design matrices (the same
feature matrix queried with many targets: probes, ablations, per-user
heads).  Everything about a solve that depends only on ``x`` is therefore
cached across requests, keyed by the design fingerprint:

  * the padded device-resident copy of ``x`` (skips re-pad + host→device
    transfer on every request);
  * the squared column norms (the O(obs·vars) pass of Algorithm 1 line 3);
  * the per-block Gram Cholesky factors for ``mode="gram"`` — the
    O(obs·vars·thr) factorisation that dominates small-iteration solves,
    computed once per (thr, ridge) and reused by every later request.

Entries are LRU-evicted so memory is bounded by ``max_entries`` designs.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.solvebakp import block_gram_cholesky
from repro.core.types import column_norms_sq


@dataclass
class DesignEntry:
    """Cached per-design state.  ``x_pad`` is bucket-padded, fp32, on device."""

    x_pad: jax.Array                      # (obs_p, vars_p)
    cn: jax.Array                         # (vars_p,) squared column norms
    chol: Dict[Tuple[int, float], jax.Array] = field(default_factory=dict)
    _cn_thr: Dict[int, jax.Array] = field(default_factory=dict)

    def cn_for_thr(self, thr: int) -> jax.Array:
        """Column norms extended to solvebakp's thr-multiple padding."""
        vars_p = self.x_pad.shape[1]
        nblocks = -(-vars_p // thr)
        pad = nblocks * thr - vars_p
        if pad == 0:
            return self.cn
        if thr not in self._cn_thr:
            self._cn_thr[thr] = jnp.concatenate(
                [self.cn, jnp.zeros((pad,), jnp.float32)])
        return self._cn_thr[thr]

    def chol_for(self, thr: int, ridge: float) -> jax.Array:
        """Block-Gram Cholesky factors for (thr, ridge), computed once."""
        key = (int(thr), float(ridge))
        if key not in self.chol:
            obs_p, vars_p = self.x_pad.shape
            nblocks = -(-vars_p // thr)
            pad = nblocks * thr - vars_p
            x = self.x_pad
            if pad:
                x = jnp.pad(x, ((0, 0), (0, pad)))
            xb = x.reshape(obs_p, nblocks, thr)
            self.chol[key] = block_gram_cholesky(xb, ridge)
        return self.chol[key]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class DesignCache:
    """LRU cache: design key → ``DesignEntry``."""

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self.stats = CacheStats()
        self._entries: "OrderedDict[str, DesignEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[DesignEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return entry

    def put(self, key: str, entry: DesignEntry) -> DesignEntry:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return entry

    def get_or_build(self, key: str, build_x_pad) -> Tuple[DesignEntry, bool]:
        """Fetch the entry for ``key``, building it on miss.

        ``build_x_pad`` is a zero-arg callable returning the bucket-padded
        design matrix — only invoked on a miss, so hits skip the host-side
        padding entirely.  Returns (entry, cache_hit).
        """
        entry = self.get(key)
        if entry is not None:
            return entry, True
        x_pad = jnp.asarray(build_x_pad(), jnp.float32)
        entry = DesignEntry(x_pad=x_pad, cn=column_norms_sq(x_pad))
        return self.put(key, entry), False
