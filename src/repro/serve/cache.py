"""Design cache — memoised per-design solver state for repeated-X traffic.

Serving workloads are dominated by repeated design matrices (the same
feature matrix queried with many targets: probes, ablations, per-user
heads).  Everything about a solve that depends only on ``x`` is therefore
cached across requests, keyed by the design fingerprint:

  * the padded device-resident copy of ``x`` (skips re-pad + host→device
    transfer on every request);
  * the squared column norms (the O(obs·vars) pass of Algorithm 1 line 3);
  * the per-block Gram Cholesky factors for ``mode="gram"`` — the
    O(obs·vars·thr) factorisation that dominates small-iteration solves,
    computed once per (thr, ridge) and reused by every later request;
  * per-placement sharded device copies — a bucket routed to a mesh-sharded
    backend (see ``repro.serve.placement``) needs ``x`` laid out for that
    backend's in_specs (rows over data axes, replicated, 2-D); caching the
    ``device_put`` per placement means repeat flushes skip the reshard;
  * (optionally) each tenant's last solved coefficients — repeated-design
    tenants re-solve with slowly-drifting ``y``, and warm-starting from the
    previous solution cuts the sweep count without changing the fixed point.

Entries are LRU-evicted so memory is bounded by ``max_entries`` designs;
per-entry warm coefficients are themselves LRU-bounded by ``max_tenants``.

Thread safety: the async dispatcher's pre-warm thread and the solver thread
touch the same entries concurrently, so every piece of mutable per-entry
state (warm-coefficient LRU, derived-factor dicts, per-placement copies) is
guarded by a per-entry lock — the cache-level lock only covers the LRU map
itself.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solvebakp import block_gram_cholesky
from repro.core.types import column_norms_sq


@dataclass
class DesignEntry:
    """Cached per-design state.  ``x_pad`` is bucket-padded, fp32, on device.

    All mutable members (``_warm``, ``chol``, ``_cn_thr``, ``_sharded``) are
    read AND written from two threads (the dispatcher's pre-warm thread and
    the engine's solver thread), so every accessor takes the per-entry
    ``_lock`` — an OrderedDict mid-``move_to_end`` or a dict mid-insert is
    not safe to race.  The lock is per-entry (not the cache-wide one) so a
    slow Cholesky build for one design never blocks lookups on another.
    """

    x_pad: jax.Array                      # (obs_p, vars_p)
    cn: jax.Array                         # (vars_p,) squared column norms
    chol: Dict[Tuple[int, float], jax.Array] = field(default_factory=dict)
    max_tenants: int = 64
    _cn_thr: Dict[int, jax.Array] = field(default_factory=dict)
    _warm: "OrderedDict[str, np.ndarray]" = field(default_factory=OrderedDict)
    _sharded: Dict[object, jax.Array] = field(default_factory=dict)
    _lock: threading.RLock = field(default_factory=threading.RLock,
                                   repr=False, compare=False)

    # --------------------------------------------- per-tenant warm starts
    def warm_coef(self, tenant_id: Optional[str]) -> Optional[np.ndarray]:
        """Last stored coefficients for ``tenant_id`` (None = cold)."""
        if tenant_id is None:
            return None
        with self._lock:
            coef = self._warm.get(tenant_id)
            if coef is not None:
                self._warm.move_to_end(tenant_id)
            return coef

    def store_coef(self, tenant_id: Optional[str], coef: np.ndarray) -> None:
        """Retain a tenant's solved (unpadded) coefficients, LRU-bounded.

        Copies: the same array is handed to the caller as
        ``ServedSolve.coef``, and an in-place mutation there must not
        corrupt the tenant's next warm start.
        """
        if tenant_id is None:
            return
        coef = np.array(coef, np.float32, copy=True)
        with self._lock:
            self._warm[tenant_id] = coef
            self._warm.move_to_end(tenant_id)
            while len(self._warm) > self.max_tenants:
                self._warm.popitem(last=False)

    def cn_for_thr(self, thr: int) -> jax.Array:
        """Column norms extended to solvebakp's thr-multiple padding."""
        vars_p = self.x_pad.shape[1]
        nblocks = -(-vars_p // thr)
        pad = nblocks * thr - vars_p
        if pad == 0:
            return self.cn
        with self._lock:
            if thr not in self._cn_thr:
                self._cn_thr[thr] = jnp.concatenate(
                    [self.cn, jnp.zeros((pad,), jnp.float32)])
            return self._cn_thr[thr]

    def chol_for(self, thr: int, ridge: float) -> jax.Array:
        """Block-Gram Cholesky factors for (thr, ridge), computed once."""
        key = (int(thr), float(ridge))
        with self._lock:
            if key not in self.chol:
                obs_p, vars_p = self.x_pad.shape
                nblocks = -(-vars_p // thr)
                pad = nblocks * thr - vars_p
                x = self.x_pad
                if pad:
                    x = jnp.pad(x, ((0, 0), (0, pad)))
                xb = x.reshape(obs_p, nblocks, thr)
                self.chol[key] = block_gram_cholesky(xb, ridge)
            return self.chol[key]

    def x_for_placement(self, placement, smesh) -> jax.Array:
        """``x_pad`` laid out for a sharded placement's in_specs.

        The ``device_put`` (an all-device scatter or broadcast) happens once
        per (design, placement) and is memoised, so repeat flushes onto the
        same mesh reuse the resident copy instead of resharding.
        """
        if placement is None or not placement.sharded:
            return self.x_pad
        from jax.sharding import NamedSharding, PartitionSpec as P
        with self._lock:
            if placement not in self._sharded:
                if placement.kind == "obs_sharded":
                    spec = P(smesh.data_axes, None)
                elif placement.kind == "rhs_sharded":
                    spec = P(None, None)  # replicated: devices share x
                elif placement.kind == "mesh_2d":
                    spec = P(smesh.data_axes, smesh.model_axis)
                else:
                    raise ValueError(
                        f"unknown placement kind {placement.kind!r}")
                self._sharded[placement] = jax.device_put(
                    self.x_pad, NamedSharding(smesh.mesh, spec))
            return self._sharded[placement]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class DesignCache:
    """LRU cache: design key → ``DesignEntry``.

    Thread-safe: the async dispatcher pre-warms entries from its dispatch
    thread (overlapping padding + host→device transfer with in-flight
    solves) while the solver thread reads them, so the LRU bookkeeping is
    guarded by a lock.  Entry *construction* runs outside the lock; on a
    build race the first ``put`` wins and the loser's entry is dropped.
    """

    def __init__(self, max_entries: int = 64, max_tenants: int = 64):
        self.max_entries = max_entries
        self.max_tenants = max_tenants
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, DesignEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str,
            record_stats: bool = True) -> Optional[DesignEntry]:
        """Fetch (and LRU-touch) an entry.  ``record_stats=False`` makes the
        lookup invisible to hit/miss accounting — used by the dispatcher's
        pre-warm so each request still logs exactly one cache event, at
        flush time."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                if record_stats:
                    self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            if record_stats:
                self.stats.hits += 1
            return entry

    def put(self, key: str, entry: DesignEntry) -> DesignEntry:
        with self._lock:
            existing = self._entries.get(key)
            if existing is not None:  # build race: first writer wins
                self._entries.move_to_end(key)
                return existing
            self._entries[key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            return entry

    def get_or_build(self, key: str, build_x_pad,
                     record_stats: bool = True) -> Tuple[DesignEntry, bool]:
        """Fetch the entry for ``key``, building it on miss.

        ``build_x_pad`` is a zero-arg callable returning the bucket-padded
        design matrix — only invoked on a miss, so hits skip the host-side
        padding entirely.  Returns (entry, cache_hit).
        """
        entry = self.get(key, record_stats)
        if entry is not None:
            return entry, True
        x_pad = jnp.asarray(build_x_pad(), jnp.float32)
        entry = DesignEntry(x_pad=x_pad, cn=column_norms_sq(x_pad),
                            max_tenants=self.max_tenants)
        return self.put(key, entry), False
