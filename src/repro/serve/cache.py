"""Design cache — LRU of ``PreparedDesign`` handles for repeated-X traffic.

Serving workloads are dominated by repeated design matrices (the same
feature matrix queried with many targets: probes, ablations, per-user
heads).  Everything about a solve that depends only on ``x`` therefore lives
on one ``repro.core.prepare.PreparedDesign`` per design, cached across
requests and keyed by the design fingerprint:

  * the padded device-resident copy of ``x`` (skips re-pad + host→device
    transfer on every request);
  * the squared column norms (the O(obs·vars) pass of Algorithm 1 line 3)
    and their per-``thr`` padded layouts;
  * the per-block Gram Cholesky factors for ``mode="gram"``, computed once
    per (thr, ridge) and reused by every later request;
  * per-placement sharded device copies — a bucket routed to a mesh-sharded
    backend (see ``repro.serve.placement``) reuses its resident reshard;
  * each tenant's last solved coefficients (warm starts), LRU-bounded.

This module used to carry its own ``DesignEntry`` dataclass with exactly
that state; PR 4 promoted it to the public ``PreparedDesign`` handle, and
the cache now stores handles directly — ``DesignEntry`` remains as an alias
so existing callers and tests keep working.  The per-entry lock semantics
(every mutable accessor guarded, per-design so one slow Cholesky build never
blocks another design's lookups) moved with the state and are unchanged.

Entries are LRU-evicted so memory is bounded by ``max_entries`` designs;
per-entry warm coefficients are themselves LRU-bounded by ``max_tenants``.
The cache-level lock only covers the LRU map itself.

PR 9: with a ``repro.store.DesignStore`` attached (``store=``), the cache
becomes a *view over the store's device tier* — eviction turns into
demotion (device → host → disk, warm-start state preserved), lookups that
miss the device tier try a promotion before rebuilding from source, and
designs too large for the device byte budget come back as non-resident
streaming handles served by the ``"bakp_stream"`` method.  Without a store
the behaviour is bit-identical to before.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro import obs
from repro.core.prepare import PreparedDesign, prepare
from repro.core.spec import SolverSpec

# Backwards-compatible name: per-design cached state IS the public handle.
DesignEntry = PreparedDesign


@dataclass
class CacheStats:
    """Per-cache counters (convenience mirror of the ``serve_cache_*``
    families this cache records into its ``repro.obs`` registry — see
    ``ServeStats`` for the pattern)."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}


class DesignCache:
    """LRU cache: design key → ``PreparedDesign``.

    Thread-safe: the async dispatcher pre-warms entries from its dispatch
    thread (overlapping padding + host→device transfer + method-state
    warming with in-flight solves) while the solver thread reads them, so
    the LRU bookkeeping is guarded by a lock.  Entry *construction* runs
    outside the lock; on a build race the first ``put`` wins and the
    loser's entry is dropped.
    """

    def __init__(self, max_entries: int = 64, max_tenants: int = 64,
                 registry: Optional[obs.MetricsRegistry] = None,
                 store=None):
        self.max_entries = max_entries
        self.max_tenants = max_tenants
        self.store = store  # Optional[repro.store.DesignStore]
        self.stats = CacheStats()
        reg = registry or obs.default_registry()
        self._m_hits = reg.counter(
            "serve_cache_hits_total", "design-cache lookups served resident")
        self._m_misses = reg.counter(
            "serve_cache_misses_total", "design-cache lookups that built")
        self._m_evictions = reg.counter(
            "serve_cache_evictions_total", "designs LRU-evicted")
        self._m_resident = reg.gauge(
            "serve_cache_entries", "designs currently resident")
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, PreparedDesign]" = OrderedDict()

    def __len__(self) -> int:
        if self.store is not None:
            return len(self.store)  # device-tier resident count
        return len(self._entries)

    def _record_lookup(self, hit: bool, record_stats: bool) -> None:
        if not record_stats:
            return
        with self._lock:
            if hit:
                self.stats.hits += 1
                self._m_hits.inc()
            else:
                self.stats.misses += 1
                self._m_misses.inc()

    def _sync_evictions(self, demotions_before: int) -> None:
        """Mirror store demotions into the historical eviction counters —
        dashboards keyed on ``serve_cache_evictions_total`` keep reading
        the device tier's turnover."""
        delta = self.store.stats.demotions_device - demotions_before
        if delta > 0:
            with self._lock:
                self.stats.evictions += delta
                self._m_evictions.inc(delta)
        self._m_resident.set(len(self.store))

    def get(self, key: str,
            record_stats: bool = True) -> Optional[PreparedDesign]:
        """Fetch (and LRU-touch) an entry.  ``record_stats=False`` makes the
        lookup invisible to hit/miss accounting — used by the dispatcher's
        pre-warm so each request still logs exactly one cache event, at
        flush time.  Store-backed: returns the device-resident entry or the
        non-resident streaming handle; never promotes (that is
        ``get_or_build``'s job, so plain lookups stay O(1))."""
        if self.store is not None:
            entry = self.store.get(key)
            self._record_lookup(entry is not None, record_stats)
            return entry
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                if record_stats:
                    self.stats.misses += 1
                    self._m_misses.inc()
                return None
            self._entries.move_to_end(key)
            if record_stats:
                self.stats.hits += 1
                self._m_hits.inc()
            return entry

    def put(self, key: str, entry: PreparedDesign) -> PreparedDesign:
        if self.store is not None:
            before = self.store.stats.demotions_device
            out = self.store.admit(key, entry)
            self._sync_evictions(before)
            return out
        with self._lock:
            existing = self._entries.get(key)
            if existing is not None:  # build race: first writer wins
                self._entries.move_to_end(key)
                return existing
            self._entries[key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
                self._m_evictions.inc()
            self._m_resident.set(len(self._entries))
            return entry

    def get_or_build(self, key: str, build_x_pad,
                     spec: Optional[SolverSpec] = None,
                     record_stats: bool = True,
                     placement=None, mesh=None
                     ) -> Tuple[PreparedDesign, bool]:
        """Fetch the ``PreparedDesign`` for ``key``, preparing it on miss.

        ``build_x_pad`` is a zero-arg callable returning the bucket-padded
        design matrix — only invoked on a miss, so hits skip the host-side
        padding entirely.  ``spec`` (optional) additionally warms the
        method's derived state (thr-padded column norms, block-Gram
        Cholesky, the fused kernel's resident tiers) on hit AND miss — the
        dispatcher's pre-warm passes it so those builds run off the lane
        threads; idempotent + per-entry locked, so racing with a lane
        thread is safe.  ``placement``/``mesh`` extend the warm to the
        lane-resident sharded copy and bind the entry's home placement
        (``PreparedDesign.bind_home`` — first-wins).  Returns
        (entry, cache_hit).

        Store-backed: a device-tier miss first tries ``store.promote`` —
        climbing the design back from its host/disk snapshot (with warm
        coefficients and Cholesky state restored) counts as a *hit*, since
        ``build_x_pad`` never runs.  The dispatcher's pre-warm routes
        through here, so promotion overlaps queue wait by construction.
        Only a design unknown to every tier rebuilds from source.
        """
        if self.store is not None:
            entry = self.store.get(key)
            hit = entry is not None
            if not hit:
                before = self.store.stats.demotions_device
                promoted = self.store.promote(key)
                if promoted is not None:
                    entry, hit = promoted, True
                self._sync_evictions(before)
            self._record_lookup(hit, record_stats)
            if not hit:
                before = self.store.stats.demotions_device
                entry = self.store.build(
                    key, np.asarray(build_x_pad(), np.float32),
                    max_tenants=self.max_tenants)
                self._sync_evictions(before)
        else:
            entry = self.get(key, record_stats)
            hit = entry is not None
            if not hit:
                built = prepare(np.asarray(build_x_pad(), np.float32),
                                fingerprint=key, max_tenants=self.max_tenants)
                entry = self.put(key, built)
        if spec is not None:
            entry.warm_lane_state(spec, placement=placement, mesh=mesh)
        else:
            entry.bind_home(placement)
            if (placement is not None and placement.sharded
                    and mesh is not None and entry.x_pad is not None):
                entry.x_for_placement(placement, mesh)
        return entry, hit
