"""repro.serve — batched multi-tenant solver-serving engine.

Turns the BAK solver library into a serving system: many concurrent
``SolveRequest``s are bucketed by padded power-of-two shape, same-design
requests are coalesced into one multi-RHS core solve (one stream of ``x``
serves every tenant that shares it), remaining same-bucket requests are
vmapped, and per-design state (device copy, column norms, block-Gram
Cholesky) is memoised in an LRU cache.

Layout:
  types.py     SolveRequest / ServedSolve records.
  batching.py  pow-2 shape buckets, exact zero padding, design fingerprints,
               deterministic request grouping.
  cache.py     LRU DesignCache of per-design solver state.
  engine.py    SolverServeEngine — submit/flush front-end.

Drivers: ``repro.launch.solver_serve`` (CLI) and
``benchmarks/serve_throughput.py`` (coalescing speedup vs sequential solve).
"""
from repro.serve.batching import (bucket_shape, design_fingerprint,
                                  group_requests, next_pow2, pad_x, pad_y)
from repro.serve.cache import CacheStats, DesignCache, DesignEntry
from repro.serve.engine import ServeConfig, ServeStats, SolverServeEngine
from repro.serve.types import ServedSolve, SolveRequest

__all__ = [
    "CacheStats",
    "DesignCache",
    "DesignEntry",
    "ServeConfig",
    "ServeStats",
    "ServedSolve",
    "SolveRequest",
    "SolverServeEngine",
    "bucket_shape",
    "design_fingerprint",
    "group_requests",
    "next_pow2",
    "pad_x",
    "pad_y",
]
