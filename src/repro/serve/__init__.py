"""repro.serve — batched multi-tenant solver-serving engine.

Turns the BAK solver library into a serving system: many concurrent
``SolveRequest``s are bucketed by padded power-of-two shape, same-design
requests are coalesced into one multi-RHS core solve (one stream of ``x``
serves every tenant that shares it), remaining same-bucket requests are
vmapped, per-design state (device copy, column norms, block-Gram Cholesky,
per-tenant warm-start coefficients) is memoised in an LRU cache, and an
async dispatcher overlays deadline-aware batching with backpressure on top
of the synchronous engine.

Layout:
  types.py     SolveRequest / ServedSolve records.
  batching.py  pow-2 shape buckets, exact zero padding, design fingerprints,
               deterministic request grouping, request validation.
  cache.py     LRU DesignCache of per-design solver state + warm coefs.
  engine.py    SolverServeEngine — submit/flush front-end.
  dispatch.py  AsyncDispatcher — bounded intake queue, per-request
               deadlines, full/deadline/idle flush policy, host-side
               bucketing overlapped with in-flight device solves.

Drivers: ``repro.launch.solver_serve`` (CLI; sync + async modes),
``benchmarks/serve_throughput.py`` (coalescing speedup vs sequential solve)
and ``benchmarks/serve_async.py`` (async latency/deadline + warm-start
sweep savings).
"""
from repro.serve.batching import (bucket_shape, design_fingerprint,
                                  group_requests, next_pow2, pad_x, pad_y,
                                  prepare_request)
from repro.serve.cache import CacheStats, DesignCache, DesignEntry
from repro.serve.dispatch import (AsyncDispatcher, DispatchConfig,
                                  DispatcherStopped, DispatchStats,
                                  QueueFullError, SolveTicket)
from repro.serve.engine import ServeConfig, ServeStats, SolverServeEngine
from repro.serve.types import ServedSolve, SolveRequest

__all__ = [
    "AsyncDispatcher",
    "CacheStats",
    "DesignCache",
    "DesignEntry",
    "DispatchConfig",
    "DispatchStats",
    "DispatcherStopped",
    "QueueFullError",
    "ServeConfig",
    "ServeStats",
    "ServedSolve",
    "SolveRequest",
    "SolveTicket",
    "SolverServeEngine",
    "bucket_shape",
    "design_fingerprint",
    "group_requests",
    "next_pow2",
    "pad_x",
    "pad_y",
    "prepare_request",
]
