"""repro.serve — batched multi-tenant solver-serving engine.

Turns the BAK solver library into a serving system: many concurrent
``SolveRequest``s are bucketed by padded power-of-two shape, same-design
requests are coalesced into one multi-RHS core solve (one stream of ``x``
serves every tenant that shares it), remaining same-bucket requests are
vmapped, per-design state (device copy, column norms, block-Gram Cholesky,
per-tenant warm-start coefficients) is memoised in an LRU cache, and an
async dispatcher overlays deadline-aware batching with backpressure on top
of the synchronous engine.

The engine is a consumer of the public core API (PR 4): requests carry a
``repro.core.SolverSpec`` (or legacy per-field knobs, mirrored into one),
per-design state is a ``repro.core.PreparedDesign`` handle cached in the
``DesignCache``, and every solve dispatches through
``PreparedDesign.solve`` + the method registry — backends registered via
``repro.core.register_method`` are servable without touching this package.

Layout:
  types.py     SolveRequest / ServedSolve records.
  batching.py  pow-2 shape buckets, exact zero padding, design fingerprints,
               deterministic request grouping (canonical-spec keyed),
               request validation.
  cache.py     LRU DesignCache of PreparedDesign handles (per-design solver
               state + warm coefs); with a ``repro.store.DesignStore``
               attached it becomes a view over the store's device tier —
               eviction demotes to host/disk instead of deleting, and
               over-budget designs serve as non-resident streaming handles.
  placement.py Placement/PlacementPolicy/ServeMesh — routing buckets onto
               the mesh-sharded solvers (obs-sharded, k-sharded multi-RHS,
               2-D) by padded size.
  lanes.py     execution lanes — one executor thread per (device set,
               kernel path) with a most-urgent-first queue; LanePool
               routes batches by registry/placement lookup.
  engine.py    SolverServeEngine — submit/flush front-end; flush() builds
               batches and submits them to its lanes.
  dispatch.py  AsyncDispatcher — bounded intake queue, per-request
               deadlines, full/deadline/idle flush policy, host-side
               bucketing overlapped with in-flight device solves; fired
               batches fan out across the engine's lanes.

Every layer records into ``repro.obs`` (PR 6): the engine/cache/dispatcher
dual-write their stats dataclasses and a ``MetricsRegistry`` (injectable;
the process-global one by default), every ``ServedSolve`` carries a
``SolveTelemetry`` record, and the exporters
(``repro.obs.write_metrics_json`` / ``start_metrics_server``) read the
same registry the benchmarks report from.

Drivers: ``repro.launch.solver_serve`` (CLI; sync + async modes),
``benchmarks/serve_throughput.py`` (coalescing speedup vs sequential solve)
and ``benchmarks/serve_async.py`` (async latency/deadline + warm-start
sweep savings).
"""
from repro.core.prepare import PreparedDesign
from repro.core.spec import SolverSpec, UnsupportedSpecError
from repro.obs import SolveTelemetry
from repro.serve.batching import (bucket_shape, design_fingerprint,
                                  group_requests, next_pow2, pad_x, pad_y,
                                  prepare_request)
from repro.serve.cache import CacheStats, DesignCache, DesignEntry
from repro.serve.dispatch import (AsyncDispatcher, DispatchConfig,
                                  DispatcherStopped, DispatchStats,
                                  QueueFullError, SolveTicket,
                                  TicketCancelled)
from repro.serve.engine import ServeConfig, ServeStats, SolverServeEngine
from repro.serve.lanes import (LaneExecutor, LaneKey, LanePool, LaneShutdown,
                               LaneStats, LaneWork, LaneWorkerDeath,
                               current_lane, lane_for)
from repro.serve.placement import (Placement, PlacementPolicy, ServeMesh,
                                   build_serve_mesh, mesh_device_count,
                                   placement_for_bucket, placement_for_group)
from repro.serve.types import ServedSolve, SolveRequest
from repro.store import DesignStore, StoreStats

__all__ = [
    "AsyncDispatcher",
    "CacheStats",
    "DesignCache",
    "DesignEntry",
    "DesignStore",
    "DispatchConfig",
    "DispatchStats",
    "DispatcherStopped",
    "LaneExecutor",
    "LaneKey",
    "LanePool",
    "LaneShutdown",
    "LaneStats",
    "LaneWork",
    "LaneWorkerDeath",
    "Placement",
    "PlacementPolicy",
    "PreparedDesign",
    "QueueFullError",
    "ServeConfig",
    "ServeMesh",
    "ServeStats",
    "ServedSolve",
    "SolveRequest",
    "SolveTelemetry",
    "SolveTicket",
    "SolverServeEngine",
    "SolverSpec",
    "StoreStats",
    "TicketCancelled",
    "UnsupportedSpecError",
    "build_serve_mesh",
    "mesh_device_count",
    "placement_for_bucket",
    "placement_for_group",
    "bucket_shape",
    "current_lane",
    "design_fingerprint",
    "lane_for",
    "group_requests",
    "next_pow2",
    "pad_x",
    "pad_y",
    "prepare_request",
]
