"""Shape bucketing, padding and design grouping for the serving engine.

Serving traffic arrives with arbitrary (obs, vars) shapes; jitting one
program per exact shape would recompile unboundedly.  Requests are therefore
padded up to power-of-two **buckets** — the compile cache is keyed by bucket,
so the number of distinct compiled programs is logarithmic in the shape range
actually seen.  Zero padding is exact for least squares:

  * extra zero *rows* contribute nothing to any inner product ⟨x_j, e⟩ or
    column norm, so the normal equations are unchanged;
  * extra zero *columns* have zero norm — ``safe_inv`` pins their updates to
    0 (and ``mode="gram"``'s ridge keeps the block factorisation well-posed),
    so their coefficients stay exactly 0 and are stripped on the way out;
  * extra zero *right-hand sides* (multi-RHS k-padding) solve the trivial
    system with an all-zero coefficient column.

Grouping is deterministic: groups are keyed in first-seen submission order
(python dict insertion order), so a fixed request list always produces the
same buckets, the same groups and the same intra-group ordering.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.core.methods  # noqa: F401  (populates the method registry)
from repro.core.prepare import design_fingerprint as _core_fingerprint
from repro.core.spec import SolverSpec, is_registered, method_names
from repro.serve.types import SolveRequest

Bucket = Tuple[int, int]


def prepare_request(req: SolveRequest, *,
                    fingerprint: bool = False) -> SolveRequest:
    """Validate a request and normalise its arrays to host numpy, in place.

    Called by ``SolverServeEngine.submit`` — and, in the async path, by the
    dispatcher thread *before* the request reaches the engine, so array
    normalisation and (with ``fingerprint=True``) design hashing overlap
    with whatever solve is in flight on the device.  Idempotent: a prepared
    request passes through unchanged, so engine.submit re-preparing one the
    dispatcher already handled is free.

    A request carrying an explicit ``SolveRequest.spec`` has its legacy
    mirror fields (method/max_iter/atol/rtol/thr) synced from it, so code
    that still reads those sees the authoritative values.
    """
    x = req.x = np.asarray(req.x)
    if x.ndim != 2:
        raise ValueError(f"request x must be 2D (obs, vars), got {x.shape}")
    y = req.y = np.asarray(req.y)
    if y.ndim != 1 or y.shape[0] != x.shape[0]:
        raise ValueError(
            f"request y must be (obs,) matching x rows, got {y.shape} "
            f"for x {x.shape}")
    if req.a0 is not None:
        a0 = req.a0 = np.asarray(req.a0, np.float32)
        if a0.shape != (x.shape[1],):
            raise ValueError(
                f"request a0 must be (vars,) = ({x.shape[1]},) matching x "
                f"columns, got {a0.shape}")
    if req.spec is not None:  # spec wins; mirror for legacy readers
        req.method = req.spec.method
        req.max_iter = req.spec.max_iter
        req.atol = req.spec.atol
        req.rtol = req.spec.rtol
        req.thr = req.spec.thr
    if not is_registered(req.method):
        raise ValueError(
            f"method must be one of {method_names()}, got {req.method!r}")
    if req.deadline_s is not None and req.deadline_s <= 0:
        raise ValueError(f"deadline_s must be positive, got {req.deadline_s}")
    if fingerprint and req.design_key is None:
        req.design_key = design_fingerprint(x)
    return req


def next_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two ≥ max(n, floor)."""
    n = max(int(n), int(floor))
    return 1 << (n - 1).bit_length()


def bucket_shape(obs: int, nvars: int, *, min_obs: int = 8,
                 min_vars: int = 8) -> Bucket:
    """Padded (obs, vars) bucket for a request shape."""
    return next_pow2(obs, min_obs), next_pow2(nvars, min_vars)


def pad_x(x: np.ndarray, bucket: Bucket) -> np.ndarray:
    """Zero-pad a design matrix up to ``bucket``.  Returns fp32 numpy."""
    x = np.asarray(x, np.float32)
    obs, nvars = x.shape
    obs_p, vars_p = bucket
    if (obs, nvars) == (obs_p, vars_p):
        return x
    x_pad = np.zeros((obs_p, vars_p), np.float32)
    x_pad[:obs, :nvars] = x
    return x_pad


def pad_y(y: np.ndarray, obs_p: int) -> np.ndarray:
    """Zero-pad a right-hand side (obs,) or (obs, k) to ``obs_p`` rows."""
    y = np.asarray(y, np.float32)
    if y.shape[0] == obs_p:
        return y
    y_pad = np.zeros((obs_p,) + y.shape[1:], np.float32)
    y_pad[: y.shape[0]] = y
    return y_pad


def design_fingerprint(x, *, _prefix: str = "d") -> str:
    """Content fingerprint of a design matrix (delegates to
    ``repro.core.design_fingerprint`` — the identity lives with the
    ``PreparedDesign`` handle now).

    Two requests whose ``x`` hash equal are coalesced into one multi-RHS
    solve and share one design-cache entry.  Callers that already know two
    matrices are identical can skip this by setting
    ``SolveRequest.design_key``.
    """
    return _core_fingerprint(x, _prefix=_prefix)


def request_bucket(req: SolveRequest, *, min_obs: int = 8,
                   min_vars: int = 8) -> Bucket:
    obs, nvars = np.asarray(req.x).shape
    return bucket_shape(obs, nvars, min_obs=min_obs, min_vars=min_vars)


def config_key(req: SolveRequest, bucket: Bucket, placement=None,
               spec: Optional[SolverSpec] = None) -> Tuple:
    """Outer grouping key: ``(bucket, method, canonical spec[, placement])``.

    The canonical spec (``SolverSpec.canonical``) resets every field the
    method's registry entry does not consume, so only knob differences that
    would change the result split a group — direct methods ignore every
    iteration knob and any mix of per-tenant max_iter/rtol/thr still
    coalesces into one multi-RHS solve; "bak" additionally ignores ``thr``.
    bucket and method always lead (the engine reads outer[0]/outer[1]).

    ``spec`` overrides the spec derived from the request — the engine passes
    its effective spec (engine-level omega/ridge applied) so grouping always
    matches what will actually be solved.

    ``placement`` (a ``repro.serve.placement.Placement``, or None for the
    mesh-less engine) always trails the key: a compiled program is laid out
    for exactly one mesh placement, so requests routed to different
    placements must never share a batch even if every solver knob matches.
    """
    spec = spec if spec is not None else req.solver_spec()
    key: Tuple = (bucket, spec.method, spec.canonical())
    if placement is not None:
        key = key + (placement,)
    return key


def group_requests(
    requests: List[SolveRequest], *, min_obs: int = 8, min_vars: int = 8,
    placement_fn=None, spec_fn=None,
) -> Dict[Tuple, Dict[str, List[int]]]:
    """Group request indices: (bucket, method-config) → design key → [idx].

    The outer key (``config_key``) includes exactly the solver knobs the
    method consumes, so only requests that can legally share one compiled
    solve land in the same group; the inner key is the design fingerprint
    (or caller-supplied ``design_key``).  Insertion order of both levels
    follows first occurrence in ``requests``.

    ``placement_fn(bucket, method) -> Placement`` (optional) appends the
    mesh placement to the outer key; ``spec_fn(request) -> SolverSpec``
    (optional) supplies the effective spec (the engine passes
    ``SolverServeEngine.spec_for``) — see ``config_key``.
    """
    groups: Dict[Tuple, Dict[str, List[int]]] = {}
    for i, req in enumerate(requests):
        bucket = request_bucket(req, min_obs=min_obs, min_vars=min_vars)
        spec = spec_fn(req) if spec_fn is not None else req.solver_spec()
        placement = (placement_fn(bucket, spec.method)
                     if placement_fn is not None else None)
        key = req.design_key or design_fingerprint(req.x)
        groups.setdefault(config_key(req, bucket, placement, spec),
                          {}).setdefault(key, []).append(i)
    return groups
