"""Request/response records for the solver-serving engine.

A ``SolveRequest`` is one tenant's system ``x @ a ≈ y``; the engine groups
requests into padded shape buckets, coalesces requests that share a design
matrix into one multi-RHS solve, and returns one ``ServedSolve`` per request
with all padding stripped and per-request accuracy/latency metadata attached.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.spec import SolverSpec
from repro.obs import SolveTelemetry


@dataclass
class SolveRequest:
    """One solve request.

    Attributes:
      x: (obs, vars) design matrix (numpy or jax array).
      y: (obs,) right-hand side.
      spec: optional ``repro.core.SolverSpec`` carrying the full solver
        configuration — the preferred form.  When set it wins over the
        legacy per-field knobs below (which are synced from it during
        validation so older readers keep seeing consistent values).
      method: solver method — any name in ``repro.core.method_names()``
        (same registry as ``repro.core.solve``).  Requests are only
        coalesced/batched with requests whose canonical spec matches.
      max_iter / atol / rtol / thr: legacy solver knobs (see
        ``repro.core.SolverSpec``); ignored when ``spec`` is given.
      a0: optional (vars,) initial coefficients (warm start).  The iterative
        methods start from ``a0`` instead of zeros, so a request whose ``y``
        drifted only slightly since its last solve converges in a fraction of
        the cold-start sweeps.  Warm and cold requests still coalesce into
        one multi-RHS solve (cold members ride a zero column of the stacked
        ``a0``).  Ignored by the direct methods ("lstsq"/"normal").
      tenant_id: optional stable caller identity.  When set (and the engine's
        ``warm_cache`` is on) the design cache retains this tenant's last
        coefficients keyed by (design, tenant) and uses them as ``a0`` on the
        tenant's next solve against the same design; an explicit ``a0`` takes
        precedence over the cached one.
      deadline_s: optional *relative* deadline in seconds (from submit time).
        The synchronous engine ignores it; the async dispatcher
        (``repro.serve.dispatch``) flushes a bucket early so its oldest
        member completes before its deadline, and reports misses.
      design_key: optional caller-provided identity for ``x``.  When two
        requests carry the same key the engine trusts it and skips hashing
        the matrix bytes; leave None to let the engine fingerprint ``x``.
      request_id: optional caller tag, echoed on the result.
      deadline_at: optional *absolute* deadline on the ``obs.now()`` clock.
        Stamped by the async dispatcher from ``deadline_s`` at submit time;
        synchronous callers may set it directly.  The engine's retry ladder
        (``repro.resilience``) stops retrying once it passes — a request
        never burns its deadline on backoff sleeps.
    """

    x: Any
    y: Any
    method: str = "bakp_gram"
    max_iter: int = 50
    atol: float = 0.0
    rtol: float = 0.0
    thr: int = 128
    spec: Optional[SolverSpec] = None
    a0: Optional[Any] = None
    tenant_id: Optional[str] = None
    deadline_s: Optional[float] = None
    design_key: Optional[str] = None
    request_id: Optional[str] = None
    deadline_at: Optional[float] = None

    def solver_spec(self) -> SolverSpec:
        """The request's ``SolverSpec``: the explicit ``spec`` when given,
        else one built from the legacy per-field knobs (engine-level
        ``omega``/``ridge`` defaults are applied by the engine — see
        ``SolverServeEngine.spec_for``)."""
        if self.spec is not None:
            return self.spec
        return SolverSpec(method=self.method, max_iter=int(self.max_iter),
                          atol=float(self.atol), rtol=float(self.rtol),
                          thr=int(self.thr))


@dataclass
class ServedSolve:
    """Per-request result, padding stripped back to the request's shapes.

    ``batch_kind`` records how the request was executed:
      "multi_rhs" — coalesced with same-design requests into one (obs, k)
                    multi-RHS solve;
      "vmap"      — stacked with same-bucket (different-design) requests
                    into one vmapped batch solve;
      "single"    — solved alone.
    ``latency_s`` is the wall time of the batch solve the request rode in
    (shared by all members of the batch); ``group_size`` its occupancy.

    For a coalesced ("multi_rhs") request, ``n_sweeps``/``converged`` are
    group-level: the solver's stopping criterion is the group-total SSE
    (with the absolute tolerance corrected for padding), so an individual
    tenant in a group is not guaranteed its own per-column atol.  ``sse``
    is always this request's own, recomputed from the stripped residual.

    ``warm_start`` is True when the solve started from a non-zero ``a0``
    (explicit or recalled from the design cache's per-tenant coefficient
    store).  ``error`` is None on success; on a solver failure the engine
    isolates the poisoned batch, fills ``error`` with the exception text and
    returns zero coefficients (``converged=False``) instead of wedging the
    whole flush — check ``ok`` before trusting ``coef``.

    ``placement`` records which backend the solve ran on: "single" (one
    device), or a mesh placement — "obs_sharded" (design rows sharded over
    the data axes), "rhs_sharded" (the coalesced group's k axis sharded,
    ``x`` replicated) or "mesh_2d" (rows × columns over a 2-D mesh).  See
    ``repro.serve.placement``.

    ``retries`` counts the retry-ladder steps the solve took before this
    result (``repro.resilience``): 0 = first attempt; the ``batch_kind``/
    ``placement``/telemetry method describe the rung that finally served.

    ``telemetry`` is the request's ``repro.obs.SolveTelemetry`` record —
    everything above plus the kernel path that actually executed (fused /
    persweep / xla / sharded / vmap), and, on the async path, queue wait
    and deadline margin (back-filled by the dispatcher).  None when obs is
    disabled (``REPRO_OBS_DISABLED=1``).
    """

    request_id: str
    coef: np.ndarray
    residual: np.ndarray
    sse: float
    n_sweeps: int
    converged: bool
    bucket: tuple = (0, 0)
    batch_kind: str = "single"
    group_size: int = 1
    latency_s: float = 0.0
    cache_hit: bool = False
    warm_start: bool = False
    placement: str = "single"
    retries: int = 0
    error: Optional[str] = None
    extra: dict = field(default_factory=dict)
    telemetry: Optional[SolveTelemetry] = None

    @property
    def ok(self) -> bool:
        return self.error is None
